"""Perf-regression gate: compare a fresh BENCH record against the committed
baseline.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline benchmarks/baseline.json --candidate bench.json

Metric specs are **direction-aware** — ``(json path, label, direction)``
where direction is ``"lower"`` (kernel counts, modeled times: growth beyond
tolerance fails), ``"higher"`` (throughputs: a drop beyond tolerance
fails), ``"exact"`` (structural counts that must not drift at all),
``"positive"`` (liveness gates: the candidate value must be > 0 regardless
of the baseline — a zero prefix-cache hit rate or zero stitched-prefill
kernels means the feature silently stopped engaging), or ``"max:<bound>"``
(absolute ceiling judged on the candidate alone — verifier findings must
stay at 0, verify overhead under 5% of the cold compile).

Gated sections:

* per-workload **stitched kernel count** and **modeled stitch step time**
  (lower) — the paper's kernel-compression win eroding / a slower plan;
* **training** — stitched kernel count / modeled time of the traced
  backward graph, and the packed AdamW+clip update's kernel count (1 when
  the whole multi-tensor update shares a single packed kernel);
* **serving** — continuous- and static-mode ``tokens_per_sec`` (higher):
  a throughput drop beyond tolerance fails, an improvement passes.  These
  are the only *wall-clock* gated metrics: best-of-reps in the harness
  damps within-machine jitter, and ``--serving-tolerance`` (default: the
  global tolerance) lets CI widen just these against a baseline recorded
  on different hardware without loosening the deterministic gates.  The
  prefix-heavy sub-run adds two liveness gates (positive): the
  prefix-cache hit rate and the stitched-prefill kernel count;
* **packing** — horizontal FFD packing on the wide-expert MoE block:
  packed-plan kernel count (lower), packs formed / subgraphs packed
  (positive liveness — a zero means the packer silently stopped
  engaging), and the packed-vs-unpacked kernel-reduction ratio (higher);
* **verify** — the static verifier's total ERROR findings across workload
  plans (``max:0`` — any finding on a healthy build is a verifier or
  compiler bug) and its worst in-compile overhead fraction (``max:0.05``);
* **sharding** — per-shard stitched kernel counts / modeled times of the
  mesh-placed backward and packed-update graphs (lower), and the count of
  distinct mesh-keyed cache entries (exact: losing a placement means two
  meshes started sharing one plan).

A candidate fails when a gated metric moves beyond ``--tolerance`` (default
10%) in the bad direction.  Workloads present only in the candidate are
reported as new (not gated); workloads or sections missing from the
candidate fail the gate — losing coverage silently is itself a regression.
"""

from __future__ import annotations

import argparse
import json
import sys

TOLERANCE = 0.10

# (json path inside workloads[name], label, direction)
METRICS = (
    (("kernels", "stitch"), "stitched_kernels", "lower"),
    (("modeled_time_s", "stitch"), "modeled_stitch_time_s", "lower"),
)

# json paths inside the top-level "training" section
TRAINING_METRICS = (
    (("grad", "kernels", "stitch"), "grad_stitched_kernels", "lower"),
    (("grad", "modeled_time_s", "stitch"), "grad_modeled_stitch_time_s", "lower"),
    (("packed_update", "kernels", "stitch"), "packed_update_kernels", "lower"),
    (("packed_update", "modeled_time_s", "stitch"),
     "packed_update_modeled_time_s", "lower"),
)

# json paths inside the top-level "serving" section — throughputs, so a
# DROP beyond tolerance is the regression
SERVING_METRICS = (
    (("continuous", "tokens_per_sec"), "continuous_tokens_per_sec", "higher"),
    (("static", "tokens_per_sec"), "static_tokens_per_sec", "higher"),
    # liveness, not wall clock: the prefix cache must actually hit and the
    # bucketed prefills must actually carry stitched plans
    (("prefix", "prefix_cache", "hit_rate"), "prefix_cache_hit_rate",
     "positive"),
    (("prefix", "prefill", "n_kernels"), "prefill_stitched_kernels",
     "positive"),
)

# json paths inside the top-level "compute_stitching" section — kernel
# counts after GEMM/custom-kernel admission into stitched partitions.
# Deterministic (no wall clock): the block must stay collapsed and both
# plans must actually carry Pallas groups.
COMPUTE_METRICS = (
    (("block_fn", "n_kernels"), "block_fn_kernels", "lower"),
    (("block_fn", "pallas_groups"), "block_fn_pallas_groups", "positive"),
    (("decode", "n_kernels"), "decode_kernels", "lower"),
    (("decode", "pallas_groups"), "decode_pallas_groups", "positive"),
)

# json paths inside the top-level "packing" section — horizontal FFD
# packing on the wide-expert MoE block.  Deterministic: the packed plan's
# kernel count must not grow and the packer must actually form packs (a
# zero means horizontal packing silently stopped engaging).
PACKING_METRICS = (
    (("packed", "n_kernels"), "packed_kernels", "lower"),
    (("packed", "packs"), "packs_formed", "positive"),
    (("packed", "packed_subgraphs"), "packed_subgraphs", "positive"),
    (("kernel_reduction",), "pack_kernel_reduction", "higher"),
)

# The "measured" section is schema-checked, not value-gated: interpret-mode
# wall clock is too noisy to gate, but losing the measured-timing record
# entirely (the timer silently disabled, the section dropped from the
# harness) must fail loudly — it is the perf trajectory's ground truth.
MEASURED_REQUIRED_KEYS = ("rmsnorm_us", "softmax_us", "exec")
MEASURED_EXEC_KEYS = ("measured_s", "modeled_time_s", "calls")

# json paths inside the top-level "verify" section — the static verifier
# must stay clean (zero ERROR findings across every workload plan) and
# cheap (in-compile verification bounded at 5% of the cold compile)
VERIFY_METRICS = (
    (("findings_total",), "verify_findings_total", "max:0"),
    (("max_overhead_frac",), "verify_max_overhead_frac", "max:0.05"),
)

# json paths inside the top-level "sharding" section
SHARDING_METRICS = (
    (("grad_local", "kernels", "stitch"), "grad_local_stitched_kernels", "lower"),
    (("grad_local", "modeled_time_s", "stitch"),
     "grad_local_modeled_stitch_time_s", "lower"),
    (("packed_local", "kernels", "stitch"), "packed_local_kernels", "lower"),
    (("cache", "mesh_keyed_entries"), "mesh_keyed_entries", "exact"),
)


def _get(d: dict, path) -> float | None:
    for k in path:
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d


def _gate_metric(b, c, label, direction, tolerance, failures, lines,
                 row_name):
    """One direction-aware comparison; appends to failures/lines."""
    if direction == "positive":
        # liveness gates judge the candidate alone; a baseline that
        # predates the metric skips it (same rule as whole sections),
        # but a candidate that lost it is lost coverage
        if b is None and c is None:
            return
        if c is None:
            failures.append(f"{row_name}.{label}: metric missing "
                            f"from candidate (baseline={b:g})")
            return
        verdict = "OK"
        if c <= 0:
            verdict = "REGRESSION"
            failures.append(f"{row_name}.{label}: candidate {c:g} "
                            f"(must be > 0)")
        b_str = f"{b:g}" if b is not None else "-"
        lines.append(f"{row_name},{label},{b_str},{c:g},-,{verdict}")
        return
    if direction.startswith("max:"):
        # absolute ceiling on the candidate; the baseline only decides
        # whether a missing candidate value is lost coverage
        bound = float(direction[4:])
        if b is None and c is None:
            return
        if c is None:
            failures.append(f"{row_name}.{label}: metric missing "
                            f"from candidate (bound <= {bound:g})")
            return
        verdict = "OK"
        if c > bound:
            verdict = "REGRESSION"
            failures.append(f"{row_name}.{label}: candidate {c:g} "
                            f"exceeds bound {bound:g}")
        b_str = f"{b:g}" if b is not None else "-"
        lines.append(f"{row_name},{label},{b_str},{c:g},-,{verdict}")
        return
    if b is None or c is None:
        failures.append(f"{row_name}.{label}: metric missing "
                        f"(baseline={b}, candidate={c})")
        return
    ratio = c / b if b else float("inf") if c else 1.0
    verdict = "OK"
    if direction == "lower" and ratio > 1.0 + tolerance:
        verdict = "REGRESSION"
        failures.append(
            f"{row_name}.{label}: {b:g} -> {c:g} "
            f"(+{100 * (ratio - 1):.1f}% > {100 * tolerance:.0f}%)")
    elif direction == "higher" and ratio < 1.0 - tolerance:
        verdict = "REGRESSION"
        failures.append(
            f"{row_name}.{label}: {b:g} -> {c:g} "
            f"(-{100 * (1 - ratio):.1f}% drop > {100 * tolerance:.0f}%)")
    elif direction == "exact" and c != b:
        verdict = "REGRESSION"
        failures.append(f"{row_name}.{label}: {b:g} -> {c:g} "
                        f"(must match exactly)")
    lines.append(f"{row_name},{label},{b:g},{c:g},{ratio:.3f},{verdict}")


def _gate_section(baseline: dict, candidate: dict, section: str, specs,
                  tolerance, failures, lines) -> None:
    """Gate one top-level record section; a section in the baseline but not
    the candidate is lost coverage (fails)."""
    base = baseline.get(section)
    if base is None:
        return                            # baseline predates this section
    cand = candidate.get(section)
    if cand is None:
        failures.append(f"{section}: section missing from candidate record")
        return
    for path, label, direction in specs:
        _gate_metric(_get(base, path), _get(cand, path), label, direction,
                     tolerance, failures, lines, section)


def compare(baseline: dict, candidate: dict, tolerance: float = TOLERANCE,
            serving_tolerance: float | None = None):
    """Returns (failures, lines): failure strings (empty = pass) and the
    full per-metric report.  ``serving_tolerance`` overrides ``tolerance``
    for the wall-clock serving section only (cross-machine baselines)."""
    failures, lines = [], []
    base_wl = baseline.get("workloads", {})
    cand_wl = candidate.get("workloads", {})
    for name in sorted(base_wl):
        if name not in cand_wl:
            failures.append(f"{name}: missing from candidate record")
            continue
        for path, label, direction in METRICS:
            _gate_metric(_get(base_wl[name], path), _get(cand_wl[name], path),
                         label, direction, tolerance, failures, lines, name)
    for name in sorted(set(cand_wl) - set(base_wl)):
        lines.append(f"{name},-,-,-,-,NEW (not gated)")

    _gate_section(baseline, candidate, "training", TRAINING_METRICS,
                  tolerance, failures, lines)
    _gate_section(baseline, candidate, "serving", SERVING_METRICS,
                  tolerance if serving_tolerance is None else serving_tolerance,
                  failures, lines)
    _gate_section(baseline, candidate, "sharding", SHARDING_METRICS,
                  tolerance, failures, lines)
    _gate_section(baseline, candidate, "verify", VERIFY_METRICS,
                  tolerance, failures, lines)
    _gate_section(baseline, candidate, "compute_stitching", COMPUTE_METRICS,
                  tolerance, failures, lines)
    _gate_section(baseline, candidate, "packing", PACKING_METRICS,
                  tolerance, failures, lines)
    check_measured_schema(baseline, candidate, failures, lines)
    return failures, lines


def check_measured_schema(baseline: dict, candidate: dict, failures,
                          lines) -> None:
    """Fail loudly when the candidate lacks the measured-timing section (or
    its required keys); the values themselves stay ungated."""
    if not isinstance(baseline.get("measured"), dict):
        return                            # baseline predates this section
    meas = candidate.get("measured")
    if not isinstance(meas, dict):
        failures.append("measured: section missing from candidate record "
                        "(measured-kernel timing was not captured)")
        return
    missing = [k for k in MEASURED_REQUIRED_KEYS if k not in meas]
    exec_rec = meas.get("exec")
    if isinstance(exec_rec, dict):
        missing += [f"exec.{k}" for k in MEASURED_EXEC_KEYS
                    if k not in exec_rec]
    if missing:
        failures.append(f"measured: keys missing from candidate record: "
                        f"{', '.join(missing)}")
        return
    lines.append("measured,schema,-,-,-,OK (values not gated)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--candidate", required=True)
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    ap.add_argument("--serving-tolerance", type=float, default=None,
                    help="wider tolerance for the wall-clock serving "
                         "throughput gate only (default: --tolerance); use "
                         "when the committed baseline was recorded on "
                         "different hardware")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)

    failures, lines = compare(baseline, candidate, args.tolerance,
                              serving_tolerance=args.serving_tolerance)
    print("workload,metric,baseline,candidate,ratio,verdict")
    for line in lines:
        print(line)
    if failures:
        print(f"\nFAIL — {len(failures)} perf regression(s) "
              f"beyond {100 * args.tolerance:.0f}%:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print(f"\nPASS — no metric regressed beyond {100 * args.tolerance:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
