"""Perf-regression gate: compare a fresh BENCH record against the committed
baseline.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline benchmarks/baseline.json --candidate bench.json

Gated metrics (deterministic modeled quantities only — wall-clock numbers
in the record are informational and too noisy to gate):

* per-workload **stitched kernel count** — more kernels than baseline means
  fusion got worse (the paper's kernel-compression win eroding);
* per-workload **modeled stitch step time** — the cost model's end-to-end
  estimate regressing means a slower plan shipped;
* **training metrics** — stitched kernel count / modeled time of the traced
  backward graph, and the packed AdamW+clip update's kernel count (1 when
  the whole multi-tensor update shares a single packed kernel).  Wall-clock
  step times in the record are informational only.

A candidate fails when either metric exceeds baseline by more than
``--tolerance`` (default 10%).  Workloads present only in the candidate are
reported as new (not gated); workloads missing from the candidate fail the
gate — losing coverage silently is itself a regression.
"""

from __future__ import annotations

import argparse
import json
import sys

TOLERANCE = 0.10

# (json path inside workloads[name], label, gate?) — lower is better for all
METRICS = (
    (("kernels", "stitch"), "stitched_kernels"),
    (("modeled_time_s", "stitch"), "modeled_stitch_time_s"),
)

# json paths inside the top-level "training" section — lower is better
TRAINING_METRICS = (
    (("grad", "kernels", "stitch"), "grad_stitched_kernels"),
    (("grad", "modeled_time_s", "stitch"), "grad_modeled_stitch_time_s"),
    (("packed_update", "kernels", "stitch"), "packed_update_kernels"),
    (("packed_update", "modeled_time_s", "stitch"), "packed_update_modeled_time_s"),
)


def _get(d: dict, path) -> float | None:
    for k in path:
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d


def compare(baseline: dict, candidate: dict, tolerance: float = TOLERANCE):
    """Returns (failures, lines): failure strings (empty = pass) and the
    full per-metric report."""
    failures, lines = [], []
    base_wl = baseline.get("workloads", {})
    cand_wl = candidate.get("workloads", {})
    for name in sorted(base_wl):
        if name not in cand_wl:
            failures.append(f"{name}: missing from candidate record")
            continue
        for path, label in METRICS:
            b = _get(base_wl[name], path)
            c = _get(cand_wl[name], path)
            if b is None or c is None:
                failures.append(f"{name}.{label}: metric missing "
                                f"(baseline={b}, candidate={c})")
                continue
            ratio = c / b if b else float("inf") if c else 1.0
            verdict = "OK"
            if ratio > 1.0 + tolerance:
                verdict = "REGRESSION"
                failures.append(
                    f"{name}.{label}: {b:g} -> {c:g} "
                    f"(+{100 * (ratio - 1):.1f}% > {100 * tolerance:.0f}%)")
            lines.append(f"{name},{label},{b:g},{c:g},{ratio:.3f},{verdict}")
    for name in sorted(set(cand_wl) - set(base_wl)):
        lines.append(f"{name},-,-,-,-,NEW (not gated)")

    base_tr = baseline.get("training")
    if base_tr is not None:
        cand_tr = candidate.get("training")
        if cand_tr is None:
            failures.append("training: section missing from candidate record")
        else:
            for path, label in TRAINING_METRICS:
                b = _get(base_tr, path)
                c = _get(cand_tr, path)
                if b is None or c is None:
                    failures.append(f"training.{label}: metric missing "
                                    f"(baseline={b}, candidate={c})")
                    continue
                ratio = c / b if b else float("inf") if c else 1.0
                verdict = "OK"
                if ratio > 1.0 + tolerance:
                    verdict = "REGRESSION"
                    failures.append(
                        f"training.{label}: {b:g} -> {c:g} "
                        f"(+{100 * (ratio - 1):.1f}% > {100 * tolerance:.0f}%)")
                lines.append(f"training,{label},{b:g},{c:g},{ratio:.3f},{verdict}")
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--candidate", required=True)
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)

    failures, lines = compare(baseline, candidate, args.tolerance)
    print("workload,metric,baseline,candidate,ratio,verdict")
    for line in lines:
        print(line)
    if failures:
        print(f"\nFAIL — {len(failures)} perf regression(s) "
              f"beyond {100 * args.tolerance:.0f}%:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print(f"\nPASS — no metric regressed beyond {100 * args.tolerance:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
