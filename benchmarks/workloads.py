"""The paper's workload suite (Table 1), rebuilt in JAX and traced to
StitchIR.

Six micro-benchmarks follow the TensorFlow-Examples models the paper used
(logistic, word2vec, bi-rnn, dyn-rnn, perceptron, var-encoder) — each traced
as a full TRAINING step (forward + backward via ``jax.grad``), matching the
paper's setting where all micro-benchmarks are training graphs.  The four
industry models are structural proxies at reduced width: ``nmt`` (attention
encoder-decoder inference), ``multi-interests`` (the paper's Fig. 1 motif:
embedding reductions + small batched GEMMs + elementwise glue),
``rokid``/``aiwriter`` (deep stacks of fine-grained mixed ops).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import Graph
from repro.core.trace import trace_to_graph

RNG = np.random.default_rng(0)


def _f32(*shape, scale=0.1):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# micro-benchmarks (training steps, fwd+bwd)
# ---------------------------------------------------------------------------

def logistic() -> Graph:
    X, y = _f32(256, 784, scale=1.0), RNG.integers(0, 2, 256).astype(np.float32)
    w, b = _f32(784), np.float32(0.0)

    def loss(w, b):
        p = jax.nn.sigmoid(X @ w + b)
        return -jnp.mean(y * jnp.log(p + 1e-7) + (1 - y) * jnp.log1p(-p + 1e-7))

    def step(w, b):
        l, (gw, gb) = jax.value_and_grad(loss, argnums=(0, 1))(w, b)
        return l, w - 0.1 * gw, b - 0.1 * gb

    g, _ = trace_to_graph(step, w, b, name="logistic")
    return g


def word2vec() -> Graph:
    V, D, B = 2048, 128, 256
    emb, out = _f32(V, D), _f32(V, D)
    ctx = RNG.integers(0, V, B)
    pos = RNG.integers(0, V, B)
    neg = RNG.integers(0, V, (B, 5))

    def loss(emb, out):
        h = emb[ctx]                                   # (B, D)
        s_pos = jnp.sum(h * out[pos], axis=-1)
        s_neg = jnp.einsum("bd,bkd->bk", h, out[neg])
        return -jnp.mean(jax.nn.log_sigmoid(s_pos)) \
            - jnp.mean(jax.nn.log_sigmoid(-s_neg))

    def step(emb, out):
        l, (ge, go) = jax.value_and_grad(loss, argnums=(0, 1))(emb, out)
        return l, emb - 0.1 * ge, out - 0.1 * go

    g, _ = trace_to_graph(step, emb, out, name="word2vec")
    return g


def _rnn_cell(h, x, wx, wh, b):
    return jnp.tanh(x @ wx + h @ wh + b)


def bi_rnn() -> Graph:
    B, T, D, H = 64, 8, 64, 128
    xs = _f32(T, B, D, scale=1.0)
    y = RNG.integers(0, 10, B)
    params = (_f32(D, H), _f32(H, H), _f32(H), _f32(D, H), _f32(H, H), _f32(H),
              _f32(2 * H, 10))

    def loss(*p):
        wxf, whf, bf, wxb, whb, bb, wo = p
        hf = jnp.zeros((B, H))
        hb = jnp.zeros((B, H))
        for t in range(T):                     # unrolled: fine-grained ops
            hf = _rnn_cell(hf, xs[t], wxf, whf, bf)
            hb = _rnn_cell(hb, xs[T - 1 - t], wxb, whb, bb)
        logits = jnp.concatenate([hf, hb], -1) @ wo
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(B), y])

    def step(*p):
        l, gs = jax.value_and_grad(loss, argnums=tuple(range(7)))(*p)
        return (l,) + tuple(pi - 0.1 * gi for pi, gi in zip(p, gs))

    g, _ = trace_to_graph(step, *params, name="bi_rnn")
    return g


def dyn_rnn() -> Graph:
    B, T, D, H = 64, 12, 64, 128
    xs = _f32(T, B, D, scale=1.0)
    lens = RNG.integers(1, T + 1, B)
    y = RNG.integers(0, 10, B)
    params = (_f32(D, H), _f32(H, H), _f32(H), _f32(H, 10))

    def loss(wx, wh, b, wo):
        h = jnp.zeros((B, H))
        out = jnp.zeros((B, H))
        for t in range(T):                     # dynamic masking per step
            nh = _rnn_cell(h, xs[t], wx, wh, b)
            mask = (t < lens)[:, None].astype(jnp.float32)
            h = mask * nh + (1 - mask) * h
            out = jnp.where(t + 1 == lens[:, None], h, out)
        logits = out @ wo
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(B), y])

    def step(*p):
        l, gs = jax.value_and_grad(loss, argnums=(0, 1, 2, 3))(*p)
        return (l,) + tuple(pi - 0.1 * gi for pi, gi in zip(p, gs))

    g, _ = trace_to_graph(step, *params, name="dyn_rnn")
    return g


def perceptron() -> Graph:
    B, D, H1, H2, C = 256, 784, 256, 256, 10
    X = _f32(B, D, scale=1.0)
    y = RNG.integers(0, C, B)
    params = (_f32(D, H1), _f32(H1), _f32(H1, H2), _f32(H2), _f32(H2, C), _f32(C))

    def loss(w1, b1, w2, b2, w3, b3):
        h = jax.nn.relu(X @ w1 + b1)
        h = jax.nn.relu(h @ w2 + b2)
        logits = h @ w3 + b3
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(B), y])

    def step(*p):
        l, gs = jax.value_and_grad(loss, argnums=tuple(range(6)))(*p)
        return (l,) + tuple(pi - 0.1 * gi for pi, gi in zip(p, gs))

    g, _ = trace_to_graph(step, *params, name="perceptron")
    return g


def var_encoder() -> Graph:
    B, D, H, Z = 128, 784, 256, 32
    X = _f32(B, D, scale=1.0)
    eps = _f32(B, Z, scale=1.0)
    params = (_f32(D, H), _f32(H), _f32(H, Z), _f32(H, Z),
              _f32(Z, H), _f32(H), _f32(H, D), _f32(D))

    def loss(we, be, wmu, wlv, wd, bd, wo, bo):
        h = jnp.tanh(X @ we + be)
        mu, logvar = h @ wmu, h @ wlv
        z = mu + jnp.exp(0.5 * logvar) * eps
        hd = jnp.tanh(z @ wd + bd)
        xr = jax.nn.sigmoid(hd @ wo + bo)
        recon = -jnp.mean(jnp.sum(
            X * jnp.log(xr + 1e-7) + (1 - X) * jnp.log1p(-xr + 1e-7), -1))
        kl = -0.5 * jnp.mean(jnp.sum(1 + logvar - mu**2 - jnp.exp(logvar), -1))
        return recon + kl

    def step(*p):
        l, gs = jax.value_and_grad(loss, argnums=tuple(range(8)))(*p)
        return (l,) + tuple(pi - 0.1 * gi for pi, gi in zip(p, gs))

    g, _ = trace_to_graph(step, *params, name="var_encoder")
    return g


# ---------------------------------------------------------------------------
# industry-model proxies
# ---------------------------------------------------------------------------

def nmt() -> Graph:
    """Attention decoder step (inference): the paper's nmt is inference."""
    B, S, D, H = 32, 64, 256, 8
    dh = D // H
    enc = _f32(B, S, D, scale=1.0)
    x = _f32(B, D, scale=1.0)
    wq, wk, wv, wo = _f32(D, D), _f32(D, D), _f32(D, D), _f32(D, D)
    w1, w2 = _f32(D, 4 * D), _f32(4 * D, D)
    gamma = _f32(D, scale=1.0)

    def decode(x):
        q = (x @ wq).reshape(B, H, dh)
        k = (enc @ wk).reshape(B, S, H, dh)
        v = (enc @ wv).reshape(B, S, H, dh)
        att = jnp.einsum("bhd,bshd->bhs", q, k) / np.sqrt(dh)
        p = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhs,bshd->bhd", p, v).reshape(B, D)
        h = ctx @ wo + x
        h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6) * gamma
        return h + jax.nn.relu(h @ w1) @ w2

    g, _ = trace_to_graph(decode, x, name="nmt")
    return g


def multi_interests() -> Graph:
    """The paper's Fig. 1 motif: two small/batched GEMMs bracketing a large
    intermediate, stitched with reductions and elementwise glue."""
    B, M, K, N = 256, 32, 16, 64
    u = _f32(B, M, K, scale=1.0)
    w = _f32(B, K, N, scale=1.0)
    t = _f32(B, M, N, scale=1.0)

    def f(u, w, t):
        dot_1 = jnp.einsum("bmk,bkn->bmn", u, w)          # small -> large
        e = jnp.exp(dot_1 - jnp.max(dot_1, axis=-1, keepdims=True))
        sm = e / jnp.sum(e, axis=-1, keepdims=True)
        mul = sm * jax.nn.sigmoid(t)
        red = jnp.sum(mul, axis=1)                        # (B, N)
        dot_2 = jnp.einsum("bmn,bn->bm", dot_1, red)      # large -> small
        div = dot_2 / (1.0 + jnp.sum(jnp.abs(dot_2), -1, keepdims=True))
        log_1 = jnp.log1p(jnp.abs(red))
        sub = jnp.tanh(dot_2) - 0.5
        return div, log_1, mul * 2.0, sub

    g, _ = trace_to_graph(f, u, w, t, name="multi_interests")
    return g


def _deep_stack(name: str, depth: int, B=128, D=256) -> Graph:
    """rokid/aiwriter proxy: long chains of fine-grained mixed ops."""
    x = _f32(B, D, scale=1.0)
    ws = [_f32(D, D) for _ in range(4)]
    gamma = _f32(D, scale=1.0)

    def f(x):
        h = x
        for i in range(depth):
            h = h @ ws[i % 4]
            h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
            h = jax.nn.silu(h) + 0.1 * jnp.tanh(h) * gamma
            if i % 3 == 2:
                h = h - jnp.mean(h, axis=0, keepdims=True)
        return jnp.sum(h * h)

    def step(x):
        l, gx = jax.value_and_grad(f)(x)
        return l, x - 0.1 * gx

    g, _ = trace_to_graph(step, x, name=name)
    return g


def rokid() -> Graph:
    return _deep_stack("rokid", depth=12)


def aiwriter() -> Graph:
    return _deep_stack("aiwriter", depth=20)


WORKLOADS = {
    "nmt": nmt,
    "multi-interests": multi_interests,
    "rokid": rokid,
    "aiwriter": aiwriter,
    "logistic": logistic,
    "word2vec": word2vec,
    "bi-rnn": bi_rnn,
    "dyn-rnn": dyn_rnn,
    "perceptron": perceptron,
    "var-encoder": var_encoder,
}


def build_all() -> dict[str, Graph]:
    return {name: fn() for name, fn in WORKLOADS.items()}
