"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--hw V100|TPU_V5E] [--quick]

Sections:
  Table 2  workload characteristics (graph size, kernels, avg kernel us,
           memory-intensive time ratio)
  Table 3  kernel compression + modeled speedup (TF / XLA / FusionStitching)
  Fig. 6   fusion-pattern class composition
  Table 4  scratch (VMEM/shared) statistics incl. Alg.4 alloc/req
  Cache    StitchCache cold vs warm compile times (same-graph recompile and
           record replay onto a freshly built isomorphic graph)
  Verify   static-verifier wall clock per workload (verify overhead vs the
           cold compile) and offline verify_compiled findings (gated at 0)
  Serving  continuous-batching vs static-batch tokens/sec on a mixed-length
           request stream (warmed; measures scheduling, not compiles)
  Training stitched train step vs plain jit: backward-graph kernel
           compression (off/xla/stitch) and the packed multi-tensor
           AdamW+clip update collapsing to a single kernel
  Sharding mesh-aware stitched train step under shard_map on the forced
           multi-device host platform: per-shard backward/packed kernel
           counts, trajectory agreement with the single-device stitched
           run, mesh-keyed cache entries
  Compute  compute-intensive stitching: transformer block (q/k/v GEMMs +
           Pallas flash attention + gelu MLP) -> ONE stitched kernel, plus
           the serving decode step's plan kernel counts
  Packing  horizontal FFD packing (§4.2) on a wide-expert MoE block:
           stitched-kernel count packed vs unpacked, packs formed, modeled
           + measured step time
  Perf     measured interpret-mode execution of stitched kernels vs oracle
           on the classic patterns (CPU wall time, correctness evidence)

Output: ``name,us_per_call,derived`` CSV rows per section.  With
``--json PATH`` a machine-readable BENCH record (per-workload kernel
counts, modeled step times, cache cold/warm compile times) is also written
— the start of the perf trajectory across PRs.
"""

from __future__ import annotations

# The Sharding section needs a multi-device host platform; force 8 CPU
# devices before the first jax import so the record is identical locally
# and in CI.  An operator-provided count via XLA_FLAGS is respected.  The
# modeled/kernel-count metrics of the other sections are device-count
# independent.
from repro.launch.hostenv import force_host_devices

force_host_devices(8)

import argparse
import json
import sys
import time

import numpy as np

from repro.core import (
    CostModel, FusionPattern, OpKind, StitchCompiler, TPU_V5E, V100,
)

from .workloads import build_all


def table2(graphs, cost: CostModel):
    print("\n# Table 2 — workload characteristics")
    print("name,graph_size,kernels_tf,avg_kernel_us,mem_ratio")
    for name, g in graphs.items():
        nodes = g.compute_nodes()
        times = [cost.kernel_time(g, n.name) + cost.hw.launch_latency
                 for n in nodes]
        mem_time = sum(t for n, t in zip(nodes, times) if n.is_memory_intensive())
        total = sum(times)
        print(f"{name},{len(g.nodes)},{len(nodes)},"
              f"{1e6 * total / max(len(nodes), 1):.2f},"
              f"{100 * mem_time / max(total, 1e-12):.0f}%")


def table3(graphs, cost: CostModel):
    print("\n# Table 3 — kernel compression & modeled speedup "
          f"(hw={cost.hw.name}, phi={cost.hw.launch_latency * 1e6:.0f}us)")
    print("name,xla/tf-kernel,fs/tf-kernel,fs/xla-kernel,"
          "xla/tf-perf,fs/tf-perf,fs/xla-perf")
    ratios_k, ratios_p = [], []
    results = {}
    for name, g in graphs.items():
        stats = {}
        for mode in ("off", "xla", "stitch"):
            cg = StitchCompiler(hw=cost.hw, mode=mode, use_pallas=False).compile(g)
            stats[mode] = cg.stats
        k_tf, k_xla, k_fs = (stats[m].n_kernels for m in ("off", "xla", "stitch"))
        t_tf, t_xla, t_fs = (stats[m].modeled_time for m in ("off", "xla", "stitch"))
        row = (k_tf / k_xla, k_tf / k_fs, k_xla / k_fs,
               t_tf / t_xla, t_tf / t_fs, t_xla / t_fs)
        ratios_k.append(row[2])
        ratios_p.append(row[5])
        results[name] = {
            "graph_size": len(g.nodes),
            "kernels": {"off": k_tf, "xla": k_xla, "stitch": k_fs},
            "modeled_time_s": {"off": t_tf, "xla": t_xla, "stitch": t_fs},
            "compression_fs_over_xla": row[2],
            "speedup_fs_over_xla": row[5],
        }
        print(f"{name},{row[0]:.2f},{row[1]:.2f},{row[2]:.2f},"
              f"{row[3]:.2f},{row[4]:.2f},{row[5]:.2f}")
    gk = float(np.exp(np.mean(np.log(ratios_k))))
    gp = float(np.exp(np.mean(np.log(ratios_p))))
    print(f"GEOMEAN,fs/xla-kernel={gk:.2f},fs/xla-perf={gp:.2f}")
    print("# paper: fs/xla kernel compression 2.9x avg (1.18-10.4x); "
          "fs/xla speedup 1.4x geomean (1.25-1.85x)")
    return results


def fig6(graphs):
    print("\n# Fig. 6 — fusion pattern composition (stitch mode)")
    print("name,elemwise,reduction,gemm")
    for name, g in graphs.items():
        cg = StitchCompiler(mode="stitch", use_pallas=False).compile(g)
        pc = cg.stats.pattern_classes
        tot = max(sum(pc.values()), 1)
        print(f"{name},{pc.get('elemwise', 0) / tot:.2f},"
              f"{pc.get('reduction', 0) / tot:.2f},{pc.get('gemm', 0) / tot:.2f}")


def fig7_fig8(graphs, cost: CostModel):
    """Fig. 7: accumulated kernel time normalized to the XLA baseline.
    Fig. 8: stitch-mode kernel-time breakdown by pattern class."""
    print("\n# Fig. 7 — accumulated kernel time, normalized to xla "
          "(launch overhead excluded)")
    print("name,fs/xla_kernel_time")
    reductions = []
    breakdowns = {}
    for name, g in graphs.items():
        times = {}
        classes = {"elemwise": 0.0, "reduction": 0.0, "gemm": 0.0}
        for mode in ("xla", "stitch"):
            cg = StitchCompiler(hw=cost.hw, mode=mode, use_pallas=False).compile(g)
            total = 0.0
            for grp in cg.groups:
                if len(grp.members) == 1:
                    (m,) = grp.members
                    t = cost.kernel_time(g, m)
                    cls = ("gemm" if g[m].is_compute_intensive() else
                           "reduction" if g[m].kind is OpKind.REDUCTION else
                           "elemwise")
                else:
                    p = FusionPattern(g, grp.members)
                    t = cost.fused_time(p)
                    cls = p.pattern_class
                total += t
                if mode == "stitch":
                    classes[cls] = classes.get(cls, 0.0) + t
            times[mode] = total
        ratio = times["stitch"] / times["xla"]
        reductions.append(ratio)
        breakdowns[name] = classes
        print(f"{name},{ratio:.2f}")
    avg = float(np.mean(reductions))
    print(f"AVERAGE,{avg:.2f}")
    print(f"# paper Fig.7: ~39% kernel-time reduction vs xla (ratio ~0.61); "
          f"ours {100 * (1 - avg):.0f}% ({avg:.2f})")

    print("\n# Fig. 8 — stitch kernel-time breakdown by pattern class")
    print("name,elemwise,reduction,gemm")
    for name, cls in breakdowns.items():
        tot = max(sum(cls.values()), 1e-12)
        print(f"{name},{cls['elemwise'] / tot:.2f},{cls['reduction'] / tot:.2f},"
              f"{cls['gemm'] / tot:.2f}")


def table4(graphs, cost: CostModel):
    print("\n# Table 4 — scratch-memory statistics (Alg. 4)")
    print("name,pt_ratio,shd_avg_kb,max_shd_kb,alloc_over_req")
    from repro.core import ScratchAllocator
    for name, g in graphs.items():
        cg = StitchCompiler(hw=cost.hw, mode="stitch", use_pallas=False).compile(g)
        chosen = [FusionPattern(g, grp.members) for grp in cg.groups
                  if len(grp.members) > 1]
        n_with = 0
        allocs, reqs = [], []
        for p in chosen:
            req = cost.scratch_request(p)
            if not req:
                continue
            n_with += 1
            plan = ScratchAllocator(g).allocate(req)
            allocs.append(plan.allocated)
            reqs.append(plan.requested)
        if not chosen:
            continue
        pt = n_with / len(chosen)
        avg = np.mean(allocs) / 1024 if allocs else 0.0
        mx = max(allocs) / 1024 if allocs else 0.0
        aor = (sum(allocs) / sum(reqs)) if reqs else 1.0
        print(f"{name},{pt:.2f},{avg:.1f},{mx:.1f},{aor:.2f}")


def cache_timing(graphs, cost: CostModel, quick: bool) -> dict:
    """StitchCache amortization: cold (full pattern-gen + ILP + tuning)
    vs warm (same-graph recompile through the live memo) vs replay (plan
    record applied to a freshly built isomorphic graph)."""
    from repro.cache import StitchCache
    from .workloads import build_all

    print("\n# Cache — StitchCache cold/warm compile times")
    print("name,cold_ms,warm_ms,replay_ms,warm_speedup,replay_speedup")
    rebuilt = {} if quick else build_all()
    out = {}
    warm_ratios = []
    for name, g in graphs.items():
        cache = StitchCache()
        comp = StitchCompiler(hw=cost.hw, mode="stitch", cache=cache)
        t0 = time.perf_counter()
        comp.compile(g)
        cold = time.perf_counter() - t0
        comp.compile(g)                    # absorb one-time warm-path setup
        t0 = time.perf_counter()
        warm_cg = comp.compile(g)
        warm = time.perf_counter() - t0
        assert warm_cg.stats.cache_status == "hit"
        replay = None
        if name in rebuilt:
            t0 = time.perf_counter()
            replay_cg = comp.compile(rebuilt[name])
            replay = time.perf_counter() - t0
            assert replay_cg.stats.cache_status == "hit"
        warm_ratios.append(cold / max(warm, 1e-9))
        out[name] = {
            "cold_compile_s": cold,
            "warm_compile_s": warm,
            "replay_compile_s": replay,
            "warm_speedup": cold / max(warm, 1e-9),
            "replay_speedup": cold / max(replay, 1e-9) if replay else None,
        }
        replay_ms = "" if replay is None else f"{replay * 1e3:.2f}"
        replay_x = "" if replay is None else f"{cold / max(replay, 1e-9):.0f}x"
        print(f"{name},{cold * 1e3:.2f},{warm * 1e3:.3f},{replay_ms},"
              f"{cold / max(warm, 1e-9):.0f}x,{replay_x}")
    geo = float(np.exp(np.mean(np.log(warm_ratios))))
    print(f"GEOMEAN,warm_speedup={geo:.0f}x")
    return {"per_workload": out, "warm_speedup_geomean": geo}


def verify_section(graphs, cost: CostModel, cache: dict) -> dict:
    """Static-verifier cost & cleanliness: per-workload verify="plans"
    wall-clock (the in-compile IR/plan audit) against the cache section's
    cold compile, plus a full offline :func:`verify_compiled` sweep.  Both
    gated: findings must stay at zero and the verify overhead must stay a
    rounding error next to pattern-gen + ILP + tuning."""
    from repro.analysis import errors, verify_compiled

    print("\n# Verify — static verification wall-clock & findings")
    print("name,verify_ms,overhead_vs_cold,errors")
    out = {}
    findings_total = 0
    fracs = []
    for name, g in graphs.items():
        comp = StitchCompiler(hw=cost.hw, mode="stitch", use_pallas=False,
                              verify="full")
        cg = comp.compile(g)
        budget = comp.gen_cfg.scratch_budget
        if budget is None:
            budget = comp.hw.onchip_budget
        # best-of-3: the overhead fraction is gated (max:0.05) and the full
        # IR+plan audit is milliseconds — one descheduled sample must not
        # fail the build
        best, fs = None, []
        for _ in range(3):
            t0 = time.perf_counter()
            fs = verify_compiled(cg, scratch_budget=budget, cost=comp.cost)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        n_err = len(errors(fs))
        findings_total += n_err
        cold = cache["per_workload"].get(name, {}).get("cold_compile_s")
        frac = best / cold if cold else None
        if frac is not None:
            fracs.append(frac)
        out[name] = {"verify_s": best, "overhead_frac": frac, "errors": n_err}
        frac_str = f"{100 * frac:.2f}%" if frac is not None else "-"
        print(f"{name},{best * 1e3:.2f},{frac_str},{n_err}")
    mx = max(fracs) if fracs else 0.0
    print(f"MAX_OVERHEAD,{100 * mx:.2f}%,findings_total={findings_total}")
    return {"per_workload": out, "findings_total": findings_total,
            "max_overhead_frac": mx}


def serving(quick: bool) -> dict:
    """Continuous vs static batching on a mixed-length request stream.

    Same tiny model, same ragged requests, same slot count; static lock-step
    pads every group to its worst-case prompt and decodes to its worst-case
    token budget, the continuous scheduler evicts/refills per request.  Both
    paths are warmed (compiled) before timing, so the ratio measures
    scheduling, not XLA compiles."""
    import jax
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig

    print("\n# Serving — continuous vs static batching (mixed-length stream)")
    print("name,us_per_call,derived")
    cfg = get_reduced("qwen3_1_7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    slots, max_len = 4, 64
    n_req = 8 if quick else 16
    lens = rng.integers(4, 25, n_req)
    news = np.where(np.arange(n_req) % 4 == 3, 24, 6)   # one straggler per 4
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32) for p in lens]

    # one engine per mode, reused across warmup and timed runs — a fresh
    # Engine would re-jit its decode step and the timing would measure XLA
    # compiles instead of scheduling
    eng_static = Engine(model, params, ServeConfig(batch=slots, max_len=max_len))
    eng_cont = Engine(model, params, ServeConfig(batch=slots, max_len=max_len))

    def run_static() -> int:
        tokens = 0
        for g in range(0, n_req, slots):
            group = prompts[g:g + slots]
            glens = [len(p) for p in group]
            rect = np.zeros((slots, max(glens)), np.int32)
            for r, p in enumerate(group):
                rect[r, :len(p)] = p
            eng_static.cfg.max_new_tokens = int(max(news[g:g + slots]))
            eng_static.generate(rect, prompt_lens=glens)
            tokens += int(np.sum(news[g:g + slots]))    # useful tokens only
        return tokens

    def run_continuous() -> int:
        for p, n in zip(prompts, news):
            eng_cont.submit(p, max_new_tokens=int(n))
        fins = eng_cont.drain()
        return sum(len(f.tokens) for f in fins)

    results = {}
    reps = 2 if quick else 3
    for name, fn in (("static", run_static), ("continuous", run_continuous)):
        fn()                                            # warm the compiles
        best = None
        for _ in range(reps):        # best-of-reps: tokens_per_sec is gated
            t0 = time.perf_counter()   # (direction-aware), so damp scheduler
            tokens = fn()              # jitter instead of gating one sample
            dt = time.perf_counter() - t0
            if best is None or dt < best[1]:
                best = (tokens, dt)
        tokens, dt = best
        results[name] = {"tokens": tokens, "seconds": dt,
                         "tokens_per_sec": tokens / max(dt, 1e-9)}
        print(f"serve_{name},{dt / max(tokens, 1) * 1e6:.1f},"
              f"{tokens / max(dt, 1e-9):.1f}tok/s")
    speedup = (results["continuous"]["tokens_per_sec"]
               / max(results["static"]["tokens_per_sec"], 1e-9))
    print(f"SPEEDUP,continuous/static={speedup:.2f}x")
    results["prefix"] = serving_prefix(model, params, cfg, rng, slots,
                                       max_len, n_req)
    return {"n_requests": n_req, "slots": slots,
            "static": results["static"], "continuous": results["continuous"],
            "continuous_over_static": speedup,
            "prefix": results["prefix"]}


def serving_prefix(model, params, cfg, rng, slots: int, max_len: int,
                   n_req: int) -> dict:
    """Prefix-heavy trace (requests drawn from 4 shared prompt templates)
    through paged KV + the content-hashed prefix cache, with both decode
    and the bucketed prefills served through stitch().  The gated metrics
    are deterministic: the prefix-cache hit rate must be nonzero (repeated
    prompts actually skip prefill) and every landed per-bucket prefill
    plan must report stitched kernels."""
    from repro.cache import CompilationService
    from repro.serve import Engine, ServeConfig

    svc = CompilationService()
    eng = Engine(model, params,
                 ServeConfig(batch=slots, max_len=max_len, page_size=8,
                             prefix_cache=True, stitch_execute=True),
                 stitch_service=svc)
    pool = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
            for p in rng.integers(8, 25, 4)]
    reqs = [pool[i] for i in rng.integers(0, len(pool), n_req)]

    def run() -> int:
        for p in reqs:
            eng.submit(p, max_new_tokens=6)
        return sum(len(f.tokens) for f in eng.drain())

    run()                                               # warm the compiles
    t0 = time.perf_counter()
    tokens = run()
    dt = time.perf_counter() - t0
    # join background stitch compiles so per-bucket kernel counts are
    # deterministic before the record is written
    pending = eng.land_plans(timeout=120.0)
    rep = eng.report()
    plans = {k: (v["plan"] or {}).get("n_kernels", 0)
             for k, v in rep["prefill"]["plans"].items()}
    px = rep["prefix_cache"]
    print(f"serve_prefix,{dt / max(tokens, 1) * 1e6:.1f},"
          f"hit_rate={px['hit_rate']:.2f}")
    print(f"serve_prefix_prefill_kernels,,{sum(plans.values())} "
          f"across {len(plans)} bucket(s), {pending} plan(s) pending")
    return {"tokens": tokens, "seconds": dt,
            "tokens_per_sec": tokens / max(dt, 1e-9),
            "prefix_cache": {"hit_rate": px["hit_rate"], "hits": px["hits"],
                             "misses": px["misses"]},
            "prefill": {"n_kernels": sum(plans.values()),
                        "buckets": len(plans), "plans": plans,
                        "pending": pending},
            "kv": {"peak_used": rep["kv"]["peak_used"],
                   "page_size": rep["kv"]["page_size"]}}


def training(quick: bool) -> dict:
    """Stitched training step vs plain jit: backward-graph kernel compression
    (off/xla/stitch) and the packed multi-tensor AdamW+clip update collapsing
    to one kernel, plus wall-clock step times (CPU interpret mode for the
    stitched path — overhead expected; the *deterministic* metrics are the
    kernel counts and modeled times the regression gate consumes)."""
    import jax
    import jax.numpy as jnp
    from repro.cache import CompilationService
    from repro.configs import get_reduced
    from repro.core import StitchCompiler
    from repro.models import build_model
    from repro.optim import AdamWConfig
    from repro.train import StitchedTrainStep, init_state, make_train_step

    print("\n# Training — stitched backward + packed AdamW vs jit step")
    print("name,us_per_call,derived")
    cfg = get_reduced("qwen3_1_7b")
    model = build_model(cfg)
    opt_cfg = AdamWConfig(warmup_steps=5, total_steps=100)
    B, S = 2, 16

    def batch(i):
        r = np.random.default_rng(1000 + i)
        return {"tokens": jnp.asarray(r.integers(0, cfg.vocab, (B, S)), jnp.int32),
                "labels": jnp.asarray(r.integers(0, cfg.vocab, (B, S)), jnp.int32)}

    state_jit = init_state(model, jax.random.PRNGKey(0))
    state_st = init_state(model, jax.random.PRNGKey(0))

    jit_step = jax.jit(make_train_step(model, opt_cfg))
    svc = CompilationService()
    st_step = StitchedTrainStep(model, opt_cfg, service=svc)

    # warm both paths; let the background stitch compiles land and upgrade
    state_jit, _ = jit_step(state_jit, batch(0))
    state_st, _ = st_step(state_st, batch(0))
    st_step.wait(timeout=300.0)
    state_st, _ = st_step(state_st, batch(1))   # poll -> upgraded artifacts

    reps = 2 if quick else 5
    times = {}
    for name, fn, s0 in (("jit", jit_step, state_jit),
                         ("stitched", st_step, state_st)):
        s = s0
        t0 = time.perf_counter()
        for i in range(reps):
            s, m = fn(s, batch(10 + i))
            jax.block_until_ready(m["loss"])
        times[name] = (time.perf_counter() - t0) / reps
        print(f"train_step_{name},{times[name] * 1e6:.0f},"
              + ("baseline" if name == "jit" else "interpret-mode-overhead-expected"))

    rep = st_step.report()
    statuses = {"grad": rep["grad"]["status"],
                "optimizer": rep["optimizer"]["status"],
                "fallback_steps": rep["fallback_steps"]}
    grad_graph = st_step._grad.graph
    if (grad_graph is None or rep["grad"].get("plan") is None
            or st_step._packed is None
            or rep["optimizer"].get("plan") is None):
        # trace/compile failure: the step served the jit fallback.  Record
        # the statuses but omit the gated metrics — check_regression then
        # reports "metric missing" (a clear gated failure, not a crash here).
        print(f"# training: stitched step unavailable ({statuses}); "
              "gated metrics omitted")
        return {"batch": B, "seq": S,
                "step_time_s": {"jit": times["jit"],
                                "stitched": times["stitched"]},
                "status": statuses}
    grad_kernels = {}
    grad_times = {}
    for mode in ("off", "xla"):
        cg = StitchCompiler(mode=mode, use_pallas=False).compile(grad_graph)
        grad_kernels[mode] = cg.stats.n_kernels
        grad_times[mode] = cg.stats.modeled_time
    grad_plan = rep["grad"]["plan"]
    grad_kernels["stitch"] = grad_plan["n_kernels"]
    grad_times["stitch"] = grad_plan["modeled_time"]

    packed = st_step._packed
    opt_graph = packed.graph
    cg_off = StitchCompiler(mode="off", use_pallas=False).compile(opt_graph)
    opt_plan = rep["optimizer"]["plan"]
    print(f"train_grad_kernels,,off={grad_kernels['off']} "
          f"xla={grad_kernels['xla']} stitch={grad_kernels['stitch']}")
    print(f"train_packed_update,,{cg_off.stats.n_kernels} ops -> "
          f"{opt_plan['n_kernels']} packed kernel(s)")
    print(f"# stitched upgrade: grad={rep['grad']['status']} "
          f"optimizer={rep['optimizer']['status']} "
          f"fallback_steps={rep['fallback_steps']}")

    return {
        "batch": B, "seq": S,
        "step_time_s": {"jit": times["jit"], "stitched": times["stitched"]},
        "grad": {
            "n_ops": grad_plan["n_ops"],
            "kernels": {**grad_kernels},
            "modeled_time_s": {**grad_times},
        },
        "packed_update": {
            "n_ops": opt_plan["n_ops"],
            "kernels": {"off": cg_off.stats.n_kernels,
                        "stitch": opt_plan["n_kernels"]},
            "modeled_time_s": {"off": cg_off.stats.modeled_time,
                               "stitch": opt_plan["modeled_time"]},
        },
        "status": statuses,
    }


def sharding(quick: bool) -> dict | None:
    """Mesh-aware stitched training under shard_map (forced 8-device host):
    per-shard backward + packed-update plans at shard-local shapes,
    trajectory agreement with the single-device stitched run, and the
    mesh-keyed cache behavior (one entry per placement)."""
    import jax
    import jax.numpy as jnp
    from repro.cache import CompilationService, StitchCache
    from repro.configs import get_reduced
    from repro.core import StitchCompiler
    from repro.models import build_model
    from repro.optim import AdamWConfig
    from repro.train import StitchedTrainStep, init_state

    n = len(jax.devices())
    print("\n# Sharding — shard_map stitched train step (per-shard graphs)")
    if n < 2:
        print("# skipped: single-device host "
              "(set --xla_force_host_platform_device_count)")
        return None
    mesh = jax.make_mesh((n // 2, 2), ("data", "model"))
    cfg = get_reduced("qwen3_1_7b")
    model = build_model(cfg)
    opt_cfg = AdamWConfig(warmup_steps=5, total_steps=100)
    B, S = n, 8

    def batch(i):
        r = np.random.default_rng(7000 + i)
        return {"tokens": jnp.asarray(r.integers(0, cfg.vocab, (B, S)), jnp.int32),
                "labels": jnp.asarray(r.integers(0, cfg.vocab, (B, S)), jnp.int32)}

    # max_background=0 makes the upgrade deterministic: step 0 runs the XLA
    # fallbacks, then the stitched plans are landed synchronously
    svc_sh = CompilationService(max_background=0)
    st_sh = StitchedTrainStep(model, opt_cfg, service=svc_sh, mesh=mesh)
    svc_1d = CompilationService(max_background=0)
    st_1d = StitchedTrainStep(model, opt_cfg, service=svc_1d)

    s_sh = jax.device_put(init_state(model, jax.random.PRNGKey(0)),
                          st_sh.state_shardings())
    s_1d = init_state(model, jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    s_sh, m_sh = st_sh(s_sh, batch(0))
    s_1d, m_1d = st_1d(s_1d, batch(0))
    deltas = [abs(float(m_sh["loss"]) - float(m_1d["loss"]))]
    for st, svc in ((st_sh, svc_sh), (st_1d, svc_1d)):
        for phase in (st._grad, st._packed):
            svc.compiler("stitch", phase.placement).compile(
                phase.graph, bypass_cache_lookup=True)
    steps = 2 if quick else 4
    for i in range(1, steps):
        s_sh, m_sh = st_sh(s_sh, batch(i))
        s_1d, m_1d = st_1d(s_1d, batch(i))
        deltas.append(abs(float(m_sh["loss"]) - float(m_1d["loss"])))
    dt = time.perf_counter() - t0

    grad_plan = st_sh._grad.plan_stats()
    packed_plan = st_sh._packed.report().get("plan") or {}
    off = StitchCompiler(mode="off", use_pallas=False).compile(st_sh._grad.graph)

    # mesh-keyed entries: the same graph compiled under two placements makes
    # two distinct cache entries (neither shadows the other)
    entries_cache = StitchCache()
    for placement in (st_sh._grad.placement, ""):
        StitchCompiler(mode="stitch", use_pallas=False, cache=entries_cache,
                       placement=placement).compile(st_sh._packed.graph)
    mesh_keyed_entries = len(entries_cache.store.memory)

    print(f"shard_grad_kernels,,off={off.stats.n_kernels} "
          f"stitch={grad_plan['n_kernels']} (per-shard, "
          f"mesh={dict(mesh.shape)})")
    print(f"shard_packed_update,,{packed_plan.get('n_kernels')} packed "
          f"kernel(s) over TP-local panels")
    print(f"shard_trajectory,,max_loss_delta={max(deltas):.2e} over "
          f"{steps} steps ({dt:.1f}s)")
    print(f"shard_cache,,mesh_keyed_entries={mesh_keyed_entries} "
          f"(same graph, two placements)")
    print(f"# upgrade: grad={st_sh._grad.status} "
          f"optimizer={st_sh._packed.status} "
          f"fallback_steps={st_sh.fallback_steps}")

    return {
        "mesh": {k: int(v) for k, v in mesh.shape.items()},
        "devices": n, "batch": B, "seq": S, "steps": steps,
        "grad_local": {
            "n_ops": grad_plan["n_ops"],
            "kernels": {"off": off.stats.n_kernels,
                        "stitch": grad_plan["n_kernels"]},
            "modeled_time_s": {"off": off.stats.modeled_time,
                               "stitch": grad_plan["modeled_time"]},
        },
        "packed_local": {
            "kernels": {"stitch": packed_plan.get("n_kernels")},
            "modeled_time_s": {"stitch": packed_plan.get("modeled_time")},
        },
        "trajectory": {"max_loss_delta_vs_single_device": max(deltas),
                       "statuses": {"grad": st_sh._grad.status,
                                    "optimizer": st_sh._packed.status,
                                    "fallback_steps": st_sh.fallback_steps}},
        "cache": {"mesh_keyed_entries": mesh_keyed_entries,
                  "per_placement": svc_sh.cache.report().get("per_placement")},
    }


def compute_stitching(quick: bool) -> dict:
    """Kernel-count evidence for compute-intensive stitching: a transformer
    block (rms -> q/k/v GEMMs -> Pallas flash attention -> output GEMM ->
    gelu MLP) compiling to ONE stitched kernel, and the serving decode
    step's plan shrinking with it.  Counts are deterministic — the gate
    holds them exactly (``lower`` direction + liveness), no wall clock."""
    import jax
    import jax.numpy as jnp
    from repro.cache import CompilationService
    from repro.configs import get_reduced
    from repro.exec import stitch
    from repro.kernels.flash_attention import flash_attention
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig

    print("\n# Compute stitching — GEMMs + Pallas attention in one kernel")
    print("name,us_per_call,derived")

    B, S, D, H = 2, 128, 16, 2
    dh, F = D // H, 64
    rng = np.random.default_rng(3)

    def mk(*shape):
        return jnp.asarray(rng.standard_normal(shape) * 0.1, jnp.float32)

    w = dict(wq=mk(D, D), wk=mk(D, D), wv=mk(D, D), wo=mk(D, D),
             w1=mk(D, F), w2=mk(F, D), g1=mk(D), g2=mk(D))
    x = mk(B, S, D)

    def rms(v, gain):
        return v * jax.lax.rsqrt(
            jnp.mean(v * v, axis=-1, keepdims=True) + 1e-6) * gain

    def attn_mlp_block(w, x):
        h = rms(x, w["g1"])
        q = (h @ w["wq"]).reshape(B, S, H, dh)
        k = (h @ w["wk"]).reshape(B, S, H, dh)
        v = (h @ w["wv"]).reshape(B, S, H, dh)
        a = flash_attention(q, k, v, causal=True).reshape(B, S, D)
        x2 = x + a @ w["wo"]
        return x2 + jax.nn.gelu(rms(x2, w["g2"]) @ w["w1"]) @ w["w2"]

    sf = stitch(attn_mlp_block, mode="offline", name="bench_attn_mlp_block")
    sf(w, x)
    plan = sf.report()["plan"] or {}
    block = {"n_ops": plan.get("n_ops"), "n_kernels": plan.get("n_kernels"),
             "pallas_groups": plan.get("pallas_groups")}
    print(f"block_fn_kernels,,{block['n_ops']}->{block['n_kernels']} "
          f"pallas={block['pallas_groups']}")

    # the same admission rules through serving: decode-step plan counts
    cfg = get_reduced("qwen3_1_7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    svc = CompilationService()
    eng = Engine(model, params,
                 ServeConfig(batch=2, max_len=32, stitch_execute=True),
                 stitch_service=svc)
    for p in (rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
              for n in (6, 11)):
        eng.submit(p, max_new_tokens=4)
    eng.drain()
    pending = eng.land_plans(timeout=120.0)
    dplan = eng.report()["decode"]["plan"] or {}
    decode = {"n_ops": dplan.get("n_ops"), "n_kernels": dplan.get("n_kernels"),
              "pallas_groups": dplan.get("pallas_groups")}
    print(f"decode_step_kernels,,{decode['n_ops']}->{decode['n_kernels']} "
          f"pallas={decode['pallas_groups']}, {pending} plan(s) pending")
    return {"block_fn": block, "decode": decode}


def packing(quick: bool) -> dict:
    """Horizontal FFD packing on a wide-expert MoE block: the per-expert
    FFN chains are independent subgraphs, so the unpacked planner leaves
    them as per-expert kernel launches while the packer bins them into
    shared stitched kernels (paper §4.2).  The gated metrics are
    deterministic — packed kernel count (lower) and packs formed
    (positive); the measured interpret-mode step time is reported for the
    trajectory, not gated."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.core import StitchCompiler
    from repro.core.fusiongen import GenConfig
    from repro.core.trace import trace_to_graph
    from repro.models import build_model

    print("\n# Packing — horizontal FFD packs (MoE block, packed vs unpacked)")
    print("name,us_per_call,derived")
    # wide experts: each per-expert chain is register-feasible alone but the
    # dependence-connected monolith is not, so packing is the only cover
    # that shares launches (d_expert at the 2 MiB budget's edge)
    cfg = get_reduced("qwen2_moe_a2_7b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, n_experts=16, top_k=2, d_expert=8192, n_shared=0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lp = model.layer_params(params, 0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)) * 0.1, cfg.dtype)
    g, names = trace_to_graph(model.block_fn, lp, x, name="moe_block")
    env = dict(zip(names, jax.tree_util.tree_leaves((lp, x))))

    reps = 1 if quick else 3
    out: dict = {}
    for key, pack in (("packed", True), ("unpacked", False)):
        comp = StitchCompiler(mode="stitch",
                              gen_cfg=GenConfig(pack_patterns=pack))
        art = comp.compile(g, bypass_cache_lookup=True)
        st = art.stats
        jax.block_until_ready(list(art(env).values()))   # warm
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(list(art(env).values()))
        dt = (time.perf_counter() - t0) / reps
        out[key] = {
            "n_ops": st.n_ops,
            "n_kernels": st.n_kernels,
            "pallas_groups": st.pallas_groups,
            "packs": st.packs,
            "packed_subgraphs": st.packed_subgraphs,
            "modeled_time_s": st.modeled_time,
            "measured_step_s": dt,
        }
        print(f"moe_block_{key},{dt * 1e6:.0f},"
              f"{st.n_ops}->{st.n_kernels} kernels packs={st.packs} "
              f"modeled={st.modeled_time * 1e6:.1f}us")
    red = out["unpacked"]["n_kernels"] / max(out["packed"]["n_kernels"], 1)
    print(f"PACKING,kernel_reduction={red:.2f}x,"
          f"{out['packed']['packed_subgraphs']} subgraphs in "
          f"{out['packed']['packs']} pack(s)")
    return {
        "config": {"n_experts": cfg.moe.n_experts, "top_k": cfg.moe.top_k,
                   "d_expert": cfg.moe.d_expert, "d_model": cfg.d_model},
        "packed": out["packed"],
        "unpacked": out["unpacked"],
        "kernel_reduction": red,
    }


def perf_measured(quick: bool) -> dict:
    """Wall-clock interpret-mode stitched kernels vs unfused jnp on the
    canonical patterns — correctness + relative-ordering evidence — plus
    the obs kernel timer's measured-vs-modeled record for one stitched
    executable.  Returns the BENCH record's ``measured`` section; the
    regression gate checks the section *exists* (schema), the values stay
    ungated (interpret-mode wall clock is too noisy to gate)."""
    print("\n# Perf — measured (CPU interpret mode; relative ordering only)")
    print("name,us_per_call,derived")
    import jax
    from repro.kernels import ref
    from repro.kernels.norms import rmsnorm as k_rmsnorm
    from repro.kernels.softmax import softmax as k_softmax

    rng = np.random.default_rng(0)
    x = rng.standard_normal((2048, 1024)).astype(np.float32)
    g = rng.standard_normal(1024).astype(np.float32)
    reps = 3 if quick else 10
    out: dict = {}

    def timeit(fn, *args):
        fn(*args)
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / reps * 1e6

    unfused_rms = jax.jit(lambda x, g: ref.rmsnorm(x, g))
    t_ref = timeit(unfused_rms, x, g)
    t_pal = timeit(lambda x, g: k_rmsnorm(x, g), x, g)
    print(f"rmsnorm_oracle_jit,{t_ref:.1f},baseline")
    print(f"rmsnorm_stitched_interpret,{t_pal:.1f},interpret-mode-overhead-expected")
    out["rmsnorm_us"] = {"oracle_jit": t_ref, "stitched_interpret": t_pal}

    unfused_sm = jax.jit(lambda x: ref.softmax(x, 0.125))
    t_ref = timeit(unfused_sm, x)
    t_pal = timeit(lambda x: k_softmax(x, 0.125), x)
    print(f"softmax_oracle_jit,{t_ref:.1f},baseline")
    print(f"softmax_stitched_interpret,{t_pal:.1f},interpret-mode-overhead-expected")
    out["softmax_us"] = {"oracle_jit": t_ref, "stitched_interpret": t_pal}

    out["exec"] = _measured_exec(reps)
    return out


def _measured_exec(reps: int) -> dict:
    """Measured-vs-modeled through the opt-in obs kernel timer: one
    stitched executable, ``block_until_ready``-bracketed wall clock per
    call next to the plan's cost-model time — the per-plan comparison
    ``launch/inspect.py`` prints from a trace."""
    import jax
    import jax.numpy as jnp
    from repro import obs
    from repro.exec import stitch

    def fused(x, g):
        h = x * jax.nn.sigmoid(1.702 * x)
        m = jnp.mean(h * h, axis=-1, keepdims=True)
        return h * jax.lax.rsqrt(m + 1e-6) * g

    sf = stitch(fused, mode="offline", name="bench_measured")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256, 512)), jnp.float32)
    g = jnp.asarray(rng.standard_normal(512), jnp.float32)
    sf(x, g)                                   # compile outside the timer
    obs.enable_timing()
    try:
        for _ in range(reps):
            sf(x, g)
    finally:
        obs.disable_timing()
    rep = sf.report()
    meas = (rep["measured"] or {}).get("stitched", {})
    plan = rep["plan"] or {}
    print(f"measured_stitched_exec,{meas.get('mean', 0.0) * 1e6:.1f},"
          f"modeled={plan.get('modeled_time', 0.0) * 1e6:.1f}us")
    return {"fn": "bench_measured", "calls": meas.get("count", 0),
            "measured_s": meas, "modeled_time_s": plan.get("modeled_time"),
            "n_kernels": plan.get("n_kernels")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="V100", choices=["V100", "TPU_V5E"])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_*.json-style record of per-workload "
                         "kernel counts, modeled step times and cache "
                         "cold/warm compile times")
    args = ap.parse_args(sys.argv[1:])
    cost = CostModel(V100 if args.hw == "V100" else TPU_V5E)

    t0 = time.time()
    graphs = build_all()
    print(f"# built {len(graphs)} workload graphs in {time.time() - t0:.1f}s "
          f"(sizes: {', '.join(f'{k}={len(v.nodes)}' for k, v in graphs.items())})")

    table2(graphs, cost)
    workloads = table3(graphs, cost)
    fig6(graphs)
    fig7_fig8(graphs, cost)
    table4(graphs, cost)
    cache = cache_timing(graphs, cost, args.quick)
    verify = verify_section(graphs, cost, cache)
    serve = serving(args.quick)
    train = training(args.quick)
    shard = sharding(args.quick)
    compute = compute_stitching(args.quick)
    packs = packing(args.quick)
    measured = perf_measured(args.quick)

    if args.json:
        record = {
            "bench": "fusionstitching",
            "hw": cost.hw.name,
            "unix_time": time.time(),
            "quick": args.quick,
            "workloads": workloads,
            "cache": cache,
            "verify": verify,
            "serving": serve,
            "training": train,
            "compute_stitching": compute,
            "packing": packs,
            "measured": measured,
        }
        if shard is not None:
            record["sharding"] = shard
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"\n# wrote {args.json}")


if __name__ == "__main__":
    main()
