"""Quickstart: trace a JAX function, plan fusion with the ILP, execute the
stitched Pallas kernels, compare against the oracle.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import StitchCompiler, build_reference_fn
from repro.core.trace import trace_to_graph


def layer(x, w, gamma):
    """A transformer-ish block tail: matmul -> rmsnorm -> glu-ish gate."""
    h = x @ w
    h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6) * gamma
    return jax.nn.silu(h) * jnp.tanh(h + 1.0)


def main():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((512, 512), dtype=np.float32)
    w = (rng.standard_normal((512, 512)) * 0.05).astype(np.float32)
    gamma = rng.standard_normal(512, dtype=np.float32)

    graph, names = trace_to_graph(layer, x, w, gamma)
    inputs = dict(zip(names, [x, w, gamma]))
    print(graph.dump())

    print("\nmode     kernels  compression  modeled_us  pallas_groups")
    for mode in ("off", "xla", "stitch"):
        cg = StitchCompiler(mode=mode).compile(graph)
        s = cg.stats
        print(f"{mode:8s} {s.n_kernels:7d}  {s.compression:10.2f}  "
              f"{s.modeled_time * 1e6:9.2f}  {s.pallas_groups}")

    ref = build_reference_fn(graph)(inputs)
    out = StitchCompiler(mode="stitch").compile(graph)(inputs)
    err = max(float(np.max(np.abs(np.asarray(out[k]) - np.asarray(ref[k]))))
              for k in ref)
    print(f"\nstitched-vs-oracle max abs error: {err:.2e}")
    assert err < 1e-3
    print("OK")


if __name__ == "__main__":
    main()
