"""Stitching your own function: the jit-like ``repro.exec.stitch()`` API.

Five demos, none of which flow through the train or serve machinery:

1. an arbitrary user pytree function (nested dicts/tuples, kwargs),
2. a Mamba block and a Griffin RG-LRU block via ``Model.block_fn`` —
   workloads the fusion pipeline had never seen before the exec refactor,
3. compute stitching: a transformer block (q/k/v projections, Pallas flash
   attention, output projection, gelu MLP) collapsing to ONE stitched kernel,
4. the same user function dispatched over a ``--model-parallel``-style
   host mesh through ``shard_map``, with a mesh-keyed cache placement,
5. horizontal packing: a wide-expert MoE block whose per-expert FFN chains
   ride in shared FFD-packed kernels, bitwise-equal to jit.

    PYTHONPATH=src python examples/stitch_fn.py
"""

import sys

# rehearse the sharded demo on any host (respects operator XLA_FLAGS)
from repro.launch.hostenv import force_host_devices

force_host_devices(8, argv=sys.argv)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.cache import CompilationService
from repro.configs import get_reduced
from repro.exec import stitch
from repro.models import build_model


def show(name, sf):
    rep = sf.report()
    plan = rep.get("plan", {})
    print(f"  [{name}] status={rep['status']} "
          f"kernels={plan.get('n_ops', '?')}->{plan.get('n_kernels', '?')} "
          f"pallas={plan.get('pallas_groups', '?')} "
          f"stitched_calls={rep['stitched_calls']} "
          f"fallback_calls={rep['fallback_calls']}")


def check(got, want, what, tol=2e-4):
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=tol, atol=tol)
    print(f"  {what}: matches the jit reference")


def demo_user_function(svc):
    print("\n-- 1. arbitrary pytree function ------------------------------")

    def my_fn(state, batch, *, temperature=1.0):
        h = jnp.tanh(batch["x"] @ state["w"]) + state["b"]
        e = jnp.exp(h / temperature - jnp.max(h, -1, keepdims=True))
        probs = e / jnp.sum(e, -1, keepdims=True)
        return {"probs": probs, "entropy": -jnp.sum(
            probs * jnp.log(probs + 1e-9), -1)}

    rng = np.random.default_rng(0)
    state = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 0.1, jnp.float32),
             "b": jnp.asarray(rng.standard_normal(64) * 0.1, jnp.float32)}
    batch = {"x": jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)}

    sf = stitch(my_fn, service=svc, name="my_fn")
    out = sf(state, batch, temperature=0.7)        # step 0: fallback artifact
    svc.wait(120.0)                                # let the upgrade land
    out = sf(state, batch, temperature=0.7)        # upgraded: stitched plan
    check(out, jax.jit(lambda s, b: my_fn(s, b, temperature=0.7))(state, batch),
          "pytree + kwargs")
    show("my_fn", sf)


def demo_model_blocks(svc):
    print("\n-- 2. Mamba / Griffin blocks (never trained, never served) ---")
    rng = np.random.default_rng(1)
    for arch in ("falcon_mamba_7b", "recurrentgemma_9b"):
        model = build_model(get_reduced(arch))
        if model.block_fn is None:
            continue
        params = model.init(jax.random.PRNGKey(0))
        if arch == "falcon_mamba_7b":
            lp = model.layer_params(params, 0)
        else:  # griffin: first recurrent layer of the first super-block
            lp = jax.tree.map(lambda l: l[0], params["supers"])["l0"]
        x = jnp.asarray(rng.standard_normal(
            (2, 16, model.cfg.d_model)), model.cfg.dtype)
        sf = stitch(model.block_fn, service=svc, name=f"{arch}_block")
        out = sf(lp, x)
        svc.wait(120.0)
        out = sf(lp, x)
        # bf16 recurrent blocks: XLA rewrites the scan body under jit (loop
        # fusion changes bf16 roundings, compounding over time steps), so
        # even *eager* jax diverges from jit by a few bf16 ulps here
        tol = 5e-2 if model.cfg.dtype == "bfloat16" else 2e-4
        check(out, jax.jit(model.block_fn)(lp, x), f"{arch} block", tol=tol)
        show(f"{arch}_block", sf)


def demo_compute_stitching(svc):
    print("\n-- 3. compute stitching: transformer block -> ONE kernel ------")
    from repro.kernels.flash_attention import flash_attention

    B, S, D, H = 2, 128, 16, 2
    dh, F = D // H, 64
    rng = np.random.default_rng(3)

    def mk(*shape):
        return jnp.asarray(rng.standard_normal(shape) * 0.1, jnp.float32)

    w = dict(wq=mk(D, D), wk=mk(D, D), wv=mk(D, D), wo=mk(D, D),
             w1=mk(D, F), w2=mk(F, D), g1=mk(D), g2=mk(D))
    x = mk(B, S, D)

    def rms(v, gain):
        return v * jax.lax.rsqrt(
            jnp.mean(v * v, axis=-1, keepdims=True) + 1e-6) * gain

    def attn_mlp_block(w, x):
        h = rms(x, w["g1"])
        q = (h @ w["wq"]).reshape(B, S, H, dh)
        k = (h @ w["wk"]).reshape(B, S, H, dh)
        v = (h @ w["wv"]).reshape(B, S, H, dh)
        a = flash_attention(q, k, v, causal=True).reshape(B, S, D)
        x2 = x + a @ w["wo"]
        return x2 + jax.nn.gelu(rms(x2, w["g2"]) @ w["w1"]) @ w["w2"]

    sf = stitch(attn_mlp_block, service=svc, name="attn_mlp_block")
    out = sf(w, x)
    svc.wait(120.0)
    out = sf(w, x)                  # q/k/v GEMMs + flash attention + MLP: one
    check(out, jax.jit(attn_mlp_block)(w, x), "attention+MLP block")
    show("attn_mlp_block", sf)


def demo_sharded(svc):
    print("\n-- 4. shard_map dispatch over the host mesh ------------------")
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(2 if jax.device_count() % 2 == 0 else 1)
    allax = tuple(mesh.axis_names)

    def local_loss(params, b):
        h = jnp.tanh(b @ params["w"]) + params["c"]
        return jax.lax.pmean(jnp.mean(jnp.square(h)), allax), h

    rng = np.random.default_rng(2)
    params = {"w": jnp.asarray(rng.standard_normal((32, 32)) * 0.1, jnp.float32),
              "c": jnp.asarray(rng.standard_normal(32) * 0.1, jnp.float32)}
    b = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)

    sf = stitch(local_loss, service=svc, mesh=mesh,
                in_specs=(P(), P(allax)), out_specs=(P(), P(allax)),
                name="sharded_loss")
    loss, h = sf(params, b)
    svc.wait(120.0)
    loss, h = sf(params, b)
    ref_l, ref_h = jax.jit(
        lambda p, x: (jnp.mean(jnp.square(jnp.tanh(x @ p["w"]) + p["c"])),
                      jnp.tanh(x @ p["w"]) + p["c"]))(params, b)
    check((loss, h), (ref_l, ref_h), f"mesh={dict(mesh.shape)} dispatch")
    print(f"  cache placement: {sf.placement}")
    show("sharded_loss", sf)


def demo_horizontal_packing(svc):
    print("\n-- 5. horizontal packing: wide-expert MoE block ---------------")
    import dataclasses

    # experts wide enough that the dependence-connected monolith is
    # occupancy-infeasible — packing the per-expert chains is the only
    # cover that shares launches (paper §4.2)
    cfg = get_reduced("qwen2_moe_a2_7b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, n_experts=16, top_k=2, d_expert=8192, n_shared=0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lp = model.layer_params(params, 0)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)) * 0.1, cfg.dtype)

    sf = stitch(model.block_fn, service=svc, name="moe_block")
    out = sf(lp, x)                 # step 0: fallback artifact
    svc.wait(240.0)
    out = sf(lp, x)                 # upgraded: packed stitched plan
    for got, want in zip(
            jax.tree_util.tree_leaves(out),
            jax.tree_util.tree_leaves(jax.jit(model.block_fn)(lp, x))):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    print("  MoE block: bitwise-equal to the jit reference")
    plan = sf.report().get("plan", {})
    assert plan.get("packs", 0) >= 1, "per-expert chains must pack"
    print(f"  packs={plan.get('packs')} "
          f"packed_subgraphs={plan.get('packed_subgraphs')} "
          f"kernels={plan.get('n_ops')}->{plan.get('n_kernels')}")
    show("moe_block", sf)


def main():
    svc = CompilationService()
    demo_user_function(svc)
    demo_model_blocks(svc)
    demo_compute_stitching(svc)
    demo_sharded(svc)
    demo_horizontal_packing(svc)
    print("\ncache:", {k: v for k, v in svc.cache.report().items()
                       if k in ("hits", "misses", "memory_entries")})
    print("OK")


if __name__ == "__main__":
    main()
