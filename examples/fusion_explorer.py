"""Fusion explorer: show the full FusionStitching pipeline on the paper's
Fig. 1 motif — candidate patterns, ILP plan, schedule template, generated
kernel source, and scratch plan.

    PYTHONPATH=src python examples/fusion_explorer.py
"""

from repro.core import (
    CostModel, FusionPattern, GenConfig, ScratchAllocator, StitchCompiler,
    emit_source, generate_patterns, generate_templates, solve_fusion_plan,
)
import sys
sys.path.insert(0, "benchmarks")
sys.path.insert(0, ".")
from benchmarks.workloads import multi_interests


def main():
    g = multi_interests()
    print(f"graph: {len(g.nodes)} nodes, {len(g.compute_nodes())} compute ops")

    cost = CostModel()
    patterns = generate_patterns(g, GenConfig())
    scored = [(p, cost.score(p)) for p in patterns]
    pos = [s for _, s in scored if s.score > 0]
    print(f"candidates: {len(patterns)} ({len(pos)} with positive gain)")
    for p, s in sorted(scored, key=lambda t: -t[1].score)[:5]:
        print(f"  {s.score * 1e6:8.2f}us  {p!r}  saved={s.saved_bytes}B")

    res = solve_fusion_plan(g, [p for p, _ in scored], [s.score for _, s in scored])
    print(f"\nILP plan: {len(res.chosen)} patterns, objective "
          f"{res.objective * 1e6:.2f}us saved, {res.iterations} rounds, "
          f"{res.cuts_added} cycle cuts, {res.nodes_explored} B&B nodes")

    big = max(res.chosen, key=len)
    templates = generate_templates(big, cost)
    print(f"\nlargest pattern: {len(big)} ops, class={big.pattern_class}")
    if templates:
        t = templates[0]
        print(f"template: {t}")
        req = cost.scratch_request(big)
        plan = ScratchAllocator(g).allocate(req)
        print(f"scratch: requested={plan.requested}B allocated={plan.allocated}B "
              f"(alloc/req={plan.alloc_over_req:.2f})")
        print("\n--- generated kernel source ---")
        print(emit_source(big, t))

    cg = StitchCompiler(mode="stitch").compile(g)
    print(f"compiled: {cg.stats.n_kernels} kernels from "
          f"{cg.stats.n_ops} ops (compression {cg.stats.compression:.1f}x, "
          f"{cg.stats.pallas_groups} pallas groups)")


if __name__ == "__main__":
    main()
