"""End-to-end driver: train a ~100M-param qwen3-family LM for a few hundred
steps on CPU with checkpointing + fault-tolerant supervision.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(thin wrapper over repro.launch.train with a ~100M config)
"""

import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    args = sys.argv[1:]
    sys.argv = [sys.argv[0], "--arch", "qwen3-1.7b", "--reduced",
                # widen the smoke config to ~100M params: 8 layers x 512 wide
                "--d-model", "512", "--n-layers", "8",
                "--batch", "8", "--seq", "128", "--steps", "300",
                "--ckpt-dir", "/tmp/repro_train_lm"] + args
    train_main()
