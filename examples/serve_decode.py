"""Serve a small model with batched requests (prefill + greedy decode).

    PYTHONPATH=src python examples/serve_decode.py
"""

import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "qwen3-1.7b", "--reduced",
                "--batch", "4", "--prompt-len", "32", "--new-tokens", "16"]
    serve_main()
