"""Stitched training: traced backward + packed AdamW vs the jitted reference.

The contract under test: ``StitchedTrainStep`` is a drop-in for the jitted
``make_train_step`` callable — same params, opt state, loss, and grad-norm
metric over multiple steps — while executing the backward pass and the
packed multi-tensor optimizer through compiled StitchIR artifacts, including
the miss-then-upgrade transition from the XLA fallback mid-run.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import CompilationService, StitchCache
from repro.configs import get_reduced
from repro.models import build_model
from repro.optim import AdamWConfig, PackedAdamW, adamw, make_layout, pack_tree, unpack_tree
from repro.train import StitchedTrainStep, init_state, make_train_step

B, S = 2, 8


@pytest.fixture(scope="module")
def model():
    return build_model(get_reduced("qwen3_1_7b"))


@pytest.fixture(scope="module")
def opt_cfg():
    return AdamWConfig(warmup_steps=2, total_steps=20)


def make_batch(vocab, i, batch=B, seq=S):
    r = np.random.default_rng(100 + i)
    return {"tokens": jnp.asarray(r.integers(0, vocab, (batch, seq)), jnp.int32),
            "labels": jnp.asarray(r.integers(0, vocab, (batch, seq)), jnp.int32)}


def assert_state_close(a, b, rtol=2e-5, atol=2e-6):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# the headline contract: identical training trajectory, upgrade mid-run
# ---------------------------------------------------------------------------

def test_stitched_step_matches_jit_with_upgrade_mid_run(model, opt_cfg):
    """3+ steps of numerically identical training, with the stitched plans
    landing *between* steps (deterministic miss-then-upgrade): steps 0-1 run
    on the instantly-available XLA fallback artifacts, the stitch pipeline
    is then forced synchronously, and steps 2+ run on the upgraded packed
    plans.  The trajectory must be seamless throughout."""
    vocab = model.cfg.vocab
    ref_step = jax.jit(make_train_step(model, opt_cfg))
    # max_background=0: the service never spawns the background compile, so
    # the upgrade point is under test control instead of thread timing
    svc = CompilationService(max_background=0)
    st_step = StitchedTrainStep(model, opt_cfg, service=svc)

    s_ref = init_state(model, jax.random.PRNGKey(0))
    s_st = init_state(model, jax.random.PRNGKey(0))

    for i in range(2):                                  # fallback phase
        s_st, m_st = st_step(s_st, make_batch(vocab, i))
        s_ref, m_ref = ref_step(s_ref, make_batch(vocab, i))
        np.testing.assert_allclose(float(m_st["loss"]), float(m_ref["loss"]),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(m_st["grad_norm"]),
                                   float(m_ref["grad_norm"]), rtol=1e-4)
    assert st_step._grad.status in ("miss", "pending")
    assert st_step._packed.status in ("miss", "pending")
    assert st_step._grad.compiled.stats.mode == "xla"   # fallback artifact

    # land the stitched plans in the cache (what the background thread does)
    stitch = svc.compiler("stitch")
    stitch.compile(st_step._grad.graph, bypass_cache_lookup=True)
    stitch.compile(st_step._packed.graph, bypass_cache_lookup=True)

    for i in range(2, 4):                               # upgraded phase
        s_st, m_st = st_step(s_st, make_batch(vocab, i))
        s_ref, m_ref = ref_step(s_ref, make_batch(vocab, i))
        np.testing.assert_allclose(float(m_st["loss"]), float(m_ref["loss"]),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(m_st["grad_norm"]),
                                   float(m_ref["grad_norm"]), rtol=1e-4)
    assert st_step._grad.status == "hit"
    assert st_step._packed.status == "hit"
    assert st_step._grad.compiled.stats.mode == "stitch"
    assert st_step.fallback_steps == 0

    assert int(s_st.step) == int(s_ref.step) == 4
    assert int(s_st.opt.count) == int(s_ref.opt.count) == 4
    assert_state_close(s_st.params, s_ref.params)
    assert_state_close(s_st.opt.m, s_ref.opt.m)
    assert_state_close(s_st.opt.v, s_ref.opt.v)

    # the packed optimizer plan is ONE kernel for the whole AdamW+clip update
    assert st_step._packed.kernel_count == 1
    # and the backward plan compresses kernels vs one-kernel-per-op
    grad_stats = st_step._grad.compiled.stats
    assert grad_stats.n_kernels < grad_stats.n_ops


def test_stitched_step_microbatch_accumulation(model, opt_cfg):
    """Gradient accumulation (scan over microbatches) traces through the
    same pipeline — the scan stays an executable CUSTOM partition — and the
    trajectory still matches the jitted microbatched reference."""
    vocab = model.cfg.vocab
    ref_step = jax.jit(make_train_step(model, opt_cfg, microbatches=2))
    st_step = StitchedTrainStep(model, opt_cfg, microbatches=2,
                                service=CompilationService(max_background=0))

    s_ref = init_state(model, jax.random.PRNGKey(1))
    s_st = init_state(model, jax.random.PRNGKey(1))
    for i in range(3):
        batch = make_batch(vocab, 20 + i, batch=4)
        s_st, m_st = st_step(s_st, batch)
        s_ref, m_ref = ref_step(s_ref, batch)
        np.testing.assert_allclose(float(m_st["loss"]), float(m_ref["loss"]),
                                   rtol=1e-5)
    assert st_step.fallback_steps == 0
    assert_state_close(s_st.params, s_ref.params)
    assert_state_close(s_st.opt.m, s_ref.opt.m)


def test_stitched_step_shape_drift_falls_back(model, opt_cfg):
    """A batch whose shapes differ from the traced avals (e.g. a last
    partial batch) is served by the jitted reference for that call only."""
    vocab = model.cfg.vocab
    st_step = StitchedTrainStep(model, opt_cfg,
                                service=CompilationService(max_background=0))
    s = init_state(model, jax.random.PRNGKey(2))
    s, _ = st_step(s, make_batch(vocab, 0))
    assert st_step.fallback_steps == 0
    s, m = st_step(s, make_batch(vocab, 1, seq=S // 2))   # drifted shape
    assert st_step.fallback_steps == 1
    assert np.isfinite(float(m["loss"]))
    s, _ = st_step(s, make_batch(vocab, 2))               # original shape again
    assert st_step.fallback_steps == 1
    assert int(s.step) == 3


def test_stitched_step_donates_consumed_state(model, opt_cfg):
    """The stitched dispatch must not keep the consumed TrainState alive
    (the jit path donates via donate_argnums; without the matching delete
    the stitched path holds params+opt twice at peak).  Buffer count: every
    old params/m/v leaf is deleted, every new one alive."""
    vocab = model.cfg.vocab
    st = StitchedTrainStep(model, opt_cfg,
                           service=CompilationService(max_background=0))
    s0 = init_state(model, jax.random.PRNGKey(3))
    old = jax.tree_util.tree_leaves((s0.params, s0.opt.m, s0.opt.v))
    s1, _ = st(s0, make_batch(vocab, 0))
    assert st.fallback_steps == 0            # the stitched dispatch ran
    assert sum(l.is_deleted() for l in old) == len(old)
    new = jax.tree_util.tree_leaves((s1.params, s1.opt.m, s1.opt.v))
    assert not any(l.is_deleted() for l in new)
    # and the next step still works off the new state
    s2, m = st(s1, make_batch(vocab, 1))
    assert np.isfinite(float(m["loss"]))


def test_stitched_step_donate_false_keeps_state(model, opt_cfg):
    vocab = model.cfg.vocab
    st = StitchedTrainStep(model, opt_cfg, donate=False,
                           service=CompilationService(max_background=0))
    s0 = init_state(model, jax.random.PRNGKey(5))
    old = jax.tree_util.tree_leaves((s0.params, s0.opt.m, s0.opt.v))
    st(s0, make_batch(vocab, 0))
    assert not any(l.is_deleted() for l in old)


# ---------------------------------------------------------------------------
# packed multi-tensor AdamW
# ---------------------------------------------------------------------------

def test_packed_update_is_single_kernel_and_exact():
    """The compiled packed update covers the whole AdamW+clip update with
    ONE kernel (kernel packing: independent per-tensor chains share the
    grid; the global-norm accumulators feed the clip scale via grid==1
    block composition) and reproduces the per-tensor reference exactly."""
    cfg = AdamWConfig()
    rng = np.random.default_rng(0)
    shapes = [(6, 17), (64,), (3, 4, 5), (), (40, 16)]
    params = {f"p{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
              for i, s in enumerate(shapes)}
    grads = {f"p{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
             for i, s in enumerate(shapes)}
    state = adamw.init(params)

    pa = PackedAdamW(cfg, params)
    assert pa.kernel_count == 1
    assert pa._compiled.stats.pallas_groups == 1
    assert pa._compiled.stats.n_ops > 50      # the packing is real

    new_p, new_s, metrics = pa.update(grads, state, params)
    ref_p, ref_s, ref_m = adamw.update(cfg, grads, state, params)
    assert_state_close(new_p, ref_p, rtol=1e-6, atol=1e-7)
    assert_state_close(new_s.m, ref_s.m, rtol=1e-6, atol=1e-7)
    assert_state_close(new_s.v, ref_s.v, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(metrics["grad_norm"]),
                               float(ref_m["grad_norm"]), rtol=1e-5)
    assert int(new_s.count) == 1

    # second step: state threads through pack/unpack without drift
    new_p2, new_s2, _ = pa.update(grads, new_s, new_p)
    ref_p2, ref_s2, _ = adamw.update(cfg, grads, ref_s, ref_p)
    assert_state_close(new_p2, ref_p2, rtol=1e-6, atol=1e-7)
    assert int(new_s2.count) == int(ref_s2.count) == 2


def test_pack_unpack_roundtrip_mixed_dtypes():
    rng = np.random.default_rng(3)
    tree = {
        "a": jnp.asarray(rng.standard_normal((5, 7)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((13,)), jnp.bfloat16),
        "c": jnp.asarray(rng.standard_normal(()), jnp.float32),
    }
    layout = make_layout(tree, rows=8)
    panels = pack_tree(layout, tree)
    for i, p in enumerate(panels):
        assert p.shape == layout.panel_shape(i)
        assert p.shape[0] == 8 and p.dtype == jnp.float32
    back = unpack_tree(layout, panels)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_allclose(np.asarray(back[k], np.float32),
                                   np.asarray(tree[k], np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_packed_update_with_disk_cache_service(tmp_path):
    """PackedAdamW through a CompilationService with a disk-backed cache:
    a second instance replays the packed plan (cache hit) instead of
    re-running the stitch pipeline."""
    cfg = AdamWConfig()
    params = {"w": jnp.ones((8, 4), jnp.float32), "b": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((8, 4), 0.5, jnp.float32),
             "b": jnp.full((4,), 0.5, jnp.float32)}
    state = adamw.init(params)

    svc = CompilationService(cache=StitchCache(str(tmp_path)))
    pa = PackedAdamW(cfg, params, service=svc)
    assert pa.status in ("miss", "pending", "hit")
    out1 = pa.update(grads, state, params)
    svc.wait(60.0)
    pa.poll_upgrade()
    assert pa.status == "hit"
    assert pa.kernel_count == 1
    out2 = PackedAdamW(cfg, params, service=svc).update(grads, state, params)
    assert_state_close(out1[0], out2[0], rtol=1e-6, atol=0)

    ref = adamw.update(cfg, grads, state, params)
    assert_state_close(out1[0], ref[0], rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# int64-truncation warning regression (stitched.py / codegen.py)
# ---------------------------------------------------------------------------

@pytest.mark.filterwarnings("error::UserWarning")
def test_no_x64_truncation_warning_from_stitched_execution():
    """Graphs carrying 64-bit dtypes (traced under x64 or hand-built) must
    execute through the stitched kernel AND the reference paths without the
    jnp 'requested dtype int64 ... truncated' UserWarning: the graph dtype
    is canonicalized once instead of requested per call."""
    from repro.core import GraphBuilder, StitchCompiler, build_reference_fn

    b = GraphBuilder("i64")
    x = b.param("x", (64, 32), dtype="int64")
    c = b.const("c", (), dtype="float64")
    b.graph[c].attrs["value"] = np.float64(2.0)
    y = b.ew("add", x, x)
    z = b.ew("mul", y, y)
    f = b.ew("convert", z, dtype="float64")
    g = b.build(outputs=[f])

    inputs = {"x": np.arange(64 * 32, dtype=np.int32).reshape(64, 32)}
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        ref = build_reference_fn(g)(inputs)
        compiled = StitchCompiler(mode="stitch").compile(g)
        out = compiled(inputs)
    np.testing.assert_allclose(np.asarray(out[f], np.float64),
                               np.asarray(ref[f], np.float64))
    # at least one group actually ran as a stitched Pallas kernel
    assert compiled.stats.pallas_groups >= 1


@pytest.mark.filterwarnings("error::UserWarning")
def test_no_x64_truncation_warning_from_traced_float64_consts(model, opt_cfg):
    """The original repro: tracing real model code captures np scalar consts
    as float64/int64; compiling + executing the traced graph must not warn."""
    from repro.core import StitchCompiler
    from repro.core.trace import trace_to_graph

    vocab = model.cfg.vocab
    batch = make_batch(vocab, 0)
    params = init_state(model, jax.random.PRNGKey(0)).params

    def fwd(p, tokens):
        loss, _ = model.train_forward(p, {"tokens": tokens,
                                          "labels": batch["labels"]})
        return loss

    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        g, names = trace_to_graph(fwd, params, batch["tokens"], name="fwd")
        compiled = StitchCompiler(mode="stitch").compile(g)
        env = dict(zip(names, jax.tree_util.tree_leaves((params, batch["tokens"]))))
        out = compiled(env)
        jax.block_until_ready(list(out.values()))
