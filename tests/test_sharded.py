"""Mesh-aware stitched execution: shard_map dispatch for train and serve.

The tier-1 suite runs on a forced 8-device host platform (conftest.py sets
``--xla_force_host_platform_device_count=8``), so these tests exercise a
real (4, 2) data x model mesh on CPU:

* the sharded ``StitchedTrainStep`` (``--stitch --model-parallel 2``,
  DP=4) must reproduce both the single-device stitched trajectory and the
  sharded-jit trajectory to tolerance, including a mid-run
  miss-then-upgrade transition under ``shard_map``;
* the StitchCache must key plans by placement (mesh + PartitionSpecs):
  a plan compiled at one mesh never answers a lookup at another;
* the serving engine's DP-replica dispatch must be token-for-token
  equal to the unsharded engine on both the static and continuous paths;
* ``make_host_mesh`` must reject a non-dividing ``--model-parallel``
  with an error naming the valid divisors.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.cache import CompilationService, StitchCache, placement_key
from repro.configs import get_reduced
from repro.core import StitchCompiler
from repro.models import build_model, local_shape
from repro.optim import AdamWConfig
from repro.train import StitchedTrainStep, init_state, make_train_step

from conftest import make_softmax_graph

B, S = 8, 8
N_STEPS = 4
UPGRADE_AT = 2           # steps 0-1 on the XLA fallback, 2-3 stitched


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 8:
        pytest.skip("needs the forced 8-device host platform")
    return jax.make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="module")
def model():
    return build_model(get_reduced("qwen3_1_7b"))


@pytest.fixture(scope="module")
def opt_cfg():
    return AdamWConfig(warmup_steps=2, total_steps=20)


def make_batch(vocab, i):
    r = np.random.default_rng(500 + i)
    return {"tokens": jnp.asarray(r.integers(0, vocab, (B, S)), jnp.int32),
            "labels": jnp.asarray(r.integers(0, vocab, (B, S)), jnp.int32)}


# ---------------------------------------------------------------------------
# launcher fix: make_host_mesh divisibility validation
# ---------------------------------------------------------------------------

def test_make_host_mesh_validates_divisibility():
    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())
    assert n == 8
    mesh = make_host_mesh(2)
    assert dict(mesh.shape) == {"data": 4, "model": 2}
    with pytest.raises(ValueError) as ei:
        make_host_mesh(3)                     # 8 devices, MP=3: no mesh
    msg = str(ei.value)
    assert "[1, 2, 4, 8]" in msg and "8 devices" in msg
    with pytest.raises(ValueError):
        make_host_mesh(0)


# ---------------------------------------------------------------------------
# mesh-keyed cache entries (the hit/miss assertion)
# ---------------------------------------------------------------------------

def test_mesh_keyed_cache_hit_miss(mesh):
    """A plan compiled at one placement must hit ONLY at that placement —
    another mesh (or the single-device placement) is a miss and makes its
    own entry."""
    g, _x, _y = make_softmax_graph()
    cache = StitchCache()
    pl_a = placement_key(mesh, P("data"))
    pl_b = placement_key(mesh, P(("data", "model")))
    comp_a = StitchCompiler(mode="stitch", use_pallas=False, cache=cache,
                            placement=pl_a)
    comp_b = StitchCompiler(mode="stitch", use_pallas=False, cache=cache,
                            placement=pl_b)
    comp_1d = StitchCompiler(mode="stitch", use_pallas=False, cache=cache)

    assert pl_a != pl_b != ""
    comp_a.compile(g)                              # cold at placement A
    assert cache.lookup(g, comp_a) is not None     # hit at A
    assert cache.lookup(g, comp_b) is None         # miss at B
    assert cache.lookup(g, comp_1d) is None        # miss at single-device
    comp_b.compile(g)
    comp_1d.compile(g)
    assert len(cache.store.memory) == 3            # one entry per placement
    per_pl = cache.report()["per_placement"]
    assert per_pl[pl_a]["hits"] >= 1
    assert per_pl[pl_b]["misses"] >= 1
    assert per_pl["single-device"]["misses"] >= 1


def test_mesh_keyed_disk_roundtrip(mesh, tmp_path):
    """Placement survives the disk store: a fresh process (new StitchCache)
    replays the mesh-keyed record, and the other placement still misses."""
    g, _x, _y = make_softmax_graph()
    pl = placement_key(mesh, P("data"))
    c1 = StitchCache(str(tmp_path))
    StitchCompiler(mode="stitch", use_pallas=False, cache=c1,
                   placement=pl).compile(g)

    c2 = StitchCache(str(tmp_path))                # fresh cache, same disk
    hit = c2.lookup(g, StitchCompiler(mode="stitch", use_pallas=False,
                                      cache=c2, placement=pl))
    assert hit is not None and hit.stats.cache_status == "hit"
    assert c2.lookup(g, StitchCompiler(mode="stitch", use_pallas=False,
                                       cache=c2)) is None


def test_local_shape_arithmetic(mesh):
    assert local_shape((8, 16), P(("data", "model")), mesh) == (1, 16)
    assert local_shape((8, 16), P("data", "model"), mesh) == (2, 8)
    assert local_shape((8, 16), P(), mesh) == (8, 16)
    with pytest.raises(ValueError):
        local_shape((6, 16), P("data"), mesh)      # 6 % 4 != 0


# ---------------------------------------------------------------------------
# the tentpole: sharded stitched training trajectory
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sharded_run(mesh, model, opt_cfg):
    """One 4-step run of each trajectory: sharded stitched (upgrade after
    step 2), single-device stitched (upgraded the same way), and sharded
    jit.  Shared by the assertions below — the stitch compiles dominate the
    cost."""
    vocab = model.cfg.vocab

    # sharded stitched: max_background=0 pins the upgrade point
    svc_sh = CompilationService(max_background=0)
    st_sh = StitchedTrainStep(model, opt_cfg, service=svc_sh, mesh=mesh)
    s_sh = jax.device_put(init_state(model, jax.random.PRNGKey(0)),
                          st_sh.state_shardings())

    # single-device stitched reference
    svc_1d = CompilationService(max_background=0)
    st_1d = StitchedTrainStep(model, opt_cfg, service=svc_1d)
    s_1d = init_state(model, jax.random.PRNGKey(0))

    # sharded jit reference (GSPMD over the same mesh placement)
    jit_step = jax.jit(make_train_step(model, opt_cfg))
    s_jit = jax.device_put(init_state(model, jax.random.PRNGKey(0)),
                           st_sh.state_shardings())

    hist = {"sh": [], "1d": [], "jit": []}
    statuses = []
    for i in range(N_STEPS):
        if i == UPGRADE_AT:
            # land the stitched plans mid-run (what the background thread
            # would do), for BOTH placements
            for st, svc in ((st_sh, svc_sh), (st_1d, svc_1d)):
                for phase in (st._grad, st._packed):
                    svc.compiler("stitch", phase.placement).compile(
                        phase.graph, bypass_cache_lookup=True)
        s_sh, m_sh = st_sh(s_sh, make_batch(vocab, i))
        s_1d, m_1d = st_1d(s_1d, make_batch(vocab, i))
        s_jit, m_jit = jit_step(s_jit, make_batch(vocab, i))
        statuses.append((st_sh._grad.status, st_sh._packed.status))
        for k, m in (("sh", m_sh), ("1d", m_1d), ("jit", m_jit)):
            hist[k].append((float(m["loss"]), float(m["grad_norm"])))
    return {"hist": hist, "statuses": statuses, "st_sh": st_sh,
            "st_1d": st_1d, "svc_sh": svc_sh, "mesh": mesh,
            "final": {"sh": s_sh, "1d": s_1d, "jit": s_jit}}


def test_sharded_matches_single_device_stitched(sharded_run):
    """--stitch --model-parallel 2 (DP=4) loss/grad-norm trajectories match
    the single-device stitched run to tolerance, across the mid-run
    upgrade."""
    for (l_sh, g_sh), (l_1d, g_1d) in zip(sharded_run["hist"]["sh"],
                                          sharded_run["hist"]["1d"]):
        np.testing.assert_allclose(l_sh, l_1d, rtol=5e-3)
        np.testing.assert_allclose(g_sh, g_1d, rtol=2e-2)


def test_sharded_matches_sharded_jit(sharded_run):
    """...and the sharded-jit (GSPMD) trajectory."""
    for (l_sh, g_sh), (l_j, g_j) in zip(sharded_run["hist"]["sh"],
                                        sharded_run["hist"]["jit"]):
        np.testing.assert_allclose(l_sh, l_j, rtol=5e-3)
        np.testing.assert_allclose(g_sh, g_j, rtol=2e-2)


def test_sharded_final_states_close(sharded_run):
    for a, b in zip(jax.tree_util.tree_leaves(sharded_run["final"]["sh"].params),
                    jax.tree_util.tree_leaves(sharded_run["final"]["1d"].params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-3)
    assert int(sharded_run["final"]["sh"].step) == N_STEPS
    assert int(sharded_run["final"]["sh"].opt.count) == N_STEPS


def test_sharded_miss_then_upgrade_under_shard_map(sharded_run):
    """Steps 0-1 served the XLA fallback artifacts under shard_map, steps
    2-3 the stitched plans — never the jit fallback."""
    st = sharded_run["st_sh"]
    assert sharded_run["statuses"][UPGRADE_AT - 1][0] in ("miss", "pending")
    assert sharded_run["statuses"][UPGRADE_AT] == ("hit", "hit")
    assert st._grad.compiled.stats.mode == "stitch"
    assert st.fallback_steps == 0
    # packed update: ONE kernel over the TP-shard-local panels
    assert st._packed.kernel_count == 1
    grad_stats = st._grad.compiled.stats
    assert grad_stats.n_kernels < grad_stats.n_ops


def test_sharded_cache_keys_are_mesh_scoped(sharded_run):
    """Acceptance hit/miss assertion: the sharded grad plan hits at its
    placement and misses at the single-device placement (and vice versa) —
    the cache holds distinct mesh-keyed entries."""
    st, svc = sharded_run["st_sh"], sharded_run["svc_sh"]
    assert st._grad.placement.startswith("mesh[data=4,model=2]")
    hit = svc.cache.lookup(st._grad.graph,
                           svc.compiler("stitch", st._grad.placement))
    assert hit is not None
    assert svc.cache.lookup(st._grad.graph, svc.compiler("stitch")) is None
    # the single-device run's phases hit only at the "" placement
    st1 = sharded_run["st_1d"]
    assert st1._grad.placement == ""


def test_sharded_step_donates_consumed_state(mesh, model, opt_cfg, sharded_run):
    """The sharded dispatch frees the consumed params+moments (the stitched
    analogue of donate_argnums): every old buffer is deleted, every new one
    alive."""
    vocab = model.cfg.vocab
    st = sharded_run["st_sh"]
    s0 = jax.device_put(init_state(model, jax.random.PRNGKey(9)),
                        st.state_shardings())
    old = jax.tree_util.tree_leaves((s0.params, s0.opt.m, s0.opt.v))
    s1, _ = st(s0, make_batch(vocab, 77))
    assert sum(l.is_deleted() for l in old) == len(old)
    new = jax.tree_util.tree_leaves((s1.params, s1.opt.m, s1.opt.v))
    assert not any(l.is_deleted() for l in new)


def test_sharded_shape_drift_falls_back(sharded_run, model):
    """A drifted batch is served by the (sharded-jit) fallback for that call
    only."""
    st = sharded_run["st_sh"]
    base = st.fallback_steps
    s = jax.device_put(init_state(model, jax.random.PRNGKey(4)),
                       st.state_shardings())
    r = np.random.default_rng(0)
    drifted = {"tokens": jnp.asarray(r.integers(0, model.cfg.vocab, (B, S // 2)),
                                     jnp.int32),
               "labels": jnp.asarray(r.integers(0, model.cfg.vocab, (B, S // 2)),
                                     jnp.int32)}
    s, m = st(s, drifted)
    assert st.fallback_steps == base + 1
    assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# serving: DP-replica dispatch
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_setup(mesh, model):
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_serve_dp_dispatch_matches_unsharded(mesh, serve_setup):
    from repro.serve import Engine, ServeConfig
    model, params = serve_setup
    vocab = model.cfg.vocab
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, vocab, (4, 8)).astype(np.int32)

    eng_ref = Engine(model, params, ServeConfig(batch=4, max_len=32,
                                                max_new_tokens=6))
    eng_sh = Engine(model, params, ServeConfig(batch=4, max_len=32,
                                               max_new_tokens=6), mesh=mesh)
    assert eng_sh.dp_replicas == 4          # slots=4 spread over the DP axis

    np.testing.assert_array_equal(eng_ref.generate(prompts.copy()),
                                  eng_sh.generate(prompts.copy()))

    # continuous batching: same request stream, token-for-token equal
    reqs = [rng.integers(0, vocab, (int(rng.integers(3, 9)),)).astype(np.int32)
            for _ in range(6)]
    for eng in (eng_ref, eng_sh):
        for p in reqs:
            eng.submit(p, max_new_tokens=4)
    fins_ref = sorted(eng_ref.drain(), key=lambda f: f.rid)
    fins_sh = sorted(eng_sh.drain(), key=lambda f: f.rid)
    for a, b in zip(fins_ref, fins_sh):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_serve_rejects_undividable_slots(mesh, serve_setup):
    from repro.serve import Engine, ServeConfig
    model, params = serve_setup
    with pytest.raises(ValueError, match="does not divide"):
        Engine(model, params, ServeConfig(batch=3, max_len=32), mesh=mesh)


def test_serve_stitched_sharded_upgrade(mesh, serve_setup):
    """Stitched decode under shard_map: the fallback artifact serves
    immediately, the mesh-keyed stitched plan lands, and tokens never
    change."""
    from repro.serve import Engine, ServeConfig
    model, params = serve_setup
    vocab = model.cfg.vocab
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, vocab, (4, 8)).astype(np.int32)

    ref = Engine(model, params, ServeConfig(batch=4, max_len=32,
                                            max_new_tokens=5)
                 ).generate(prompts.copy())

    svc = CompilationService(max_background=0)
    eng = Engine(model, params,
                 ServeConfig(batch=4, max_len=32, max_new_tokens=5,
                             stitch_execute=True),
                 stitch_service=svc, mesh=mesh)
    np.testing.assert_array_equal(ref, eng.generate(prompts.copy()))
    assert eng.stitch_status in ("miss", "pending")
    st = eng._stitch
    assert st["sharded"] and st["placement"].startswith("mesh[")
    svc.compiler("stitch", st["placement"]).compile(st["graph"],
                                                    bypass_cache_lookup=True)
    np.testing.assert_array_equal(ref, eng.generate(prompts.copy()))
    assert eng.stitch_status == "hit"
    assert eng._stitch["compiled"].stats.mode == "stitch"


# ---------------------------------------------------------------------------
# tracing shard-local collectives (axis_env)
# ---------------------------------------------------------------------------

def test_trace_collective_as_custom_partition(mesh):
    """A shard-local function containing a pmean traces with axis_env: the
    collective becomes an executable CUSTOM fusion partition, and the
    compiled artifact runs correctly inside shard_map."""
    from repro.core.ir import OpKind
    from repro.core.trace import trace_to_graph

    def local_fn(x):
        return jax.lax.pmean(x * 2.0 + 1.0, ("data", "model"))

    g, names = trace_to_graph(
        local_fn, jax.ShapeDtypeStruct((1, 4), jnp.float32),
        axis_env=[("data", 4), ("model", 2)])
    kinds = [n.kind for n in g.nodes.values()]
    assert OpKind.CUSTOM in kinds           # the psum partitions fusion
    compiled = StitchCompiler(mode="stitch", use_pallas=False).compile(g)

    def body(x):
        outs = compiled(dict(zip(names, [x])))
        return outs[g.outputs[0]]

    x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
    got = shard_map(body, mesh=mesh, in_specs=P(("data", "model")),
                    out_specs=P(), check_rep=False)(x)
    want = np.mean(np.asarray(x) * 2.0 + 1.0, axis=0, keepdims=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)
