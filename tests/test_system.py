"""End-to-end system tests: training loop + checkpoint/restart + fault
tolerance + serving + distributed-optimization pieces."""

import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data import DataConfig, SyntheticTokens
from repro.ft import (StepFailure, StragglerMonitor, TrainSupervisor,
                      elastic_remesh, usable_mesh_shape)
from repro.models import build_model
from repro.optim import AdamWConfig, adamw, compress
from repro.serve import Engine, ServeConfig
from repro.train import TrainState, init_state, make_train_step
from repro.ckpt import store


def _setup(arch="qwen3_1_7b", steps=20):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    step = jax.jit(make_train_step(
        model, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)))
    return cfg, model, state, data, step


def test_loss_decreases_over_training():
    cfg, model, state, data, step = _setup()
    losses = []
    for s in range(20):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, f"no learning: {losses[0]} -> {losses[-1]}"


def test_checkpoint_roundtrip_exact():
    cfg, model, state, data, step = _setup()
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    state, _ = step(state, batch)
    with tempfile.TemporaryDirectory() as d:
        store.save(state, d, int(state.step))
        like = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
        restored, s = store.restore(d, like)
        assert s == 1
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_reproduces_uninterrupted_run():
    """Supervisor with an injected failure converges to the SAME state as an
    uninterrupted run (exact data replay + checkpoint restore)."""
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        def run(ckpt_dir, fail):
            cfg, model, state, data, step = _setup()
            sup = TrainSupervisor(
                step, lambda s: {k: jnp.asarray(v) for k, v in data.batch(s).items()},
                ckpt_dir, ckpt_every=4)
            return sup.run(state, 12,
                           fail_at={7: StepFailure("boom")} if fail else None)

        s_fail = run(d1, True)
        s_ok = run(d2, False)
        for a, b in zip(jax.tree.leaves(s_fail), jax.tree.leaves(s_ok)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-5, atol=1e-6)


def test_async_checkpointer_crash_safety():
    cfg, model, state, data, step = _setup()
    with tempfile.TemporaryDirectory() as d:
        ck = store.AsyncCheckpointer(d)
        ck.save_async(state, 0)
        ck.wait()
        assert store.latest_step(d) == 0
        ck.save_async(state, 5)
        ck.wait()
        assert store.latest_step(d) == 5


def test_straggler_and_elastic():
    mon = StragglerMonitor(8, threshold=1.5)
    times = np.ones(8)
    times[3] = 4.0
    flagged = None
    for _ in range(4):
        flagged = mon.observe(times)
    assert flagged == [3]
    assert usable_mesh_shape(240, 16) == (15, 16)  # lost a host: DP shrinks
    with pytest.raises(ValueError):
        usable_mesh_shape(8, 16)
    mesh = elastic_remesh(jax.devices(), model_parallel=1)
    assert mesh.shape["model"] == 1


def test_elastic_reshard_checkpoint_roundtrip():
    """Checkpoint saved under one sharding restores under another mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg, model, state, data, step = _setup()
    with tempfile.TemporaryDirectory() as d:
        store.save(state, d, 0)
        mesh = elastic_remesh(jax.devices(), model_parallel=1)
        like = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
        sh = jax.tree.map(lambda l: NamedSharding(mesh, P()), like)
        restored, _ = store.restore(d, like, shardings=sh)
        np.testing.assert_array_equal(
            np.asarray(restored.params["embed"]), np.asarray(state.params["embed"]))


def test_gradient_compression_error_feedback():
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    err = compress.init_error(grads)
    # accumulated dequantized updates converge to true sum (error feedback)
    total_q = jnp.zeros((64, 64))
    for _ in range(50):
        q, scales, err = compress.compress_with_feedback(grads, err)
        total_q = total_q + compress.dequantize(q, scales)["w"]
    total_true = grads["w"] * 50
    rel = float(jnp.linalg.norm(total_q - total_true) / jnp.linalg.norm(total_true))
    assert rel < 0.01, f"error feedback did not converge: {rel}"


def test_quantize_roundtrip_bounded_error():
    rng = np.random.default_rng(1)
    tree = {"a": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
    q, s = compress.quantize(tree)
    deq = compress.dequantize(q, s)
    err = float(jnp.max(jnp.abs(deq["a"] - tree["a"])))
    assert err <= float(s["a"]) * 0.5 + 1e-6


def test_serving_engine_greedy_deterministic():
    cfg, model, state, data, step = _setup()
    eng = Engine(model, state.params,
                 ServeConfig(batch=2, max_len=48, max_new_tokens=6))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    a = eng.generate(prompts.copy())
    b = eng.generate(prompts.copy())
    assert np.array_equal(a, b)
    assert a.shape == (2, 6)


def test_data_pipeline_stateless_replay():
    d = SyntheticTokens(DataConfig(vocab=1000, seq_len=16, global_batch=4))
    b1, b2 = d.batch(7), d.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d.batch(7)["tokens"], d.batch(8)["tokens"])
    # per-host sharding: hosts see disjoint streams
    h0 = SyntheticTokens(DataConfig(1000, 16, 4), host_id=0, n_hosts=2)
    h1 = SyntheticTokens(DataConfig(1000, 16, 4), host_id=1, n_hosts=2)
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])
    assert h0.batch(0)["tokens"].shape == (2, 16)


def test_labels_are_next_token():
    d = SyntheticTokens(DataConfig(vocab=50, seq_len=8, global_batch=2))
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_zero1_opt_state_specs():
    """ZeRO-1: optimizer m/v get an extra data-axis shard on a divisible dim."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    cfg = get_reduced("qwen3_1_7b")
    model = build_model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    mesh = make_host_mesh(1)
    from repro.models import param_pspecs
    aparams = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                           state.params)
    pspecs = param_pspecs(aparams, cfg, mesh)
    ospecs = adamw.opt_state_pspecs(state.opt, pspecs, mesh)
    m_specs = jax.tree.leaves(ospecs.m, is_leaf=lambda x: isinstance(x, P))
    assert any("data" in str(s) for s in m_specs), \
        "no m/v leaf picked up the data axis"
