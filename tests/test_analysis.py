"""repro.analysis mutation suite: each test seeds one defect class into a
known-good artifact and asserts the verifier reports the exact RA0xx code —
plus zero-findings checks on clean graphs/plans/configs, the compiler's
verify= gate, cache-replay demotion, disk corruption handling, tuner
diagnostics, and the KV conservation audit."""

import json

import numpy as np
import pytest

from conftest import make_mlp_norm_graph, make_softmax_graph

from repro.analysis import (CODES, Finding, VerificationError, audit_kv,
                            check_donation, errors, snapshot, summarize,
                            verify_compiled, verify_graph, verify_plan,
                            verify_record, warnings_)
from repro.core import GraphBuilder, OpKind, OpNode
from repro.core.compiler import StitchCompiler


def codes(findings):
    return {f.code for f in findings}


def _chain():
    """p -> a -> b -> c elementwise chain (cycle/cover fixtures)."""
    b = GraphBuilder("chain")
    p = b.param("p", (32, 64))
    a = b.ew("relu", p)
    x = b.ew("exp", a)
    c = b.ew("neg", x)
    return b.build(outputs=[c]), (a, x, c)


# ---------------------------------------------------------------------------
# pass 1: IR verifier
# ---------------------------------------------------------------------------

class TestVerifyGraph:
    def test_clean_graphs_have_no_findings(self):
        g, _, _ = make_softmax_graph()
        assert verify_graph(g) == []
        assert verify_graph(make_mlp_norm_graph()) == []

    def test_ra001_use_before_def(self):
        g, _, _ = make_softmax_graph()
        # bypass Graph.add's operand check, as a disk loader would
        g.nodes["ghostly"] = OpNode("ghostly", OpKind.ELEMENTWISE, (64, 256),
                                    "float32", ("never_defined",),
                                    {"op": "relu"})
        fs = verify_graph(g)
        assert "RA001" in codes(fs)
        assert any(f.node == "ghostly" for f in fs)

    def test_ra002_cycle(self):
        g, (a, x, c) = _chain()
        g.nodes[a].operands = (c,)          # close the loop a -> x -> c -> a
        assert "RA002" in codes(verify_graph(g))

    def test_ra003_missing_output(self):
        g, _, _ = make_softmax_graph()
        g.outputs.append("not_a_node")
        assert "RA003" in codes(verify_graph(g))

    def test_ra004_bad_dtype(self):
        g, _, y = make_softmax_graph()
        g.nodes[y].dtype = "float1337"
        assert "RA004" in codes(verify_graph(g))

    def test_ra005_dead_node_is_warning(self):
        g, _, _ = make_softmax_graph()
        b = GraphBuilder("x")  # noqa: F841 - naming only
        g.nodes["orphan"] = OpNode("orphan", OpKind.ELEMENTWISE, (64, 256),
                                   "float32", ("x",), {"op": "relu"})
        fs = verify_graph(g)
        assert "RA005" in codes(fs)
        assert not errors(fs)               # WARN only

    def test_ra010_elementwise_shape_mismatch(self):
        g, _, y = make_softmax_graph()
        g.nodes[y].shape = (64, 128)        # operands say (64, 256)
        fs = verify_graph(g)
        assert "RA010" in codes(fs)
        assert any(f.node == y for f in fs)

    def test_ra011_broadcast_dims(self):
        g, _, _ = make_softmax_graph()
        g.nodes["bcast"].attrs["bcast_dims"] = (1,)   # (64,) -> dim 1 of (64,256)
        assert "RA011" in codes(verify_graph(g))

    def test_ra012_reshape_count(self):
        b = GraphBuilder("r")
        x = b.param("x", (8, 8))
        r = b.reshape(x, (8, 8))
        g = b.build(outputs=[r])
        g.nodes[r].shape = (8, 9)
        assert "RA012" in codes(verify_graph(g))

    def test_ra013_transpose_perm(self):
        b = GraphBuilder("t")
        x = b.param("x", (4, 8))
        t = b.transpose(x, (1, 0))
        g = b.build(outputs=[t])
        g.nodes[t].attrs["perm"] = (0, 0)
        assert "RA013" in codes(verify_graph(g))

    def test_ra014_reduce_axes(self):
        g, _, _ = make_softmax_graph()
        g.nodes["reduce_max"].attrs["axes"] = (5,)
        assert "RA014" in codes(verify_graph(g))

    def test_ra015_dot_dims(self):
        g = make_mlp_norm_graph()
        g.nodes["w"].shape = (123, 256)     # contraction extent mismatch
        assert "RA015" in codes(verify_graph(g))

    def test_ra016_slice_bounds(self):
        b = GraphBuilder("s")
        x = b.param("x", (16, 16))
        s = b.slice_(x, (0, 0), (8, 8))
        g = b.build(outputs=[s])
        g.nodes[s].attrs["limits"] = (8, 99)
        assert "RA016" in codes(verify_graph(g))

    def test_ra017_gather_shape(self):
        b = GraphBuilder("g")
        t = b.param("table", (100, 32))
        ix = b.param("ix", (4, 7), dtype="int32")
        ga = b.gather(t, ix)
        g = b.build(outputs=[ga])
        g.nodes[ga].shape = (4, 7, 31)
        assert "RA017" in codes(verify_graph(g))


# ---------------------------------------------------------------------------
# pass 2: plan verifier
# ---------------------------------------------------------------------------

class TestVerifyPlan:
    def test_clean_full_cover(self):
        g, _, _ = make_softmax_graph()
        members = frozenset(n.name for n in g.compute_nodes())
        assert verify_plan(g, [members], require_cover=True) == []

    def test_ra020_member_not_in_graph(self):
        g, _, _ = make_softmax_graph()
        fs = verify_plan(g, [frozenset({"reduce_max", "no_such_node"})])
        assert "RA020" in codes(fs)

    def test_ra021_overlapping_groups(self):
        g, _, _ = make_softmax_graph()
        fs = verify_plan(g, [frozenset({"reduce_max", "bcast"}),
                             frozenset({"bcast", "sub"})])
        assert "RA021" in codes(fs)
        assert any(f.node == "bcast" for f in fs)

    def test_ra022_uncovered_requires_cover(self):
        g, _, _ = make_softmax_graph()
        fs = verify_plan(g, [frozenset({"reduce_max"})], require_cover=True)
        assert "RA022" in codes(fs)
        # ...but the compiler's pre-tune call tolerates partial plans
        assert "RA022" not in codes(verify_plan(g, [frozenset({"reduce_max"})]))

    def test_ra023_induced_cycle(self):
        g, (a, x, c) = _chain()
        fs = verify_plan(g, [frozenset({a, c}), frozenset({x})])
        assert "RA023" in codes(fs)

    def test_ra023_cycle_through_uncovered_singleton(self):
        # the middle node is NOT in any group: it still executes as an
        # implicit singleton kernel, so the cycle must be caught pre-cover
        g, (a, x, c) = _chain()
        fs = verify_plan(g, [frozenset({a, c})])
        assert "RA023" in codes(fs)

    def test_ra024_scratch_over_budget(self):
        g, _, _ = make_softmax_graph()
        c = StitchCompiler(use_pallas=False)
        members = frozenset(n.name for n in g.compute_nodes())
        from repro.core.pattern import FusionPattern
        req = sum(c.cost.scratch_request(FusionPattern(g, members)).values())
        assert req > 0                       # fixture sanity
        fs = verify_plan(g, [members], scratch_budget=req - 1, cost=c.cost)
        assert "RA024" in codes(fs)
        assert verify_plan(g, [members], scratch_budget=req, cost=c.cost) == []

    def test_ra025_unregistered_custom_in_fused_group(self):
        b = GraphBuilder("c")
        x = b.param("x", (32, 64))
        cu = b.custom("mystery", (32, 64), "float32", (x,),
                      kernel="definitely_not_registered")
        y = b.ew("relu", cu)
        g = b.build(outputs=[y])
        fs = verify_plan(g, [frozenset({cu, y})])
        assert "RA025" in codes(fs)
        # a singleton custom group is fine: nothing is stitched around it
        assert "RA025" not in codes(verify_plan(g, [frozenset({cu})]))

    def test_ra027_source_node_in_group(self):
        g, x, _ = make_softmax_graph()
        fs = verify_plan(g, [frozenset({x, "reduce_max"})])
        assert "RA027" in codes(fs)


class TestVerifyPacks:
    """Horizontal packs: provenance must be disjoint+covering (RA060), truly
    independent (RA061), and within the register budget (RA062)."""

    def _twins(self):
        b = GraphBuilder("twins")
        p0 = b.param("p0", (8, 64))
        p1 = b.param("p1", (8, 64))
        a1 = b.ew("exp", p0)
        a2 = b.ew("neg", a1)
        c1 = b.ew("exp", p1)
        c2 = b.ew("neg", c1)
        return b.build(outputs=[a2, c2]), (a1, a2, c1, c2)

    def test_clean_pack_has_no_findings(self):
        from repro.analysis.plan import GroupView
        g, (a1, a2, c1, c2) = self._twins()
        v = GroupView({a1, a2, c1, c2}, "pallas",
                      pack=(frozenset({a1, a2}), frozenset({c1, c2})))
        assert verify_plan(g, [v]) == []

    def test_ra060_overlap_and_cover(self):
        from repro.analysis.plan import GroupView
        g, (a1, a2, c1, c2) = self._twins()
        overlapping = GroupView({a1, a2, c1, c2}, "pallas",
                                pack=(frozenset({a1, a2, c1}),
                                      frozenset({c1, c2})))
        assert "RA060" in codes(verify_plan(g, [overlapping]))
        short = GroupView({a1, a2, c1, c2}, "pallas",
                          pack=(frozenset({a1, a2}), frozenset({c1})))
        assert "RA060" in codes(verify_plan(g, [short]))

    def test_ra061_cross_subgraph_dependence(self):
        from repro.analysis.plan import GroupView
        g, (a1, a2, c1, c2) = self._twins()
        # mis-assign a1's consumer a2 to the other subgraph: the a1 -> a2
        # edge now crosses packed subgraphs
        v = GroupView({a1, a2, c1, c2}, "pallas",
                      pack=(frozenset({a1, c1}), frozenset({a2, c2})))
        assert "RA061" in codes(verify_plan(g, [v]))

    def test_ra062_register_budget(self):
        from repro.analysis.plan import GroupView
        from repro.core import CostModel
        g, (a1, a2, c1, c2) = self._twins()
        v = GroupView({a1, a2, c1, c2}, "pallas",
                      pack=(frozenset({a1, a2}), frozenset({c1, c2})))
        cost = CostModel()
        fs = verify_plan(g, [v], cost=cost, reg_budget=1)
        assert "RA062" in codes(fs)
        assert verify_plan(g, [v], cost=cost,
                           reg_budget=cost.reg_budget) == []


# ---------------------------------------------------------------------------
# pass 3: donation/aliasing
# ---------------------------------------------------------------------------

class TestCheckDonation:
    def test_clean(self):
        # under the whole-graph fused plan, x's two readers share one group
        g, x, _ = make_softmax_graph()
        members = frozenset(n.name for n in g.compute_nodes())
        assert check_donation(g, [x], groups=[members]) == []

    def test_ra030_donated_aliases_output(self):
        g, x, _ = make_softmax_graph()
        g.mark_output(x)
        fs = check_donation(g, [x])
        assert "RA030" in codes(fs)

    def test_ra031_donated_read_after_donating_group(self):
        b = GraphBuilder("d")
        x = b.param("x", (8, 8))
        a = b.ew("relu", x)
        e = b.ew("exp", a)
        c = b.ew("add", x, e)               # second read of x, 2 groups later
        g = b.build(outputs=[c])
        fs = check_donation(g, [x])
        assert "RA031" in codes(fs)
        # under a plan that fuses both readers into one group, it's safe
        assert check_donation(g, [x], groups=[frozenset({a, e, c})]) == []

    def test_ra032_unknown_or_unread_donation_warns(self):
        g, _, _ = make_softmax_graph()
        fs = check_donation(g, ["not_an_input"])
        assert codes(fs) == {"RA032"}
        assert not errors(fs)


# ---------------------------------------------------------------------------
# pass 4: KV/refcount audit
# ---------------------------------------------------------------------------

class TestKVAudit:
    def _clean_snap(self):
        from repro.analysis import KVSnapshot
        return KVSnapshot(num_pages=5, free=[4, 3], refs={1: 1, 2: 1},
                          slot_pages=[[1, 2]], table=[[1, 2, 0]],
                          slot_lengths=[20], page_size=16)

    def test_clean(self):
        assert audit_kv(self._clean_snap()) == []

    def test_ra043_leaked_page(self):
        snap = self._clean_snap()
        snap.free = [4]
        snap.refs[3] = 1                    # refcounted, owned by nobody
        fs = audit_kv(snap)
        assert codes(fs) == {"RA043"}
        assert fs[0].page == 3

    def test_ra044_double_owned(self):
        snap = self._clean_snap()
        snap.slot_pages = [[1, 2], [1]]     # slot 1 also claims page 1
        snap.table = None
        fs = audit_kv(snap)
        assert "RA044" in codes(fs)

    def test_ra041_free_and_allocated(self):
        snap = self._clean_snap()
        snap.free = [4, 3, 2]               # page 2 also refcounted
        assert "RA041" in codes(audit_kv(snap))

    def test_ra046_owned_but_free(self):
        snap = self._clean_snap()
        del snap.refs[2]
        snap.free = [4, 3, 2]               # slot still points at page 2
        fs = audit_kv(snap)
        assert "RA046" in codes(fs)

    def test_ra040_lost_page(self):
        snap = self._clean_snap()
        snap.free = [4]                     # page 3 vanished entirely
        assert "RA040" in codes(audit_kv(snap))

    def test_ra047_table_row_mismatch(self):
        snap = self._clean_snap()
        snap.table = [[2, 1, 0]]            # order flipped vs slot_pages
        assert "RA047" in codes(audit_kv(snap))

    def test_live_allocator_roundtrip(self):
        from repro.serve.kv import PageAllocator
        alloc = PageAllocator(8)
        pages = alloc.alloc(3)
        assert audit_kv(snapshot(allocator=alloc)) == []  # bare allocator
        alloc.free(pages[:1])
        assert audit_kv(snapshot(allocator=alloc)) == []
        # seed a leak: refcount with no free-list entry survives
        alloc._refs[pages[0]] = 1
        fs = audit_kv(snapshot(allocator=alloc))
        assert "RA041" in codes(fs)         # freed page now also refcounted

    def test_live_paged_engine_audit(self):
        jax = pytest.importorskip("jax")
        from repro.configs import get_reduced
        from repro.models import build_model
        from repro.serve import Engine, ServeConfig

        cfg = get_reduced("qwen3_1_7b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params,
                     ServeConfig(batch=2, max_len=32, debug_kv=True))
        assert eng.paged
        rng = np.random.default_rng(0)
        px = eng.prefill(rng.integers(0, cfg.vocab, (1, 8)))
        eng.insert(px, slot=0)
        eng.generate_step(steps=2)
        assert eng.audit_kv() == []
        eng.release(0)                      # debug_kv audits here
        assert eng.audit_kv() == []
        # seed a leak and watch release() trip the debug audit
        px2 = eng.prefill(rng.integers(0, cfg.vocab, (1, 8)))
        eng.insert(px2, slot=1)
        leaked = eng.kv.allocator.alloc(1)  # refcounted, no owner
        with pytest.raises(VerificationError) as ei:
            eng.release(1)
        assert "RA043" in ei.value.codes
        assert leaked[0] in {f.page for f in ei.value.findings}


# ---------------------------------------------------------------------------
# compiler gate
# ---------------------------------------------------------------------------

class TestCompilerGate:
    def test_clean_compile_records_summary(self):
        g, _, _ = make_softmax_graph()
        cg = StitchCompiler(use_pallas=False).compile(g)
        assert cg.stats.verify == {"errors": 0, "warnings": 0, "codes": []}
        assert cg.stats.verify_seconds > 0

    def test_verify_off_skips(self):
        g, _, _ = make_softmax_graph()
        cg = StitchCompiler(use_pallas=False, verify="off").compile(g)
        assert cg.stats.verify is None
        assert cg.stats.verify_seconds == 0.0

    def test_rejects_overlapping_plan(self):
        g, _, _ = make_softmax_graph()
        c = StitchCompiler(use_pallas=False)
        from repro.core.pattern import FusionPattern
        bad = [FusionPattern(g, {"reduce_max", "bcast"}),
               FusionPattern(g, {"bcast", "sub"})]
        c.plan = lambda graph: (bad, None)
        with pytest.raises(VerificationError) as ei:
            c.compile(g)
        assert "RA021" in ei.value.codes

    def test_rejects_cyclic_plan(self):
        g, (a, x, c_) = _chain()
        c = StitchCompiler(use_pallas=False)
        from repro.core.pattern import FusionPattern
        c.plan = lambda graph: ([FusionPattern(g, {a, c_})], None)
        with pytest.raises(VerificationError) as ei:
            c.compile(g)
        assert "RA023" in ei.value.codes

    def test_full_mode_rejects_bad_graph(self):
        g, _, y = make_softmax_graph()
        g.nodes[y].shape = (64, 128)
        c = StitchCompiler(use_pallas=False, verify="full")
        with pytest.raises(VerificationError) as ei:
            c.compile(g)
        assert "RA010" in ei.value.codes
        # plans-level verification does not inspect node shapes
        assert StitchCompiler(use_pallas=False).compile(g) is not None

    def test_every_mode_verifies_clean(self):
        g = make_mlp_norm_graph()
        for mode in ("off", "xla", "stitch"):
            cg = StitchCompiler(mode=mode, use_pallas=False,
                                verify="full").compile(g)
            assert cg.stats.verify["errors"] == 0


# ---------------------------------------------------------------------------
# disk records: corruption + replay verification
# ---------------------------------------------------------------------------

def _cached_compile(tmp_path, g):
    from repro.cache import StitchCache
    cache = StitchCache(directory=str(tmp_path))
    comp = StitchCompiler(use_pallas=False, cache=cache)
    cg = comp.compile(g)
    files = sorted(tmp_path.glob("plan_*.json"))
    assert len(files) == 1
    return cache, comp, cg, files[0]


class TestDiskRecords:
    @pytest.mark.parametrize("poison", [
        "truncate", "garbage", "wrong_type", "bad_body"])
    def test_corrupt_record_is_a_miss_with_one_warning(self, tmp_path, poison):
        from repro.cache import StitchCache
        g, _, _ = make_softmax_graph()
        _, _, cg_cold, path = _cached_compile(tmp_path, g)
        text = path.read_text()
        if poison == "truncate":
            path.write_text(text[: len(text) // 2])
        elif poison == "garbage":
            path.write_text("not json at all {{{")
        elif poison == "wrong_type":
            path.write_text("[1, 2, 3]")
        else:
            d = json.loads(text)
            d["groups"] = "oops"            # right version, wrong-typed body
            path.write_text(json.dumps(d))
        cache2 = StitchCache(directory=str(tmp_path))
        comp2 = StitchCompiler(use_pallas=False, cache=cache2)
        with pytest.warns(RuntimeWarning, match="corrupt plan record"):
            cg = comp2.compile(g)           # never raises into the compile
        assert cg.stats.cache_status == "miss"
        assert cg.stats.n_kernels == cg_cold.stats.n_kernels
        assert cache2.store.disk.corrupt_reads == 1
        rep = cache2.report()
        assert rep["total_corrupt"] == 1
        assert rep["disk_corrupt_reads"] == 1
        # the recompile overwrote the bad file: fresh cache now hits cleanly
        from repro.cache import StitchCache as SC
        cache3 = SC(directory=str(tmp_path))
        comp3 = StitchCompiler(use_pallas=False, cache=cache3)
        assert comp3.compile(g).stats.cache_status == "hit"
        assert cache3.store.disk.corrupt_reads == 0

    def test_stale_version_is_a_silent_miss(self, tmp_path):
        g, _, _ = make_softmax_graph()
        from repro.cache import StitchCache
        _, _, _, path = _cached_compile(tmp_path, g)
        d = json.loads(path.read_text())
        d["v"] = 1
        path.write_text(json.dumps(d))
        cache2 = StitchCache(directory=str(tmp_path))
        comp2 = StitchCompiler(use_pallas=False, cache=cache2)
        import warnings as w
        with w.catch_warnings():
            w.simplefilter("error")         # any warning fails the test
            assert comp2.compile(g).stats.cache_status == "miss"
        assert cache2.store.disk.corrupt_reads == 0

    def test_replay_verification_demotes_illegal_record(self, tmp_path):
        from repro.cache import StitchCache
        g, _, _ = make_softmax_graph()
        _, _, _, path = _cached_compile(tmp_path, g)
        d = json.loads(path.read_text())
        # valid JSON, valid key, illegal plan: duplicate the first group
        d["groups"].append(dict(d["groups"][0]))
        path.write_text(json.dumps(d))
        cache2 = StitchCache(directory=str(tmp_path))
        comp2 = StitchCompiler(use_pallas=False, cache=cache2)
        with pytest.warns(RuntimeWarning, match="failed static verification"):
            cg = comp2.compile(g)
        assert cg.stats.cache_status == "miss"
        rep = cache2.report()
        assert rep["total_demoted"] == 1
        assert rep["total_corrupt"] == 0

    def test_verify_off_skips_replay_verification(self, tmp_path):
        from repro.cache import StitchCache
        g, _, _ = make_softmax_graph()
        _, _, _, path = _cached_compile(tmp_path, g)
        d = json.loads(path.read_text())
        d["groups"].append(dict(d["groups"][0]))
        path.write_text(json.dumps(d))
        cache2 = StitchCache(directory=str(tmp_path))
        comp2 = StitchCompiler(use_pallas=False, cache=cache2, verify="off")
        cg = comp2.compile(g)               # replays the overlapping plan
        assert cache2.report()["total_demoted"] == 0
        assert cg is not None

    def test_clean_replay_verifies_with_zero_findings(self, tmp_path):
        from repro.cache import StitchCache
        from repro.cache.signature import compute_signature
        g, _, _ = make_softmax_graph()
        _, comp, _, _ = _cached_compile(tmp_path, g)
        cache2 = StitchCache(directory=str(tmp_path))
        comp2 = StitchCompiler(use_pallas=False, cache=cache2)
        sig = compute_signature(g)
        key = cache2.key_for(sig, "stitch", comp2.hw.name, "",
                             __import__("repro.cache.signature",
                                        fromlist=["config_key"]
                                        ).config_key(comp2.gen_cfg))
        rec = cache2.store.get(key)
        assert rec is not None
        budget = comp2.gen_cfg.scratch_budget or comp2.hw.onchip_budget
        fs = verify_record(g, sig.canon_order, rec,
                           scratch_budget=budget, cost=comp2.cost)
        assert fs == []
        assert comp2.compile(g).stats.cache_status == "hit"
        assert cache2.report()["total_demoted"] == 0

    def test_ra050_node_count_mismatch(self):
        g, _, _ = make_softmax_graph()
        from repro.cache.store import GroupRecord, PlanRecord
        rec = PlanRecord(graph_key="x", bucket_key="y", shape_key="z",
                         mode="stitch", hw="tpu", n_nodes=99,
                         groups=(GroupRecord((0, 1), "jnp"),))
        fs = verify_record(g, [n for n in g.nodes], rec)
        assert codes(fs) == {"RA050"}

    def test_ra028_bad_group_kind(self):
        g, _, _ = make_softmax_graph()
        from repro.cache.store import GroupRecord, PlanRecord
        names = list(g.nodes)
        rec = PlanRecord(graph_key="x", bucket_key="y", shape_key="z",
                         mode="stitch", hw="tpu", n_nodes=len(names),
                         groups=(GroupRecord((0,), "frobnicate"),))
        assert "RA028" in codes(verify_record(g, names, rec))


# ---------------------------------------------------------------------------
# tuner diagnostics (the former silent StitchInfeasible swallows)
# ---------------------------------------------------------------------------

class TestTunerDiagnostics:
    def _infeasible_pattern(self):
        # square shape: rows=64 is the only candidate row dimension, and
        # under it the transpose moves the row axis -> always infeasible
        b = GraphBuilder("t")
        x = b.param("x", (64, 64))
        t = b.transpose(x, (1, 0))
        y = b.ew("relu", t)
        g = b.build(outputs=[y])
        from repro.core.pattern import FusionPattern
        return g, FusionPattern(g, {t, y})

    def test_tune_records_reason(self):
        from repro.core.tuner import TemplateTuner
        _, p = self._infeasible_pattern()
        tuner = TemplateTuner()
        assert tuner.tune(p) is None
        assert len(tuner.diagnostics) == 1
        d = tuner.diagnostics[0]
        assert d["stage"] == "analyze"
        assert "row axis" in d["reason"]
        assert d["n_members"] == 2

    def test_instantiate_records_reason(self):
        from repro.core.tuner import TemplateTuner
        _, p = self._infeasible_pattern()
        tuner = TemplateTuner()
        assert tuner.instantiate(p) is None
        assert tuner.diagnostics and tuner.diagnostics[0]["stage"] == "analyze"

    def test_diagnostics_flow_into_stats_and_report(self):
        g, p = self._infeasible_pattern()
        c = StitchCompiler()                # use_pallas=True: tuning runs
        c.plan = lambda graph: ([p], None)
        cg = c.compile(g)
        assert cg.stats.diagnostics, "infeasible pattern left no diagnostic"
        assert cg.stats.diagnostics[0]["stage"] == "analyze"
        # and the group degraded to fused-jnp, numerics preserved
        assert all(grp.kind != "pallas" for grp in cg.groups)

    def test_diagnostics_bounded(self):
        from repro.core.tuner import TemplateTuner
        _, p = self._infeasible_pattern()
        tuner = TemplateTuner()
        tuner.MAX_DIAGNOSTICS = 10
        for _ in range(25):
            tuner.instantiate(p)
        assert len(tuner.diagnostics) == 10

    def test_stitched_function_report_has_diagnostics_key(self):
        jax = pytest.importorskip("jax")
        import jax.numpy as jnp
        from repro.exec import stitch

        @stitch
        def f(a):
            return jnp.tanh(a) * 2.0

        f(jnp.ones((8, 8)))
        rep = f.report()
        assert isinstance(rep["diagnostics"], list)
        from repro.obs import validate_exec_report
        assert validate_exec_report(rep) == []


# ---------------------------------------------------------------------------
# zero findings on every bundled config (the acceptance sweep)
# ---------------------------------------------------------------------------

def _arch_names():
    from repro.configs import ARCHS
    return list(ARCHS)


@pytest.mark.parametrize("arch", _arch_names())
def test_bundled_config_verifies_clean(arch):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.core.trace import trace_to_graph
    from repro.models import build_model

    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros((2, cfg.n_patch_tokens, cfg.d_model),
                                          cfg.dtype)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)),
                                      cfg.dtype)
    # full (loss, metrics) output — loss-only tracing leaves the metrics'
    # nodes dead, which the IR pass correctly flags as RA005 warnings
    g, _ = trace_to_graph(lambda p: model.train_forward(p, batch),
                          params, name=arch)
    comp = StitchCompiler(use_pallas=False)   # verify="plans" gates compile
    cg = comp.compile(g)
    assert cg.stats.verify["errors"] == 0
    budget = comp.gen_cfg.scratch_budget
    if budget is None:
        budget = comp.hw.onchip_budget
    fs = verify_compiled(cg, scratch_budget=budget, cost=comp.cost)
    assert fs == [], summarize(fs)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestCLI:
    def test_cache_dir_audit(self, tmp_path):
        from repro.analysis.__main__ import audit_cache_dir, main
        g, _, _ = make_softmax_graph()
        _cached_compile(tmp_path, g)
        (tmp_path / "plan_dead.json").write_text("{broken")
        results = audit_cache_dir(str(tmp_path))
        assert len(results) == 2
        bad = results["plan_dead.json"]
        assert codes(bad) == {"RA050"}
        good = [fs for name, fs in results.items() if name != "plan_dead.json"]
        assert good == [[]]
        assert main(["--cache-dir", str(tmp_path)]) == 1
        (tmp_path / "plan_dead.json").unlink()
        assert main(["--cache-dir", str(tmp_path)]) == 0

    def test_json_output(self, tmp_path, capsys):
        from repro.analysis.__main__ import main
        g, _, _ = make_softmax_graph()
        _cached_compile(tmp_path, g)
        assert main(["--cache-dir", str(tmp_path), "--json"]) == 0
        doc = json.loads(capsys.readouterr().out)
        assert doc["summary"] == {"errors": 0, "warnings": 0, "codes": []}

    def test_inspect_verify_delegates(self, tmp_path, capsys):
        from repro.launch.inspect import main as inspect_main
        g, _, _ = make_softmax_graph()
        _cached_compile(tmp_path, g)
        with pytest.raises(SystemExit) as ei:
            inspect_main(["verify", "--cache-dir", str(tmp_path)])
        assert ei.value.code == 0
        assert "clean" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# findings plumbing
# ---------------------------------------------------------------------------

def test_analysis_imports_without_jax(tmp_path):
    """The package contract: the verifier and the cache-record audit run in
    a process where any jax import raises."""
    import subprocess
    import sys
    g, _, _ = make_softmax_graph()
    _cached_compile(tmp_path, g)
    script = (
        "import sys\n"
        "sys.modules['jax'] = None\n"
        "from repro.analysis import audit_kv, verify_graph, verify_plan\n"
        "from repro.analysis.__main__ import main\n"
        f"raise SystemExit(main(['--cache-dir', {str(tmp_path)!r}]))\n")
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin"},
                          cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr
    assert "1/1 clean" in proc.stdout


class TestFindings:
    def test_every_code_documented(self):
        for code in CODES:
            assert code.startswith("RA") and len(code) == 5

    def test_severity_derivation(self):
        assert Finding("RA005", "x").severity == "warning"
        assert Finding("RA021", "x").severity == "error"

    def test_summarize_and_filters(self):
        fs = [Finding("RA005", "dead"), Finding("RA021", "dup", group=1)]
        assert summarize(fs) == {"errors": 1, "warnings": 1,
                                 "codes": ["RA005", "RA021"]}
        assert [f.code for f in errors(fs)] == ["RA021"]
        assert [f.code for f in warnings_(fs)] == ["RA005"]

    def test_verification_error_carries_findings(self):
        err = VerificationError("nope", [Finding("RA021", "dup")])
        assert err.codes == {"RA021"}
        assert "RA021" in str(err)
