"""repro.obs — span tracing, metrics registry, measured timer, and the
unified exec-report schema.

Contract under test: span nesting and Chrome-trace schema validity (every
exported event Perfetto-loadable), registry snapshot round-trip through
JSON and Prometheus text, the disabled fast path costing nothing and
recording nothing, the one shared percentile implementation, the
``repro.obs/exec-report@1`` schema across engine / train step / optimizer,
and an end-to-end trace of a tiny stitched serve run where the
fallback→stitched upgrade event lands *after* the compile-land event.
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.cache import CompilationService
from repro.exec import stitch
from repro.obs.metrics import Histogram, MetricsRegistry, percentiles
from repro.obs.trace import Tracer


@pytest.fixture
def traced():
    """Enable the process tracer for one test, clean before and after."""
    obs.clear_trace()
    obs.enable_tracing()
    yield obs.tracer
    obs.disable_tracing()
    obs.clear_trace()


@pytest.fixture
def svc():
    # max_background=0: upgrades land only when the test compiles them —
    # deterministic miss-then-upgrade points
    return CompilationService(max_background=0)


# ---------------------------------------------------------------------------
# tracer: spans, events, Chrome-trace export
# ---------------------------------------------------------------------------

def test_span_nesting_and_ordering():
    tr = Tracer(enabled=True)
    with tr.span("outer", cat="test", k=1):
        with tr.span("inner", cat="test"):
            time.sleep(0.001)
        tr.event("marker", cat="test", x=7)
    evs = tr.events()
    names = [e["name"] for e in evs]
    # spans record at exit: inner closes before outer; the instant marker
    # fires between them
    assert names == ["inner", "marker", "outer"]
    inner, marker, outer = evs
    assert inner["ph"] == "X" and outer["ph"] == "X" and marker["ph"] == "i"
    # the inner interval nests inside the outer one
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert outer["args"] == {"k": 1}
    assert marker["args"] == {"x": 7}


def test_span_set_attaches_args_discovered_mid_span():
    tr = Tracer(enabled=True)
    with tr.span("stage", cat="test", fixed=1) as s:
        s.set(found=42)
    (ev,) = tr.events()
    assert ev["args"] == {"fixed": 1, "found": 42}


def test_chrome_trace_schema_and_json_validity(tmp_path):
    """Every exported event carries the Chrome trace-event required fields
    and the whole document survives a JSON round-trip (Perfetto-loadable)."""
    tr = Tracer(enabled=True)
    with tr.span("compile.graph", cat="compile", graph="g"):
        tr.event("cache.miss", cat="cache")
    tr.counter_event("serve.slots", active=3, free=1)
    path = tr.save(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    events = doc["traceEvents"]
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in events)
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in events)
    for e in events:
        for key in ("ph", "ts", "pid", "tid", "name"):
            assert key in e, f"event {e} missing {key}"
        assert e["ph"] in ("X", "i", "C", "M")
        if e["ph"] == "X":
            assert e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "t"


def test_disabled_tracer_is_free_and_records_nothing():
    """The no-op contract: with tracing off, span()/event() must be cheap
    (shared null span, single attribute check) and record zero events."""
    tr = Tracer(enabled=False)
    s1 = tr.span("a", x=1)
    s2 = tr.span("b")
    assert s1 is s2                      # the one shared NULL_SPAN
    t0 = time.perf_counter()
    for _ in range(100_000):
        with tr.span("hot"):
            pass
        tr.event("hot")
    assert time.perf_counter() - t0 < 1.0    # generous absolute bound
    assert len(tr) == 0
    # the module-level façade takes the same early exit
    obs.disable_tracing()
    with obs.span("x") as s:
        s.set(anything=1)
    obs.event("y")
    assert len(obs.tracer) == 0


def test_tracer_clear_resets_epoch_and_buffer():
    tr = Tracer(enabled=True)
    tr.event("one")
    assert len(tr) == 1
    tr.clear()
    assert len(tr) == 0 and tr.events() == []


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_percentiles_shared_implementation_edge_cases():
    assert percentiles(()) == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    one = percentiles([3.5])
    assert one == {"p50": 3.5, "p95": 3.5, "p99": 3.5}
    many = percentiles(range(1, 101))
    assert many["p50"] == pytest.approx(50.5)
    assert many["p99"] == pytest.approx(99.01)
    # serve.metrics re-exports the same function (satellite: one impl)
    from repro.serve.metrics import percentiles as serve_pct
    assert serve_pct is percentiles


def test_histogram_summary_and_capacity_bound():
    h = Histogram(capacity=8)
    assert h.summary() == {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                           "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
    for v in range(20):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 20 and s["sum"] == 190.0       # exact despite drops
    assert s["min"] == 0.0 and s["max"] == 19.0
    assert len(h.values) <= 8


def test_registry_snapshot_roundtrip_and_prometheus(tmp_path):
    reg = MetricsRegistry()
    reg.counter("tokens_total").inc(5)
    reg.counter("cache_lookups", result="hit").inc(2)
    reg.gauge("occupancy").set(0.75)
    reg.histogram("step_seconds").observe(0.1)
    reg.histogram("step_seconds").observe(0.3)
    reg.register_provider("stitch", lambda: {"status": "hit"})

    snap = reg.snapshot()
    assert snap["counters"]["tokens_total"] == 5
    assert snap["counters"]['cache_lookups{result="hit"}'] == 2
    assert snap["gauges"]["occupancy"] == 0.75
    assert snap["histograms"]["step_seconds"]["count"] == 2
    assert snap["providers"]["stitch"] == {"status": "hit"}

    path = tmp_path / "metrics.json"
    reg.to_json(str(path), run="t")
    loaded = json.loads(path.read_text())
    assert loaded.pop("run") == "t"
    assert loaded == json.loads(json.dumps(snap))       # round-trip exact

    prom = reg.to_prometheus()
    assert "# TYPE tokens_total counter" in prom
    assert "tokens_total 5" in prom
    assert 'cache_lookups{result="hit"} 2' in prom
    assert "# TYPE step_seconds summary" in prom
    assert "step_seconds_count 2" in prom
    assert 'quantile="0.50"' in prom


def test_registry_kind_clash_and_provider_error():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    reg.register_provider("boom", lambda: 1 / 0)
    snap = reg.snapshot()
    assert "ZeroDivisionError" in snap["providers"]["boom"]["error"]


# ---------------------------------------------------------------------------
# the unified exec-report schema (satellite: one documented shape)
# ---------------------------------------------------------------------------

def _small_fn(x):
    h = x * jax.nn.sigmoid(x)
    return h / (1.0 + jnp.sum(h * h, axis=-1, keepdims=True))


def test_exec_report_schema_stitched_function(svc):
    sf = stitch(_small_fn, service=svc, name="schema_fn")
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 16)),
                    jnp.float32)
    sf(x)
    rep = sf.report()
    assert obs.validate_exec_report(rep) == []
    assert rep["schema"] == obs.EXEC_REPORT_SCHEMA
    assert rep["name"] == "schema_fn"
    assert rep["calls"] == {"stitched": 1, "fallback": 0, "jit": 0}
    # compat aliases stay in sync
    assert rep["stitched_calls"] == rep["calls"]["stitched"]
    assert rep["errors"] == {}
    assert rep["cache"]["total_misses"] >= 1
    assert "per_placement" in rep["cache"]


def test_exec_report_schema_uniform_across_callers(svc):
    """Engine (even jit-mode), train step's grad/optimizer, and PackedAdamW
    all report the same schema — dashboards special-case nothing."""
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.optim import AdamWConfig
    from repro.optim.packed import PackedAdamW
    from repro.serve import Engine, ServeConfig

    cfg = get_reduced("qwen3_1_7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(batch=2, max_len=16))
    assert obs.validate_exec_report(eng.stitch_report()) == []   # jit mode

    tiny = {"w": jnp.ones((4, 4), jnp.float32)}
    packed = PackedAdamW(AdamWConfig(lr=1e-3), tiny, use_compiler=False)
    rep = packed.report()
    assert obs.validate_exec_report(rep) == []
    assert rep["status"] == "jnp"
    assert rep["n_leaves"] == 1


def test_exec_report_surfaces_service_errors(svc):
    """A failed background compile shows up in ``errors`` (stringified
    service key -> message), not just the scalar ``service_error``."""
    sf = stitch(_small_fn, service=svc, name="err_fn")
    x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 8)),
                    jnp.float32)
    sf(x)
    sig = svc.cache.signature_of(sf.graph)
    key = svc.cache.key_for(sig, "stitch", svc.hw.name, "")
    svc.errors[key] = "RuntimeError: ILP exploded"
    rep = sf.report()
    assert obs.validate_exec_report(rep) == []
    assert list(rep["errors"].values()) == ["RuntimeError: ILP exploded"]
    assert all(isinstance(k, str) for k in rep["errors"])


# ---------------------------------------------------------------------------
# measured kernel timer
# ---------------------------------------------------------------------------

def test_measured_timer_records_per_path_and_modeled(svc, traced):
    reg = obs.registry()
    reg.clear()
    sf = stitch(_small_fn, service=svc, name="timed_fn")
    x = jnp.asarray(np.random.default_rng(2).standard_normal((8, 16)),
                    jnp.float32)
    sf(x)                                        # untimed warm call
    assert sf.report()["measured"] is None
    obs.enable_timing()
    try:
        for _ in range(3):
            sf(x)
    finally:
        obs.disable_timing()
    rep = sf.report()
    meas = rep["measured"]["stitched"]
    assert meas["count"] == 3 and meas["min"] > 0.0
    # the same numbers landed in the registry and in the trace
    hist = reg.histogram("exec_measured_seconds", fn="timed_fn",
                         path="stitched")
    assert hist.count == 3
    timed = [e for e in obs.tracer.events() if e["name"] == "exec.measured"]
    assert len(timed) == 3
    assert all(e["args"]["path"] == "stitched" for e in timed)


# ---------------------------------------------------------------------------
# e2e: tiny stitched serve run, upgrade strictly after compile-land
# ---------------------------------------------------------------------------

def test_e2e_stitched_serve_trace_upgrade_after_land(traced):
    """The acceptance scenario: a stitched serve run leaves a trace with
    compile-stage spans, a cache hit/miss event per compiled graph,
    per-step decode spans, and a fallback→stitched upgrade event whose
    timestamp is strictly after the compile.land event's."""
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig

    cfg = get_reduced("qwen3_1_7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    svc = CompilationService(max_background=0)   # deterministic upgrade
    eng = Engine(model, params,
                 ServeConfig(batch=2, max_len=32, stitch_execute=True),
                 stitch_service=svc)
    rng = np.random.default_rng(0)
    for _ in range(2):
        eng.submit(rng.integers(0, cfg.vocab, (5,)).astype(np.int32),
                   max_new_tokens=3)
    eng.step()                                   # miss: fallback serves
    assert eng.stitch_status in ("miss", "pending")
    # land the stitch compile (what the background thread would do)
    sp = eng._exec._active
    svc.compiler("stitch", sp.placement).compile(sp.graph,
                                                 bypass_cache_lookup=True)
    # fresh requests decode after the land: their first poll upgrades
    # (with EOS off the scheduler chunked the first batch's whole budget
    # into step one, so new work is what drives post-land decode calls)
    for _ in range(2):
        eng.submit(rng.integers(0, cfg.vocab, (5,)).astype(np.int32),
                   max_new_tokens=3)
    eng.drain()
    assert eng.stitch_status == "hit"

    events = obs.tracer.events()
    names = [e["name"] for e in events]
    assert "compile.graph" in names              # compile-stage spans
    assert "compile.pattern_gen" in names and "compile.ilp" in names
    assert "cache.miss" in names                 # per-graph lookup evidence
    assert "serve.step" in names and "serve.prefill" in names
    assert "serve.evict" in names

    by_name = {}
    for e in events:
        by_name.setdefault(e["name"], []).append(e)
    lands = by_name.get("compile.land", [])
    upgrades = by_name.get("exec.upgrade", [])
    assert lands and upgrades
    decode_land = [e for e in lands if e["args"]["graph"] == "decode_step"]
    assert decode_land
    # the ordering claim: the serving path flipped to stitched only after
    # the compile landed in the cache
    assert min(u["ts"] for u in upgrades) > min(e["ts"] for e in decode_land)

    # the exported document stays schema-valid with real pipeline events
    doc = obs.tracer.chrome_trace()
    for e in doc["traceEvents"]:
        assert {"ph", "ts", "pid", "tid", "name"} <= set(e)
    json.dumps(doc)                              # serializable end-to-end

    # inspect CLI renders both views from this trace without error
    from repro.launch.inspect import compile_timeline, measured_table
    timeline = compile_timeline(events)
    assert any("compile.land" in line for line in timeline)
    assert any("exec.upgrade" in line for line in timeline)
    measured_table(events)                       # no timer on: stub line


def test_serving_latency_summary_keys_always_present():
    """Satellite: ServeMetrics.summary() exposes latency percentiles and
    finish reasons even for an empty run (all-zero, not missing)."""
    from repro.serve.metrics import ServeMetrics
    s = ServeMetrics().summary()
    for key in ("e2e_latency_s", "ttft_s", "queue_latency_s"):
        assert s[key] == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert s["finish_reasons"] == {}
    assert s["tokens_per_sec"] == 0.0
