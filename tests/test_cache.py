"""StitchCache subsystem: signature canonicalization, shape bucketing,
two-tier persistence, plan replay, and the miss-then-upgrade service."""

import time

import numpy as np
import pytest

from repro.cache import (
    BucketPolicy, CompilationService, EvictionPolicy, GroupRecord,
    MemoryStore, PlanRecord, StitchCache, compute_signature,
)
from repro.core import GraphBuilder, StitchCompiler, build_reference_fn
from repro.core.trace import trace_to_graph
from conftest import make_mlp_norm_graph, make_softmax_graph


def _softmax_graph(pname="x", rows=64, cols=256):
    b = GraphBuilder("softmax")
    x = b.param(pname, (rows, cols))
    m = b.reduce("max", x, axes=(1,))
    e = b.ew("exp", b.ew("sub", x, b.bcast(m, (rows, cols), (0,))))
    s = b.reduce("sum", e, axes=(1,))
    y = b.ew("div", e, b.bcast(s, (rows, cols), (0,)))
    return b.build(outputs=[y]), x


# -------------------------------------------------- signatures ---------------

def test_signature_invariant_under_renaming():
    g1, _ = _softmax_graph("x")
    g2, _ = _softmax_graph("completely_different_input_name")
    s1, s2 = compute_signature(g1), compute_signature(g2)
    assert s1.graph_key == s2.graph_key
    assert s1.shape_key == s2.shape_key


def test_signature_invariant_under_insertion_order():
    """Two independent chains inserted in opposite orders (trace-order
    permutation) must produce the same canonical signature."""
    def build(swap):
        b = GraphBuilder("perm")
        x = b.param("x", (32, 64))
        y = b.param("y", (32, 64))
        if swap:
            bb = b.ew("tanh", y)
            aa = b.ew("exp", x)
        else:
            aa = b.ew("exp", x)
            bb = b.ew("tanh", y)
        return b.build(outputs=[b.ew("add", aa, bb)])

    s1, s2 = compute_signature(build(False)), compute_signature(build(True))
    assert s1.graph_key == s2.graph_key
    assert s1.shape_key == s2.shape_key


def test_signature_invariant_under_trace_order():
    import jax.numpy as jnp

    def f1(x, y):
        a = jnp.exp(x)
        b = jnp.tanh(y)
        return a + b

    def f2(x, y):
        b = jnp.tanh(y)
        a = jnp.exp(x)
        return a + b

    x = np.zeros((8, 16), np.float32)
    g1, _ = trace_to_graph(f1, x, x)
    g2, _ = trace_to_graph(f2, x, x)
    assert compute_signature(g1).graph_key == compute_signature(g2).graph_key


def test_signature_distinguishes_structure():
    def build(op, dtype="float32"):
        b = GraphBuilder("g")
        x = b.param("x", (16, 32), dtype)
        y = b.param("y", (16, 32), dtype)
        return b.build(outputs=[b.ew(op, x, y)])

    base = compute_signature(build("add")).graph_key
    assert compute_signature(build("sub")).graph_key != base
    assert compute_signature(build("add", "bfloat16")).graph_key != base
    # operand order matters (sub is not commutative): swapping operands of
    # structurally distinguishable inputs must not collide
    b3 = GraphBuilder("g")
    x3 = b3.param("x", (16, 32))
    e3 = b3.ew("exp", x3)
    gc = b3.build(outputs=[b3.ew("sub", x3, e3)])
    b4 = GraphBuilder("g")
    x4 = b4.param("x", (16, 32))
    e4 = b4.ew("exp", x4)
    gd = b4.build(outputs=[b4.ew("sub", e4, x4)])
    assert compute_signature(gc).graph_key != compute_signature(gd).graph_key


def test_signature_shapes_factored_out():
    g1, _ = _softmax_graph(rows=100)
    g2, _ = _softmax_graph(rows=120)
    s1, s2 = compute_signature(g1), compute_signature(g2)
    assert s1.graph_key == s2.graph_key      # same program
    assert s1.shape_key != s2.shape_key      # different concrete shapes


# -------------------------------------------------- bucketing ----------------

def test_bucket_policy_pow2():
    p = BucketPolicy()
    assert p.bucket_shape((100, 256)) == (128, 256)
    assert p.bucket_shape((120, 256)) == (128, 256)
    assert p.bucket_shape((3, 100)) == (3, 128)   # small dims stay exact
    assert p.bucket_shape(()) == ()
    assert BucketPolicy(mode="exact").bucket_shape((100,)) == (100,)


def test_bucketed_shapes_share_cache_entry():
    cache = StitchCache()
    comp = StitchCompiler(mode="stitch", cache=cache)
    g100, x100 = _softmax_graph(rows=100)
    g120, x120 = _softmax_graph(rows=120)
    a = comp.compile(g100)
    assert a.stats.cache_status == "miss"
    b = comp.compile(g120)                   # same bucket (128): replay
    assert b.stats.cache_status == "hit"
    rep = cache.report()
    assert rep["total_hits"] == 1 and rep["total_misses"] == 1
    # hit and miss landed in the SAME bucket
    (bucket, counts), = rep["per_bucket"].items()
    assert counts == {"hits": 1, "misses": 1}
    # replayed executable is numerically identical to the reference
    rng = np.random.default_rng(0)
    inp = rng.standard_normal((120, 256)).astype(np.float32)
    ref = build_reference_fn(g120)({x120: inp})
    out = b({x120: inp})
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=2e-4, atol=2e-4)


def test_plans_keyed_by_hardware():
    from repro.core.cost import TPU_V5E, V100
    cache = StitchCache()
    g, _ = _softmax_graph()
    StitchCompiler(hw=V100, mode="stitch", cache=cache).compile(g)
    g2, _ = _softmax_graph("renamed")
    other = StitchCompiler(hw=TPU_V5E, mode="stitch", cache=cache).compile(g2)
    assert other.stats.cache_status == "miss"   # V100 plan must not shadow it


def test_plans_keyed_by_gen_config():
    """A plan solved under one GenConfig must not replay under another —
    the knobs (large_gemm_flops, stitch_custom, ...) change which patterns
    exist, so a stale hit would silently execute the wrong plan."""
    from repro.core.fusiongen import GenConfig
    cache = StitchCache()
    g, _ = _softmax_graph()
    StitchCompiler(mode="stitch", cache=cache).compile(g)
    g2, _ = _softmax_graph("renamed")
    other = StitchCompiler(
        mode="stitch", cache=cache,
        gen_cfg=GenConfig(large_gemm_flops=1.0)).compile(g2)
    assert other.stats.cache_status == "miss"
    # the default config still hits (None hashes like GenConfig())
    g3, _ = _softmax_graph("renamed_again")
    same = StitchCompiler(mode="stitch", cache=cache).compile(g3)
    assert same.stats.cache_status == "hit"


def test_graph_mutation_invalidates_live_memo():
    from repro.core import OpKind, OpNode
    cache = StitchCache()
    comp = StitchCompiler(mode="stitch", cache=cache)
    g, x = _softmax_graph()
    comp.compile(g)
    g.add(OpNode("late", OpKind.ELEMENTWISE, (64, 256), "float32",
                 (g.outputs[0],), {"op": "neg"}))
    g.mark_output("late")
    cg = comp.compile(g)                         # must NOT replay stale plan
    assert cg.stats.cache_status == "miss"
    rng = np.random.default_rng(0)
    inp = rng.standard_normal((64, 256)).astype(np.float32)
    ref = build_reference_fn(g)({x: inp})
    out = cg({x: inp})
    np.testing.assert_allclose(np.asarray(out["late"]), np.asarray(ref["late"]),
                               rtol=2e-4, atol=2e-4)


def test_distant_shapes_miss():
    cache = StitchCache()
    comp = StitchCompiler(mode="stitch", cache=cache)
    g64, _ = _softmax_graph(rows=64)
    g100, _ = _softmax_graph(rows=100)
    comp.compile(g64)
    out = comp.compile(g100)                 # bucket 128 != 64
    assert out.stats.cache_status == "miss"
    assert cache.report()["total_misses"] == 2


# -------------------------------------------------- store / eviction ---------

def _dummy_record(i):
    return PlanRecord(
        graph_key=f"g{i}", bucket_key="b", shape_key="s", mode="stitch",
        hw="TPU_V5E", n_nodes=1, groups=(GroupRecord((0,), "op"),))


def test_memory_lru_eviction():
    ms = MemoryStore(capacity=2)
    for i in range(3):
        ms.put(_dummy_record(i))
    assert len(ms) == 2 and ms.evictions == 1
    # keys carry the placement component ("" = single-device) since v2 and
    # the GenConfig digest ("" for records frozen without a compiler) since v3
    assert ms.get(("g0", "b", "stitch", "TPU_V5E", "", "")) is None   # evicted
    assert ms.get(("g2", "b", "stitch", "TPU_V5E", "", "")) is not None


def test_disk_roundtrip_replay_matches_fresh_compile(tmp_path, rng):
    g = make_mlp_norm_graph()
    inputs = {
        "x": rng.standard_normal((128, 256), dtype=np.float32),
        "w": (rng.standard_normal((256, 256)) * 0.05).astype(np.float32),
        "gamma": rng.standard_normal(256, dtype=np.float32),
        "eps": np.float32(1e-5),
    }
    d = str(tmp_path / "plans")
    cold = StitchCompiler(mode="stitch", cache=StitchCache(directory=d)).compile(g)
    assert cold.stats.cache_status == "miss"

    # new process simulation: fresh cache over the same directory, fresh
    # graph object (isomorphic rebuild)
    g2 = make_mlp_norm_graph()
    warm_cache = StitchCache(directory=d)
    warm = StitchCompiler(mode="stitch", cache=warm_cache).compile(g2)
    assert warm.stats.cache_status == "hit"
    assert warm.stats.n_kernels == cold.stats.n_kernels
    assert warm.stats.pallas_groups == cold.stats.pallas_groups

    ref = build_reference_fn(g2)(inputs)
    out_cold, out_warm = cold(inputs), warm(inputs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out_warm[k]), np.asarray(ref[k]),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(out_warm[k]),
                                   np.asarray(out_cold[k]),
                                   rtol=2e-4, atol=2e-4)


# -------------------------------------------------- replay skips pipeline ----

def test_cache_hit_skips_pattern_gen_ilp_and_tuning(monkeypatch):
    cache = StitchCache()
    comp = StitchCompiler(mode="stitch", cache=cache)
    g, _ = _softmax_graph()
    first = comp.compile(g)
    assert first.stats.cache_status == "miss"

    def boom(*a, **k):
        raise AssertionError("expensive pipeline stage ran on a cache hit")

    from repro.core.tuner import TemplateTuner
    monkeypatch.setattr("repro.core.compiler.generate_patterns", boom)
    monkeypatch.setattr("repro.core.compiler.solve_fusion_plan", boom)
    monkeypatch.setattr(TemplateTuner, "tune", boom)

    # same graph object (live memo) ...
    second = comp.compile(g)
    assert second.stats.cache_status == "hit"
    assert second.stats.n_kernels == first.stats.n_kernels
    # ... and an isomorphic rebuild (record replay)
    g2, _ = _softmax_graph("renamed")
    third = comp.compile(g2)
    assert third.stats.cache_status == "hit"
    assert third.stats.n_kernels == first.stats.n_kernels


def test_warm_compile_at_least_10x_faster():
    cache = StitchCache()
    comp = StitchCompiler(mode="stitch", cache=cache)
    g = make_mlp_norm_graph()
    t0 = time.perf_counter()
    comp.compile(g)
    cold = time.perf_counter() - t0
    comp.compile(g)                          # absorb one-time warm-path setup
    t0 = time.perf_counter()
    warm_cg = comp.compile(g)
    warm = time.perf_counter() - t0
    assert warm_cg.stats.cache_status == "hit"
    assert cold / max(warm, 1e-9) >= 10.0, (cold, warm)


# -------------------------------------------------- service ------------------

def test_service_miss_then_upgrade():
    svc = CompilationService(StitchCache(), fallback_mode="xla")
    g, x = _softmax_graph()
    fb, status = svc.compile_or_fallback(g)
    assert status == "miss"
    assert fb.stats.mode == "xla"            # served immediately, unstitched
    svc.wait(timeout=120)
    g2, x2 = _softmax_graph("renamed")       # background compile landed
    up, status = svc.compile_or_fallback(g2)
    assert status == "hit"
    assert up.stats.mode == "stitch" and up.stats.cache_status == "hit"
    rng = np.random.default_rng(0)
    inp = rng.standard_normal((64, 256)).astype(np.float32)
    ref = build_reference_fn(g2)({x2: inp})
    out = up({x2: inp})
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=2e-4, atol=2e-4)


def test_engine_miss_then_upgrade_identical_tokens():
    import jax
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig
    from repro.train import init_state

    cfg = get_reduced("qwen3_1_7b")
    model = build_model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 8)).astype(np.int32)

    base = Engine(model, state.params,
                  ServeConfig(batch=2, max_len=48, max_new_tokens=3))
    ref = base.generate(prompts.copy())

    svc = CompilationService(StitchCache())
    eng = Engine(model, state.params,
                 ServeConfig(batch=2, max_len=48, max_new_tokens=3,
                             stitch_execute=True),
                 stitch_service=svc)
    first = eng.generate(prompts.copy())
    # the shared exec layer polls per decode step (the scheduler-path
    # behavior, now unified), so the background compile may land and
    # upgrade mid-generate; anything but a failure is healthy here
    assert eng.stitch_status in ("miss", "pending", "hit")
    np.testing.assert_array_equal(first, ref)     # fallback/upgraded serve
    svc.wait(timeout=300)
    second = eng.generate(prompts.copy())
    assert eng.stitch_status == "hit"             # upgraded to stitched plan
    np.testing.assert_array_equal(second, ref)    # stitched decode identical
    rep = eng.stitch_report()
    assert rep["plan"]["mode"] == "stitch"
    assert rep["plan"]["n_kernels"] < rep["plan"]["n_ops"]
