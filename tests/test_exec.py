"""The public execution layer: ``repro.exec.stitch()``.

Contract under test — the jit-like transform every subsystem now dispatches
through: pytree-aware tracing (nested containers, kwargs), static-argnum
specialization, shape-drift fallback, miss-then-upgrade, donation, sharded
dispatch equality, background-failure surfacing, and the anytime ILP budget
feeding the same pipeline.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.cache import CompilationService
from repro.exec import StitchedFunction, stitch
from repro.launch.mesh import make_host_mesh


def ck(a, b, rtol=1e-6, atol=1e-6):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.fixture
def svc():
    # max_background=0: upgrades land only when the test compiles them —
    # deterministic miss-then-upgrade points
    return CompilationService(max_background=0)


# ---------------------------------------------------------------------------
# the tracing boundary: pytrees, kwargs, statics, drift
# ---------------------------------------------------------------------------

def test_stitch_pytree_and_kwargs_roundtrip(svc):
    """Nested dict/tuple inputs AND outputs round-trip through stitch()
    matching the jit reference, with kwargs flowing as traced inputs."""
    def fn(tree, pair, bias=None):
        x, y = pair
        h = tree["a"]["w"] * jnp.tanh(x) + y
        if bias is not None:
            h = h + bias["b"]
        return {"out": (h, h * 2.0), "norm": jnp.sqrt(jnp.sum(h * h, -1))}

    rng = np.random.default_rng(0)
    tree = {"a": {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)}}
    pair = (jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
            jnp.asarray(rng.standard_normal((16,)), jnp.float32))
    bias = {"b": jnp.asarray(rng.standard_normal((16,)), jnp.float32)}

    sf = stitch(fn, service=svc)
    out = sf(tree, pair, bias=bias)
    ref = jax.jit(fn)(tree, pair, bias=bias)
    assert (jax.tree_util.tree_structure(out)
            == jax.tree_util.tree_structure(ref))
    ck(out, ref)
    assert sf.stitched_calls == 1 and sf.fallback_calls == 0
    assert sf.status in ("miss", "pending")

    # a kwargs *structure* change is signature drift: jit serves that call
    out2 = sf(tree, pair)
    ck(out2, jax.jit(fn)(tree, pair))
    assert sf.fallback_calls == 1


def test_stitch_static_argnums_retrace_on_change(svc):
    def fn(x, n):
        return {"p": x ** n, "s": jnp.sum(x) * n}

    x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 8)),
                    jnp.float32)
    sf = stitch(fn, service=svc, static_argnums=(1,))
    ck(sf(x, 2), jax.jit(fn, static_argnums=(1,))(x, 2))
    ck(sf(x, 3), jax.jit(fn, static_argnums=(1,))(x, 3))
    ck(sf(x, 2), jax.jit(fn, static_argnums=(1,))(x, 2))   # cached retrace
    assert sf.report()["specializations"] == 2
    assert sf.stitched_calls == 3 and sf.fallback_calls == 0


def test_stitch_shape_drift_falls_back(svc):
    def fn(d):
        return {"y": jnp.tanh(d["x"]) * d["g"]}

    rng = np.random.default_rng(2)
    d = {"x": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
         "g": jnp.asarray(rng.standard_normal((16,)), jnp.float32)}
    sf = stitch(fn, service=svc)
    sf(d)
    assert sf.fallback_calls == 0
    drifted = {"x": d["x"][:, :8], "g": d["g"][:8]}
    ck(sf(drifted), jax.jit(fn)(drifted))          # served by jit, this call
    assert sf.fallback_calls == 1
    sf(d)                                          # original shape: stitched
    assert sf.fallback_calls == 1 and sf.stitched_calls == 2


def test_stitch_upgrade_hits_and_matches(svc):
    def fn(d):
        h = jnp.exp(d["x"] - jnp.max(d["x"], -1, keepdims=True))
        return h / jnp.sum(h, -1, keepdims=True)

    d = {"x": jnp.asarray(np.random.default_rng(3).standard_normal((16, 64)),
                          jnp.float32)}
    sf = stitch(fn, service=svc)
    first = sf(d)
    assert sf.status in ("miss", "pending")
    assert sf.compiled.stats.mode == "xla"         # fallback artifact
    svc.compiler("stitch").compile(sf.graph, bypass_cache_lookup=True)
    second = sf(d)
    assert sf.status == "hit"
    assert sf.compiled.stats.mode == "stitch"
    ck(first, jax.jit(fn)(d))
    ck(second, jax.jit(fn)(d))
    assert sf.plan_stats()["n_kernels"] < sf.plan_stats()["n_ops"]


def test_stitch_donate_argnums_deletes_consumed(svc):
    def fn(state, g):
        return {"w": state["w"] - 0.1 * g}

    state = {"w": jnp.ones((8, 8), jnp.float32)}
    g = jnp.ones((8, 8), jnp.float32)
    sf = stitch(fn, service=svc, donate_argnums=(0,))
    out = sf(state, g)
    assert state["w"].is_deleted()
    assert not out["w"].is_deleted()


def test_stitch_donation_keeps_passthrough_outputs(svc):
    """A donated input leaf that the function returns unchanged is aliased
    by the output — it must survive the donation (jit aliases it safely;
    the stitched delete must not corrupt the result)."""
    def fn(state, g):
        return {"w": state["w"] - 0.1 * g, "frozen": state["frozen"]}

    state = {"w": jnp.ones((8, 8), jnp.float32),
             "frozen": jnp.full((4,), 7.0, jnp.float32)}
    g = jnp.ones((8, 8), jnp.float32)
    sf = stitch(fn, service=svc, donate_argnums=(0,))
    out = sf(state, g)
    assert sf.stitched_calls == 1
    assert state["w"].is_deleted()               # genuinely consumed
    assert not out["frozen"].is_deleted()        # passthrough survives
    np.testing.assert_array_equal(np.asarray(out["frozen"]), np.full(4, 7.0))


def test_stitch_shadow_mode_serves_jit_but_reports(svc):
    def fn(x):
        return jnp.tanh(x) * 2.0

    x = jnp.ones((4, 4), jnp.float32)
    sf = stitch(fn, mode="shadow", service=svc)
    ck(sf(x), jax.jit(fn)(x))
    assert sf.jit_calls == 1 and sf.stitched_calls == 0
    assert sf.report()["plan"]["mode"] == "xla"    # compiled for reporting


# ---------------------------------------------------------------------------
# sharded dispatch (the --model-parallel 2 acceptance shape)
# ---------------------------------------------------------------------------

def test_stitch_sharded_matches_jit_reference():
    """An arbitrary pytree function with an in-body collective, stitched
    over the (4, 2) host mesh, matches its (single-device) jit reference
    across the miss-then-upgrade transition, under a mesh-keyed placement."""
    mesh = make_host_mesh(2)
    allax = tuple(mesh.axis_names)

    def fn(params, b):
        h = jnp.tanh(b["x"] @ params["w"]) + params["c"]
        loss = jax.lax.pmean(jnp.mean(h), allax)
        return {"loss": loss, "h": h * 2.0}

    def ref_fn(params, b):
        h = jnp.tanh(b["x"] @ params["w"]) + params["c"]
        return {"loss": jnp.mean(h), "h": h * 2.0}

    rng = np.random.default_rng(4)
    params = {"w": jnp.asarray(rng.standard_normal((16, 16)), jnp.float32),
              "c": jnp.asarray(rng.standard_normal((16,)), jnp.float32)}
    b = {"x": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)}

    svc = CompilationService(max_background=0)
    sf = stitch(fn, service=svc, mesh=mesh,
                in_specs=(P(), P(allax)),
                out_specs={"loss": P(), "h": P(allax)})
    ref = jax.jit(ref_fn)(params, b)
    ck(sf(params, b), ref)
    assert sf.placement.startswith("mesh[data=4,model=2]")
    assert sf._active.sharded
    svc.compiler("stitch", sf.placement).compile(sf.graph,
                                                 bypass_cache_lookup=True)
    ck(sf(params, b), ref)
    assert sf.status == "hit" and sf.compiled.stats.mode == "stitch"
    # mesh-keyed: the plan does not exist at the single-device placement
    assert svc.cache.lookup(sf.graph, svc.compiler("stitch")) is None


def test_stitch_mesh_requires_specs():
    mesh = make_host_mesh(1)
    if mesh.size == 1:
        pytest.skip("needs a multi-device host")
    with pytest.raises(ValueError, match="in_specs"):
        stitch(lambda x: x, mode="jit", mesh=mesh)


# ---------------------------------------------------------------------------
# background-compile failure: surfaced once, never silently swallowed
# ---------------------------------------------------------------------------

def test_background_failure_warns_once_and_reports(monkeypatch):
    def fn(x):
        return jnp.tanh(x) * jnp.exp(x)

    x = jnp.ones((8, 32), jnp.float32)
    svc = CompilationService(max_background=0)   # no thread yet
    sf = stitch(fn, service=svc)
    sf(x)                                        # trace + fallback artifact
    assert sf.status in ("miss", "pending")

    def boom(*a, **k):
        raise RuntimeError("ILP exploded")

    # only stitch-mode compiles solve the ILP; the xla fallback is unaffected
    monkeypatch.setattr("repro.core.compiler.solve_fusion_plan", boom)
    svc.max_background = 2
    sf(x)                                        # poll re-kicks the compile
    svc.wait(60.0)
    with pytest.warns(RuntimeWarning, match="ILP exploded"):
        sf(x)                                    # failure surfaced, once
    assert sf.status == "failed"
    rep = sf.report()
    assert "ILP exploded" in rep["error"]
    assert "ILP exploded" in rep["service_error"]
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        out = sf(x)                              # no second warning; and the
    ck(out, jax.jit(fn)(x))                      # fallback still serves
    # the doomed compile is not re-kicked
    assert not svc.ensure_compiling(sf.graph)


# ---------------------------------------------------------------------------
# anytime ILP: wall-clock budget -> greedy fallback plan
# ---------------------------------------------------------------------------

def _mlp_graph(rows=64, d=128):
    from repro.core import GraphBuilder

    b = GraphBuilder("mlp_norm")
    x = b.param("x", (rows, d))
    w = b.param("w", (d, d))
    gm = b.param("gamma", (d,))
    h = b.dot(x, w, name="dot_0")
    mu = b.reduce("mean", h, axes=(1,), keepdims=True)
    dlt = b.ew("sub", h, b.bcast(mu, (rows, d), (0, 1)))
    v = b.reduce("mean", b.ew("square", dlt), axes=(1,), keepdims=True)
    eps = b.const("eps", ())
    b.graph[eps].attrs["value"] = np.float32(1e-6)
    r = b.ew("rsqrt", b.ew("add", v, eps))
    y = b.ew("mul", b.ew("mul", dlt, b.bcast(r, (rows, d), (0, 1))),
             b.ew("relu", b.bcast(gm, (rows, d), (1,))))
    return b.build(outputs=[y])


def test_anytime_ilp_greedy_fallback_is_valid():
    from repro.core import CostModel, GenConfig, generate_patterns
    from repro.core.ilp import solve_fusion_plan

    g = _mlp_graph()
    patterns = generate_patterns(g, GenConfig())
    scores = [CostModel().score(p).score for p in patterns]

    exact = solve_fusion_plan(g, patterns, scores)
    assert exact.method == "ilp" and not exact.budget_expired

    budgeted = solve_fusion_plan(g, patterns, scores, budget_seconds=0.0)
    assert budgeted.method == "greedy" and budgeted.budget_expired
    # valid plan: pairwise disjoint members, every member a graph node
    seen = set()
    for p in budgeted.chosen:
        assert not (p.members & seen)
        seen |= p.members
    assert budgeted.objective > 0


def test_plan_budget_compiles_correct_executable(rng):
    from repro.core import StitchCompiler, build_reference_fn

    g = _mlp_graph()
    inputs = {"x": rng.standard_normal((64, 128)).astype(np.float32),
              "w": (rng.standard_normal((128, 128)) * 0.05).astype(np.float32),
              "gamma": rng.standard_normal(128).astype(np.float32)}
    compiled = StitchCompiler(mode="stitch", plan_budget=0.0).compile(g)
    assert compiled.stats.ilp is not None
    assert compiled.stats.ilp.method == "greedy"
    assert compiled.stats.n_kernels < compiled.stats.n_ops
    ref = build_reference_fn(g)(inputs)
    out = compiled(inputs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=2e-4, atol=2e-4)


def test_stitched_function_rejects_bad_mode():
    with pytest.raises(ValueError, match="mode"):
        StitchedFunction(lambda x: x, mode="nope")
