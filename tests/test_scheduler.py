"""Continuous-batching scheduler: correctness vs the static engine,
eviction/refill/EOS behavior, bucketed admission, metrics, and the CI
perf-regression gate."""

import numpy as np
import pytest

import jax

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen3_1_7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference(model, params, prompt, n_new, max_len):
    """Per-request static-batch run (ragged generate, batch=1)."""
    eng = Engine(model, params,
                 ServeConfig(batch=1, max_len=max_len, max_new_tokens=n_new))
    return eng.generate(prompt[None].copy(), prompt_lens=[len(prompt)])[0]


def _workload(cfg, seed=42):
    rng = np.random.default_rng(seed)
    lens = [5, 12, 9, 3, 17, 7, 11]
    news = [6, 3, 9, 5, 4, 8, 2]
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32) for p in lens]
    return prompts, news


def test_continuous_matches_static_token_for_token(setup):
    """Ragged prompts + mixed budgets through 3 slots (7 requests, so slots
    are evicted and refilled mid-stream) produce exactly the tokens of
    per-request static-batch runs."""
    cfg, model, params = setup
    prompts, news = _workload(cfg)
    eng = Engine(model, params, ServeConfig(batch=3, max_len=64))
    for p, n in zip(prompts, news):
        eng.submit(p, max_new_tokens=n)
    fins = {f.rid: f for f in eng.drain()}
    assert len(fins) == len(prompts)
    for rid, (p, n) in enumerate(zip(prompts, news)):
        ref = _reference(model, params, p, n, 64)
        np.testing.assert_array_equal(fins[rid].tokens, ref,
                                      err_msg=f"request {rid}")
        assert fins[rid].finish_reason == "length"
        assert fins[rid].prompt_len == len(p)
    # forced mid-stream recycling: more finishes than slots
    summary = eng.serve_report()
    assert summary["evictions"] == len(prompts)
    assert summary["admissions"] == len(prompts)


def test_eos_evicts_and_truncates(setup):
    """With eos_id >= 0, a slot is evicted the moment it emits EOS and its
    output equals the static run truncated at the first EOS."""
    cfg, model, params = setup
    prompts, news = _workload(cfg)
    refs = [_reference(model, params, p, n, 64)
            for p, n in zip(prompts, news)]
    # pick an eos id that actually occurs mid-stream in some reference
    eos = int(refs[0][min(2, len(refs[0]) - 1)])

    eng = Engine(model, params, ServeConfig(batch=2, max_len=64, eos_id=eos))
    for p, n in zip(prompts, news):
        eng.submit(p, max_new_tokens=n)
    fins = {f.rid: f for f in eng.drain()}
    hit_eos = 0
    for rid, ref in enumerate(refs):
        cut = np.where(ref == eos)[0]
        expect = ref[:cut[0] + 1] if len(cut) else ref
        np.testing.assert_array_equal(fins[rid].tokens, expect,
                                      err_msg=f"request {rid}")
        if len(cut):
            hit_eos += 1
            assert fins[rid].finish_reason == "eos"
            assert fins[rid].tokens[-1] == eos
        else:
            assert fins[rid].finish_reason == "length"
    assert hit_eos >= 1   # the workload actually exercised EOS eviction


def test_bucketed_admission_reuses_prefill_compiles(setup):
    """Prompt lengths inside one pow2 bucket share a single compiled
    prefill; a new bucket adds exactly one."""
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    eng = Engine(model, params, ServeConfig(batch=2, max_len=64))
    for p in (5, 6, 7, 8):      # all bucket to 8
        eng.submit(rng.integers(0, cfg.vocab, (p,)).astype(np.int32),
                   max_new_tokens=2)
    eng.drain()
    sch = eng.scheduler
    assert eng.report()["cache"]["prefill_entries"] == 1
    assert sch.bucket_len(5) == sch.bucket_len(8) == 8
    eng.submit(rng.integers(0, cfg.vocab, (9,)).astype(np.int32),
               max_new_tokens=2)   # bucket 16
    eng.drain()
    assert eng.report()["cache"]["prefill_entries"] == 2
    assert sch.bucket_len(9) == 16
    # bucket is capped at the KV capacity
    assert sch.bucket_len(63) == 64


def test_metrics_and_occupancy(setup):
    cfg, model, params = setup
    prompts, news = _workload(cfg)
    eng = Engine(model, params, ServeConfig(batch=3, max_len=64))
    for p, n in zip(prompts, news):
        eng.submit(p, max_new_tokens=n)
    fins = eng.drain()
    s = eng.serve_report()
    assert s["requests_finished"] == len(prompts)
    assert s["total_tokens"] == sum(len(f.tokens) for f in fins) == sum(news)
    assert 0.0 < s["mean_occupancy"] <= 1.0
    assert s["tokens_per_sec"] > 0
    assert s["peak_queue_depth"] >= len(prompts) - 3   # slots admitted first
    for f in fins:
        assert f.finish_time >= f.admit_time >= f.arrival_time
    m = eng.scheduler.metrics.steps
    assert all(st.active <= st.slots for st in m)
    # drained: queue empty, all slots free
    assert len(eng.scheduler.queue) == 0 and eng.scheduler.n_active == 0


def test_submit_validates_capacity(setup):
    cfg, model, params = setup
    eng = Engine(model, params, ServeConfig(batch=2, max_len=16))
    with pytest.raises(ValueError):
        eng.submit(np.arange(10, dtype=np.int32), max_new_tokens=10)
    with pytest.raises(ValueError):
        eng.submit(np.array([], dtype=np.int32), max_new_tokens=2)


def test_unsupported_family_raises():
    from repro.serve import SchedulerConfig, Scheduler
    cfg = get_reduced("falcon_mamba_7b")      # ssm: prefill not pad-invariant
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(batch=2, max_len=32))
    with pytest.raises(NotImplementedError):
        Scheduler(eng, SchedulerConfig(slots=2, max_len=32))
    # the ragged static path guards the same families
    eng = Engine(model, params, ServeConfig(batch=1, max_len=32))
    with pytest.raises(NotImplementedError):
        eng.generate(np.zeros((1, 8), np.int32), prompt_lens=[8])


def test_drain_converges_at_exact_step_budget(setup):
    """A workload finishing on the last allowed step is not a convergence
    failure."""
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    eng = Engine(model, params, ServeConfig(batch=2, max_len=32))
    eng.submit(rng.integers(0, cfg.vocab, (4,)).astype(np.int32),
               max_new_tokens=3)
    # chunked decode: admission step + one 2-token chunk = 2 iterations
    fins = eng.drain(max_steps=2)
    assert len(fins) == 1 and len(fins[0].tokens) == 3


def test_static_ragged_generate_matches_exact_prefill(setup):
    """The ragged static path (bucketed prefill + per-row true_len) equals
    the legacy rectangular path when the batch is not actually ragged."""
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    eng = Engine(model, params, ServeConfig(batch=2, max_len=48,
                                            max_new_tokens=6))
    legacy = eng.generate(prompts.copy())
    ragged = eng.generate(prompts.copy(), prompt_lens=[8, 8])
    np.testing.assert_array_equal(legacy, ragged)


def test_check_regression_gate():
    """The CI gate passes an identical record, flags a >10% kernel-count or
    modeled-time regression, and fails on lost workload coverage."""
    from benchmarks.check_regression import compare
    base = {"workloads": {
        "wl_a": {"kernels": {"stitch": 10}, "modeled_time_s": {"stitch": 1e-3}},
        "wl_b": {"kernels": {"stitch": 20}, "modeled_time_s": {"stitch": 2e-3}},
    }}
    same = {"workloads": {k: dict(v) for k, v in base["workloads"].items()}}
    failures, _ = compare(base, same)
    assert failures == []

    worse = {"workloads": {
        "wl_a": {"kernels": {"stitch": 12}, "modeled_time_s": {"stitch": 1e-3}},
        "wl_b": {"kernels": {"stitch": 20}, "modeled_time_s": {"stitch": 2.3e-3}},
    }}
    failures, _ = compare(base, worse)
    assert len(failures) == 2          # +20% kernels, +15% modeled time

    within = {"workloads": {
        "wl_a": {"kernels": {"stitch": 11}, "modeled_time_s": {"stitch": 1e-3}},
        "wl_b": {"kernels": {"stitch": 20}, "modeled_time_s": {"stitch": 2.1e-3}},
    }}
    failures, _ = compare(base, within)   # <= 10%: allowed
    assert failures == []

    missing = {"workloads": {"wl_a": base["workloads"]["wl_a"]}}
    failures, _ = compare(base, missing)
    assert any("missing" in f for f in failures)


def test_check_regression_gates_serving_throughput_both_directions():
    """Direction-aware serving gate: tokens_per_sec is higher-is-better —
    a >10% drop fails, an improvement (or small wobble) passes."""
    from benchmarks.check_regression import compare
    base = {"workloads": {},
            "serving": {"continuous": {"tokens_per_sec": 2000.0},
                        "static": {"tokens_per_sec": 1500.0}}}

    dropped = {"workloads": {},
               "serving": {"continuous": {"tokens_per_sec": 1700.0},  # -15%
                           "static": {"tokens_per_sec": 1500.0}}}
    failures, _ = compare(base, dropped)
    assert len(failures) == 1 and "drop" in failures[0]
    assert "continuous_tokens_per_sec" in failures[0]

    improved = {"workloads": {},
                "serving": {"continuous": {"tokens_per_sec": 2600.0},  # +30%
                            "static": {"tokens_per_sec": 1460.0}}}     # -2.7%
    failures, _ = compare(base, improved)
    assert failures == []

    # a faster-is-worse direction mixup would let this regress silently:
    # the same +15% that fails a lower-is-better metric must PASS here
    faster = {"workloads": {},
              "serving": {"continuous": {"tokens_per_sec": 2300.0},
                          "static": {"tokens_per_sec": 1725.0}}}
    failures, _ = compare(base, faster)
    assert failures == []

    # lost section = lost coverage
    failures, _ = compare(base, {"workloads": {}})
    assert any("serving" in f and "missing" in f for f in failures)

    # serving_tolerance widens ONLY the wall-clock serving gate (CI runs
    # against a baseline recorded on different hardware); the default
    # tolerance still governs every deterministic metric
    failures, _ = compare(base, dropped, serving_tolerance=0.5)
    assert failures == []
    mixed = {"workloads": {
        "wl": {"kernels": {"stitch": 12},                  # +20% kernels
               "modeled_time_s": {"stitch": 1e-3}}}}
    base_mixed = {"workloads": {
        "wl": {"kernels": {"stitch": 10},
               "modeled_time_s": {"stitch": 1e-3}}},
        "serving": base["serving"]}
    failures, _ = compare(base_mixed, {**mixed, "serving": dropped["serving"]},
                          serving_tolerance=0.5)
    assert len(failures) == 1 and "stitched_kernels" in failures[0]


def test_check_regression_gates_prefix_liveness_positive():
    """The prefix sub-run gates as liveness: hit rate / stitched-prefill
    kernels must be > 0 in the candidate, whatever the baseline recorded;
    a baseline predating the metrics skips them, a candidate that lost
    them fails (lost coverage)."""
    from benchmarks.check_regression import compare
    wall = {"continuous": {"tokens_per_sec": 2000.0},
            "static": {"tokens_per_sec": 1500.0}}
    px = {"prefix_cache": {"hit_rate": 0.75},
          "prefill": {"n_kernels": 22}}
    base = {"workloads": {}, "serving": {**wall, "prefix": px}}

    alive = {"workloads": {}, "serving": {**wall, "prefix": {
        "prefix_cache": {"hit_rate": 0.1}, "prefill": {"n_kernels": 3}}}}
    failures, _ = compare(base, alive)
    assert failures == []

    # hit rate 0 must fail even though 0 -> 0.75 is no "drop" vs baseline
    dead = {"workloads": {}, "serving": {**wall, "prefix": {
        "prefix_cache": {"hit_rate": 0.0}, "prefill": {"n_kernels": 22}}}}
    failures, _ = compare(base, dead)
    assert len(failures) == 1 and "must be > 0" in failures[0]
    assert "prefix_cache_hit_rate" in failures[0]

    # baseline predates the prefix metrics: skip, don't fail
    old_base = {"workloads": {}, "serving": dict(wall)}
    failures, _ = compare(old_base, alive)
    assert failures == []

    # candidate lost the metrics the baseline had: lost coverage
    failures, _ = compare(base, {"workloads": {}, "serving": dict(wall)})
    assert any("prefix" in f and "missing" in f for f in failures)


def test_check_regression_gates_sharding_section():
    """Sharded metrics: per-shard kernel counts gate lower-is-better, the
    mesh-keyed entry count gates exactly."""
    from benchmarks.check_regression import compare
    base = {"workloads": {},
            "sharding": {
                "grad_local": {"kernels": {"stitch": 40},
                               "modeled_time_s": {"stitch": 1e-4}},
                "packed_local": {"kernels": {"stitch": 1}},
                "cache": {"mesh_keyed_entries": 2}}}
    same = {"workloads": {}, "sharding": {
        "grad_local": {"kernels": {"stitch": 41},          # +2.5%: fine
                       "modeled_time_s": {"stitch": 1.05e-4}},
        "packed_local": {"kernels": {"stitch": 1}},
        "cache": {"mesh_keyed_entries": 2}}}
    failures, _ = compare(base, same)
    assert failures == []

    worse = {"workloads": {}, "sharding": {
        "grad_local": {"kernels": {"stitch": 50},          # +25%
                       "modeled_time_s": {"stitch": 1e-4}},
        "packed_local": {"kernels": {"stitch": 2}},        # +100%
        "cache": {"mesh_keyed_entries": 1}}}               # placements merged
    failures, _ = compare(base, worse)
    assert len(failures) == 3
    assert any("mesh_keyed_entries" in f and "exactly" in f for f in failures)


def test_check_regression_measured_schema_checked_not_gated():
    """When the baseline carries the measured-timing section, a candidate
    that dropped it (timer silently disabled) fails the gate; the values
    themselves are never compared, only the schema."""
    from benchmarks.check_regression import compare
    measured = {"rmsnorm_us": {"oracle_jit": 5.0, "stitched_interpret": 900.0},
                "softmax_us": {"oracle_jit": 4.0, "stitched_interpret": 800.0},
                "exec": {"measured_s": {"count": 3, "mean": 1e-3},
                         "modeled_time_s": 2e-5, "calls": 3}}
    base = {"workloads": {}, "measured": measured}

    # wildly different values: schema-checked only, so still a pass
    slower = {"rmsnorm_us": {"oracle_jit": 250.0, "stitched_interpret": 45000.0},
              "softmax_us": {"oracle_jit": 200.0, "stitched_interpret": 40000.0},
              "exec": {"measured_s": {"count": 3, "mean": 5e-2},
                       "modeled_time_s": 2e-5, "calls": 3}}
    failures, lines = compare(base, {"workloads": {}, "measured": slower})
    assert failures == []
    assert any("values not gated" in ln for ln in lines)

    # losing the section entirely is lost coverage — fail loudly
    failures, _ = compare(base, {"workloads": {}})
    assert len(failures) == 1 and "measured" in failures[0]

    # as is losing a required key inside it
    broken = dict(measured, exec={"calls": 3})
    failures, _ = compare(base, {"workloads": {}, "measured": broken})
    assert any("exec.measured_s" in f for f in failures)

    # a baseline predating the section gates nothing (legacy records)
    failures, _ = compare({"workloads": {}}, {"workloads": {}})
    assert failures == []
