"""StitchCompiler end-to-end: all three modes numerically identical to the
oracle; stitch mode compresses kernels and uses Pallas groups."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import StitchCompiler, build_reference_fn, emit_source
from repro.core.trace import trace_to_graph
from conftest import make_mlp_norm_graph, make_softmax_graph


def _run_all_modes(g, inputs, rtol=2e-4):
    ref = build_reference_fn(g)(inputs)
    stats = {}
    for mode in ("off", "xla", "stitch"):
        cg = StitchCompiler(mode=mode).compile(g)
        out = cg(inputs)
        for k in ref:
            np.testing.assert_allclose(
                np.asarray(out[k]), np.asarray(ref[k]), rtol=rtol, atol=rtol,
                err_msg=f"mode={mode} output={k}")
        stats[mode] = cg.stats
    return stats


def test_softmax_modes(rng):
    g, x, y = make_softmax_graph()
    stats = _run_all_modes(g, {x: rng.standard_normal((64, 256), dtype=np.float32)})
    assert stats["off"].n_kernels > stats["xla"].n_kernels >= stats["stitch"].n_kernels
    assert stats["stitch"].n_kernels == 1
    assert stats["stitch"].pallas_groups == 1


def test_mlp_norm_modes(rng):
    g = make_mlp_norm_graph()
    inputs = {
        "x": rng.standard_normal((128, 256), dtype=np.float32),
        "w": (rng.standard_normal((256, 256)) * 0.05).astype(np.float32),
        "gamma": rng.standard_normal(256, dtype=np.float32),
        "eps": np.float32(1e-5),
    }
    stats = _run_all_modes(g, inputs)
    assert stats["stitch"].compression > stats["xla"].compression


def test_traced_function_pipeline(rng):
    def f(x, w):
        h = jnp.tanh(x @ w)
        return jax.nn.softmax(h * 2.0, axis=-1) + jnp.exp(-h)

    x = rng.standard_normal((64, 128), dtype=np.float32)
    w = (rng.standard_normal((128, 128)) * 0.1).astype(np.float32)
    g, names = trace_to_graph(f, x, w)
    expected = np.asarray(f(x, w))
    inputs = dict(zip(names, [x, w]))
    for mode in ("off", "xla", "stitch"):
        out = StitchCompiler(mode=mode).compile(g)(inputs)
        np.testing.assert_allclose(
            np.asarray(out[g.outputs[0]]), expected, rtol=2e-4, atol=2e-4)


def test_packing_of_independent_ops(rng):
    """Paper §5.1 kernel packing: independent same-shape elementwise chains
    (gradient-accumulation pattern) end up in ONE kernel."""
    from repro.core import GraphBuilder
    b = GraphBuilder("pack")
    outs = []
    for i in range(4):
        x = b.param(f"x{i}", (256, 128))
        y = b.param(f"y{i}", (256, 128))
        outs.append(b.ew("add", b.ew("mul", x, y), x))
    g = b.build(outputs=outs)
    cg = StitchCompiler(mode="stitch").compile(g)
    assert cg.stats.n_kernels == 1, "independent chains should pack"
    # xla baseline cannot pack (no data deps between chains)
    cg_xla = StitchCompiler(mode="xla").compile(g)
    assert cg_xla.stats.n_kernels == 4
    inputs = {f"x{i}": rng.standard_normal((256, 128), dtype=np.float32)
              for i in range(4)}
    inputs |= {f"y{i}": rng.standard_normal((256, 128), dtype=np.float32)
               for i in range(4)}
    ref = build_reference_fn(g)(inputs)
    out = cg(inputs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)


def test_emit_source_readable():
    g, x, y = make_softmax_graph(rows=8, cols=16)
    from repro.core import FusionPattern, CostModel, generate_templates
    p = FusionPattern(g, frozenset(n for n in g.nodes if n != x))
    templates = generate_templates(p, CostModel())
    assert templates
    src = emit_source(p, templates[0])
    assert "def stitched_kernel" in src and "template:" in src
    assert "jnp.max" in src or "ew." in src


def test_stats_pattern_classes():
    g = make_mlp_norm_graph()
    cg = StitchCompiler(mode="stitch").compile(g)
    assert sum(cg.stats.pattern_classes.values()) >= 1
    assert cg.stats.modeled_time > 0
