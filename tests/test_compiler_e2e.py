"""StitchCompiler end-to-end: all three modes numerically identical to the
oracle; stitch mode compresses kernels and uses Pallas groups."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import StitchCompiler, build_reference_fn, emit_source
from repro.core.trace import trace_to_graph
from conftest import make_mlp_norm_graph, make_softmax_graph


def _run_all_modes(g, inputs, rtol=2e-4):
    ref = build_reference_fn(g)(inputs)
    stats = {}
    for mode in ("off", "xla", "stitch"):
        cg = StitchCompiler(mode=mode).compile(g)
        out = cg(inputs)
        for k in ref:
            np.testing.assert_allclose(
                np.asarray(out[k]), np.asarray(ref[k]), rtol=rtol, atol=rtol,
                err_msg=f"mode={mode} output={k}")
        stats[mode] = cg.stats
    return stats


def test_softmax_modes(rng):
    g, x, y = make_softmax_graph()
    stats = _run_all_modes(g, {x: rng.standard_normal((64, 256), dtype=np.float32)})
    assert stats["off"].n_kernels > stats["xla"].n_kernels >= stats["stitch"].n_kernels
    assert stats["stitch"].n_kernels == 1
    assert stats["stitch"].pallas_groups == 1


def test_mlp_norm_modes(rng):
    g = make_mlp_norm_graph()
    inputs = {
        "x": rng.standard_normal((128, 256), dtype=np.float32),
        "w": (rng.standard_normal((256, 256)) * 0.05).astype(np.float32),
        "gamma": rng.standard_normal(256, dtype=np.float32),
        "eps": np.float32(1e-5),
    }
    stats = _run_all_modes(g, inputs)
    assert stats["stitch"].compression > stats["xla"].compression


def test_traced_function_pipeline(rng):
    def f(x, w):
        h = jnp.tanh(x @ w)
        return jax.nn.softmax(h * 2.0, axis=-1) + jnp.exp(-h)

    x = rng.standard_normal((64, 128), dtype=np.float32)
    w = (rng.standard_normal((128, 128)) * 0.1).astype(np.float32)
    g, names = trace_to_graph(f, x, w)
    expected = np.asarray(f(x, w))
    inputs = dict(zip(names, [x, w]))
    for mode in ("off", "xla", "stitch"):
        out = StitchCompiler(mode=mode).compile(g)(inputs)
        np.testing.assert_allclose(
            np.asarray(out[g.outputs[0]]), expected, rtol=2e-4, atol=2e-4)


def test_packing_of_independent_ops(rng):
    """Paper §5.1 kernel packing: independent same-shape elementwise chains
    (gradient-accumulation pattern) end up in ONE kernel."""
    from repro.core import GraphBuilder
    b = GraphBuilder("pack")
    outs = []
    for i in range(4):
        x = b.param(f"x{i}", (256, 128))
        y = b.param(f"y{i}", (256, 128))
        outs.append(b.ew("add", b.ew("mul", x, y), x))
    g = b.build(outputs=outs)
    cg = StitchCompiler(mode="stitch").compile(g)
    assert cg.stats.n_kernels == 1, "independent chains should pack"
    # xla baseline cannot pack (no data deps between chains)
    cg_xla = StitchCompiler(mode="xla").compile(g)
    assert cg_xla.stats.n_kernels == 4
    inputs = {f"x{i}": rng.standard_normal((256, 128), dtype=np.float32)
              for i in range(4)}
    inputs |= {f"y{i}": rng.standard_normal((256, 128), dtype=np.float32)
               for i in range(4)}
    ref = build_reference_fn(g)(inputs)
    out = cg(inputs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)


def test_emit_source_readable():
    g, x, y = make_softmax_graph(rows=8, cols=16)
    from repro.core import FusionPattern, CostModel, generate_templates
    p = FusionPattern(g, frozenset(n for n in g.nodes if n != x))
    templates = generate_templates(p, CostModel())
    assert templates
    src = emit_source(p, templates[0])
    assert "def stitched_kernel" in src and "template:" in src
    assert "jnp.max" in src or "ew." in src


def test_stats_pattern_classes():
    g = make_mlp_norm_graph()
    cg = StitchCompiler(mode="stitch").compile(g)
    assert sum(cg.stats.pattern_classes.values()) >= 1
    assert cg.stats.modeled_time > 0


def test_attention_mlp_block_single_kernel(rng):
    """A full transformer block — rmsnorm, q/k/v projections, Pallas flash
    attention, output projection, MLP, residuals — compiles to ONE stitched
    kernel: the registered custom kernel fuses with the small GEMMs around
    it instead of partitioning the graph into islands."""
    from repro.kernels.flash_attention import flash_attention

    B, S, D, H = 2, 128, 16, 2
    dh, F = D // H, 4 * 16

    def mk(*shape):
        return jnp.asarray(rng.standard_normal(shape, np.float32) * 0.1)

    wq, wk, wv, wo = mk(D, D), mk(D, D), mk(D, D), mk(D, D)
    w1, w2, g1, g2 = mk(D, F), mk(F, D), mk(D), mk(D)
    x = mk(B, S, D)

    def rms(v, gain):
        var = jnp.mean(v * v, axis=-1, keepdims=True)
        return v * jax.lax.rsqrt(var + 1e-6) * gain

    def block(wq, wk, wv, wo, w1, w2, g1, g2, x):
        h = rms(x, g1)
        q = (h @ wq).reshape(B, S, H, dh)
        k = (h @ wk).reshape(B, S, H, dh)
        v = (h @ wv).reshape(B, S, H, dh)
        a = flash_attention(q, k, v, causal=True).reshape(B, S, D)
        x2 = x + a @ wo
        return x2 + jax.nn.gelu(rms(x2, g2) @ w1) @ w2

    args = (wq, wk, wv, wo, w1, w2, g1, g2, x)
    ref = np.asarray(jax.jit(block)(*args))
    g, names = trace_to_graph(block, *args)
    cg = StitchCompiler(mode="stitch").compile(g)
    assert cg.stats.n_kernels == 1, cg.stats
    assert cg.stats.pallas_groups == 1, cg.stats
    out = cg(dict(zip(names, args)))
    np.testing.assert_array_equal(np.asarray(out[g.outputs[0]]), ref)


def test_unregistered_custom_still_partitions(rng):
    """An opaque custom op with no registry entry keeps its partition-op
    status — no silent attempt to inline arbitrary foreign kernels."""
    from functools import partial

    @partial(jax.custom_vjp)
    def opaque(x):
        return jnp.tanh(x) * 1.5

    opaque.defvjp(lambda x: (opaque(x), x), lambda res, ct: (ct,))

    def f(x, w):
        return jax.nn.relu(opaque(x @ w) + 1.0)

    x = jnp.asarray(rng.standard_normal((32, 64), np.float32))
    w = jnp.asarray(rng.standard_normal((64, 64), np.float32) * 0.1)
    g, names = trace_to_graph(f, x, w)
    customs = [n for n in g.nodes.values()
               if n.kind.value == "custom" and "project" not in n.attrs]
    if not customs:
        pytest.skip("custom_vjp traced away; nothing to assert")
    cg = StitchCompiler(mode="stitch").compile(g)
    groups_with_custom = [grp for grp in cg.groups
                          if any(c.name in grp.members for c in customs)]
    for grp in groups_with_custom:
        assert len(grp.members) == 1, "unregistered custom must not fuse"
