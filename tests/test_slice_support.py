"""Regression guard: SLICE is a first-class fusible op (the mamba-glue
finding in EXPERIMENTS §Perf 4.3-3: opaque slices fragmented every plan)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import OpKind, StitchCompiler, build_reference_fn
from repro.core.trace import trace_to_graph


def test_traced_slices_are_fusible_not_custom():
    def f(x):
        a, b = x[:, :32], x[:, 32:]
        return jax.nn.silu(a) * jnp.tanh(b)

    x = np.random.randn(64, 64).astype("float32")
    g, names = trace_to_graph(f, x)
    kinds = {n.kind for n in g.compute_nodes()}
    assert OpKind.SLICE in kinds
    assert OpKind.CUSTOM not in kinds
    cg = StitchCompiler(mode="stitch").compile(g)
    assert cg.stats.n_kernels == 1, "slices must not fragment the plan"
    out = cg({names[0]: x})
    ref = build_reference_fn(g)({names[0]: x})
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)


def test_row_axis_slice_rejected_by_emitter():
    """Slicing the row axis is not row-local: emitter must refuse (falls
    back to fused-jnp), never silently mis-evaluate."""
    from repro.core import FusionPattern
    from repro.kernels.stitched import StitchInfeasible, analyze_pattern
    from repro.core.ir import GraphBuilder

    b = GraphBuilder("rowslice")
    x = b.param("x", (64, 16))
    s = b.slice_(x, (0, 0), (32, 16))
    y = b.ew("exp", s)
    z = b.ew("neg", b.ew("relu", x))
    g = b.build(outputs=[y, z])
    p = FusionPattern(g, frozenset([s, y, z, "relu"]))
    try:
        ana = analyze_pattern(p)
        # acceptable only if it found a consistent non-64 row space
        assert ana.rows != 64 or ana.roles[s] != "row"
    except StitchInfeasible:
        pass  # refusal is the expected outcome
