"""Dominance-tree scratch allocator (Alg. 4) + template grammar (§5.2)."""

import pytest

from repro.core import (
    GraphBuilder, ScratchAllocator, parse_template, post_dominates,
)
from repro.core.scratch import _postdom_idom


def _chain_graph():
    """x -> a -> b -> c -> out : b post-dominates a, c post-dominates b."""
    b = GraphBuilder("chain")
    x = b.param("x", (64, 64))
    a = b.ew("exp", x)
    bb = b.ew("neg", a)
    c = b.ew("relu", bb)
    g = b.build(outputs=[c])
    return g, (x, a, bb, c)


def test_postdominance_chain():
    g, (x, a, bb, c) = _chain_graph()
    idom = _postdom_idom(g)
    assert post_dominates(idom, bb, a)
    assert post_dominates(idom, c, a)
    assert not post_dominates(idom, a, bb)


def test_postdominance_diamond():
    # a feeds b and c; d consumes both: d postdominates a; b does NOT.
    gb = GraphBuilder("diamond")
    x = gb.param("x", (8, 8))
    a = gb.ew("exp", x)
    b = gb.ew("neg", a)
    c = gb.ew("relu", a)
    d = gb.ew("add", b, c)
    g = gb.build(outputs=[d])
    idom = _postdom_idom(g)
    assert post_dominates(idom, d, a)
    assert not post_dominates(idom, b, a)
    assert not post_dominates(idom, c, a)


def test_scratch_reuse_in_chain():
    g, (x, a, bb, c) = _chain_graph()
    plan = ScratchAllocator(g).allocate({a: 1024, bb: 1024, c: 1024})
    # each op post-dominates its producer -> single 1KB buffer reused 3x
    assert plan.requested == 3072
    assert plan.allocated == 1024
    assert plan.alloc_over_req == pytest.approx(1 / 3)


def test_scratch_no_reuse_across_parallel_branches():
    gb = GraphBuilder("diamond")
    x = gb.param("x", (8, 8))
    a = gb.ew("exp", x)
    b = gb.ew("neg", a)
    c = gb.ew("relu", a)
    d = gb.ew("add", b, c)
    g = gb.build(outputs=[d])
    plan = ScratchAllocator(g).allocate({b: 512, c: 512})
    # b and c are live simultaneously: no sharing possible
    assert plan.allocated == 1024


def test_scratch_size_gate():
    g, (x, a, bb, c) = _chain_graph()
    # c requests more than a's buffer -> cannot Share it, allocates fresh
    plan = ScratchAllocator(g).allocate({a: 512, c: 1024})
    assert plan.allocated == 1536


def test_paper_example_dot_then_add_reuse():
    """Paper §5.4: 'the add can reuse the space allocated for the dot_1'."""
    gb = GraphBuilder("fig1")
    x = gb.param("x", (94, 94))
    w = gb.param("w", (94, 94))
    dot1 = gb.dot(x, w, name="dot_1")
    add = gb.ew("add", dot1, x)
    out = gb.reduce("sum", add, axes=(1,))
    g = gb.build(outputs=[out])
    sz = 94 * 94 * 4
    plan = ScratchAllocator(g).allocate({dot1: sz, add: sz})
    assert plan.allocated == sz, "add must reuse dot_1's scratch"


# ------------------------------------------------------------- templates ----

def test_template_roundtrip():
    t = parse_template("reduce_1[GRID,SUBLANE,SUBLANE,LANE]S; mul_1[GRID,LANE];")
    assert len(t.schedules) == 2
    assert t.schedules[0].scratch and not t.schedules[1].scratch
    assert str(t) == "reduce_1[GRID,SUBLANE,SUBLANE,LANE]S; mul_1[GRID,LANE];"


def test_template_accepts_paper_gpu_spelling():
    t = parse_template("reduce_1[GRID,WARP,WARP,CTA]S;")
    assert [a.primary for a in t.schedules[0].attrs] == \
        ["GRID", "SUBLANE", "SUBLANE", "LANE"]


def test_template_multilevel_tiling():
    t = parse_template("op[GRID_128-SUBLANE_2,LANE];")
    lv = t.schedules[0].attrs[0].levels
    assert (lv[0].kind, lv[0].factor) == ("GRID", 128)
    assert (lv[1].kind, lv[1].factor) == ("SUBLANE", 2)


def test_template_rejects_garbage():
    with pytest.raises(ValueError):
        parse_template("op[GRID")
    with pytest.raises(ValueError):
        parse_template("op[BANANA];")
    with pytest.raises(ValueError):
        parse_template("")
