"""StitchIR, pattern generation, ILP, cycle cuts, cost model."""

import numpy as np
import pytest

from repro.core import (
    CostModel, FusionPattern, GenConfig, Graph, GraphBuilder, ILPSolver,
    OpKind, ReduceKind, TPU_V5E, V100, contraction_creates_cycle,
    exploratory_fusion, generate_patterns, multi_step_substitution,
    solve_fusion_plan, substitution_fusion,
)
from conftest import make_mlp_norm_graph, make_softmax_graph


# ---------------------------------------------------------------- IR --------

def test_graph_topo_and_validate():
    g, x, y = make_softmax_graph()
    topo = g.topo_order()
    pos = {n: i for i, n in enumerate(topo)}
    for node in g.nodes.values():
        for o in node.operands:
            assert pos[o] < pos[node.name]


def test_cycle_detection_in_builder():
    g = Graph("bad")
    from repro.core.ir import OpNode
    g.add(OpNode("a", OpKind.PARAMETER, (2,), "float32"))
    with pytest.raises(ValueError):
        g.add(OpNode("b", OpKind.ELEMENTWISE, (2,), "float32", ("missing",)))


def test_reduce_kind_classification():
    b = GraphBuilder("r")
    x = b.param("x", (8, 16, 32))
    row = b.reduce("sum", x, axes=(2,))
    col = b.reduce("sum", x, axes=(0,))
    sca = b.reduce("sum", x, axes=(0, 1, 2))
    g = b.build(outputs=[row, col, sca])
    assert g[row].reduce_kind is ReduceKind.ROW
    assert g[col].reduce_kind is ReduceKind.COLUMN
    assert g[sca].reduce_kind is ReduceKind.SCALAR


def test_external_io_and_saved_bytes():
    g, x, y = make_softmax_graph(rows=4, cols=8)
    members = frozenset(n for n in g.nodes if n != x)
    p = FusionPattern(g, members)
    assert p.external_inputs == [x]
    assert p.external_outputs == [y]
    # every intermediate is internal: 5 tensors saved x 2 (write+read)
    internal = [n for n in members if n != y]
    expected = 2 * sum(g[n].bytes for n in internal)
    assert p.saved_bytes == expected


# ------------------------------------------------------- pattern gen --------

def test_substitution_collapses_between_partitions():
    g = make_mlp_norm_graph()
    partition = {n.name for n in g.nodes.values() if n.kind is OpKind.GEMM}
    pats = substitution_fusion(g, partition)
    # everything after the dot collapses into one pattern
    assert len(pats) == 1
    assert not any("dot" in m for m in pats[0].members)


def test_multi_step_widening_fuses_gemm_eventually():
    g = make_mlp_norm_graph()
    pats = multi_step_substitution(g, GenConfig())
    assert any(any("dot" in m for m in p.members) for p in pats), \
        "later widening steps must allow small-gemm fusion"


def test_exploratory_no_cycles_and_fusible_kinds():
    g = make_mlp_norm_graph()
    cfg = GenConfig(seed_min_bytes=1024)
    pats = exploratory_fusion(g, None, cfg)
    assert pats, "exploratory fusion found nothing"
    for p in pats:
        assert not p.creates_cycle()
        for n in p.nodes:
            # small GEMMs (below large_gemm_flops) and registered custom
            # kernels are explorable alongside the classic fusible kinds
            assert n.kind in (
                OpKind.ELEMENTWISE, OpKind.BROADCAST, OpKind.RESHAPE,
                OpKind.TRANSPOSE, OpKind.REDUCTION, OpKind.BATCHED_GEMM,
                OpKind.GEMM, OpKind.CUSTOM)
            if n.kind is OpKind.GEMM:
                from repro.core.fusiongen import _gemm_flops
                assert _gemm_flops(g, n) < cfg.large_gemm_flops


def test_exploratory_excludes_large_gemms():
    g = make_mlp_norm_graph()
    # with the threshold at zero every GEMM is "large" -> never explored
    cfg = GenConfig(seed_min_bytes=1024, large_gemm_flops=0.0)
    for p in exploratory_fusion(g, None, cfg):
        assert not any(n.kind is OpKind.GEMM for n in p.nodes)


def test_contraction_cycle_detection():
    # a -> b -> c ; fusing {a, c} creates a cycle through b
    b = GraphBuilder("cyc")
    x = b.param("x", (4,))
    a = b.ew("exp", x)
    mid = b.ew("neg", a)
    c = b.ew("add", a, mid)
    g = b.build(outputs=[c])
    assert contraction_creates_cycle(g, {a, c})
    assert not contraction_creates_cycle(g, {a, mid, c})


# ---------------------------------------------------------------- ILP -------

def test_ilp_simple_packing():
    # items 0,1 conflict; 2 independent. weights favor 1+2.
    solver = ILPSolver([3.0, 4.0, 2.0], [{1}, {0}, set()])
    sel, val = solver.solve()
    assert sel == [1, 2] and val == 6.0


def test_ilp_cut_constraint():
    solver = ILPSolver([3.0, 4.0, 2.0], [set(), set(), set()])
    solver.add_cut(frozenset({0, 1, 2}))
    sel, val = solver.solve()
    assert val == 7.0 and len(sel) == 2


def test_ilp_matches_pulp_on_random_instances(rng):
    pulp = pytest.importorskip("pulp")
    for trial in range(5):
        n = 12
        w = [float(x) for x in rng.uniform(0.1, 5.0, n)]
        overlaps = [set() for _ in range(n)]
        for i in range(n):
            for j in range(i + 1, n):
                if rng.random() < 0.3:
                    overlaps[i].add(j)
                    overlaps[j].add(i)
        sel, val = ILPSolver(w, overlaps).solve()
        # pulp reference
        prob = pulp.LpProblem("sp", pulp.LpMaximize)
        xs = [pulp.LpVariable(f"x{i}", cat="Binary") for i in range(n)]
        prob += pulp.lpSum(w[i] * xs[i] for i in range(n))
        for i in range(n):
            for j in overlaps[i]:
                if i < j:
                    prob += xs[i] + xs[j] <= 1
        prob.solve(pulp.PULP_CBC_CMD(msg=0))
        ref = pulp.value(prob.objective)
        assert abs(val - ref) < 1e-6, f"trial {trial}: {val} vs pulp {ref}"


def test_plan_is_disjoint_and_acyclic():
    g = make_mlp_norm_graph()
    pats = generate_patterns(g)
    cost = CostModel()
    scores = [cost.score(p).score for p in pats]
    res = solve_fusion_plan(g, pats, scores)
    seen = set()
    for p in res.chosen:
        assert not (p.members & seen), "plan patterns overlap"
        seen |= p.members
    from repro.core.ilp import _find_cycle_patterns
    assert _find_cycle_patterns(g, res.chosen) is None


def test_pack_member_exclusivity_never_double_covers():
    """A PackPattern and vertical patterns over its member subgraphs are
    mutually exclusive in every ILP solution — whichever wins, no node is
    ever covered by two chosen patterns."""
    from repro.core.pattern import PackPattern

    b = GraphBuilder("excl")
    p0 = b.param("p0", (8, 64))
    p1 = b.param("p1", (8, 64))
    a1 = b.ew("exp", p0)
    a2 = b.ew("neg", a1)
    c1 = b.ew("exp", p1)
    c2 = b.ew("neg", c1)
    g = b.build(outputs=[a2, c2])
    pack = PackPattern(g, frozenset({a1, a2, c1, c2}), "manual",
                       member_groups=(frozenset({a1, a2}),
                                      frozenset({c1, c2})))
    pats = [pack,
            FusionPattern(g, frozenset({a1, a2}), "manual"),
            FusionPattern(g, frozenset({c1, c2}), "manual")]
    for scores in ([3.0, 2.0, 2.0], [1.0, 2.0, 2.0], [5.0, 1.0, 1.0]):
        res = solve_fusion_plan(g, pats, list(scores))
        seen = set()
        for p in res.chosen:
            assert not (p.members & seen), "node double-covered"
            seen |= p.members
        if any(getattr(p, "member_groups", None) for p in res.chosen):
            # the pack covers everything: nothing else may co-select
            assert len(res.chosen) == 1


def test_pack_pairwise_cycle_is_hard_exclusion():
    """A pack and a vertical pattern that close a cycle only when BOTH are
    contracted (P -> Q and Q -> P through different member pairs) are
    mutually excluded up front — the plan stays acyclic and keeps the
    better-scoring of the two."""
    from repro.core.ilp import _find_cycle_patterns
    from repro.core.pattern import PackPattern

    b = GraphBuilder("paircyc")
    p0 = b.param("p0", (8, 64))
    p1 = b.param("p1", (8, 64))
    a = b.ew("exp", p0)      # pack member 1
    q1 = b.ew("neg", a)      # vertical member (consumes pack)
    q2 = b.ew("relu", p1)    # vertical member (feeds pack)
    d = b.ew("tanh", q2)     # pack member 2
    g = b.build(outputs=[q1, d])
    pack = PackPattern(g, frozenset({a, d}), "manual",
                       member_groups=(frozenset({a}), frozenset({d})))
    vert = FusionPattern(g, frozenset({q1, q2}), "manual")
    assert _find_cycle_patterns(g, [pack]) is None
    assert _find_cycle_patterns(g, [vert]) is None
    assert _find_cycle_patterns(g, [pack, vert]) is not None
    for scores in ([2.0, 1.0], [1.0, 2.0]):
        res = solve_fusion_plan(g, [pack, vert], list(scores))
        assert len(res.chosen) == 1
        assert _find_cycle_patterns(g, res.chosen) is None
        assert res.objective == max(scores)


# ---------------------------------------------------------- cost model ------

def test_cost_model_monotonic_bandwidth():
    hw = TPU_V5E
    assert hw.mem_time(1 << 20) < hw.mem_time(1 << 24)
    assert hw.efficiency(1 << 10) < hw.efficiency(1 << 26) <= 1.0


def test_score_positive_for_classic_stitch():
    g, x, y = make_softmax_graph(rows=1024, cols=1024)
    members = frozenset(n for n in g.nodes if n != x)
    p = FusionPattern(g, members)
    for hw in (V100, TPU_V5E):
        s = CostModel(hw).score(p)
        assert s.feasible and s.score > 0


def test_score_rejects_over_budget():
    # column reduction -> (4M,) intermediate consumed in-kernel: its scratch
    # tile is the whole 16MB row, far over V100's 96KB shared budget.
    b = GraphBuilder("big")
    x = b.param("x", (64, 1 << 22))
    r = b.reduce("sum", x, axes=(0,))
    rb = b.bcast(r, (64, 1 << 22), (1,))
    y = b.ew("div", x, rb)
    g = b.build(outputs=[y])
    p = FusionPattern(g, frozenset([r, rb, y]))
    s = CostModel(V100).score_model_based(p)
    assert not s.feasible and "budget" in s.reason
