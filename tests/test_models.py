"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
asserting output shapes + finiteness; decode/prefill consistency; pallas vs
ref kernel-mode equivalence at the model level."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_reduced
from repro.kernels.ops import kernel_mode
from repro.models import SHAPES, build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros((B, cfg.n_patch_tokens, cfg.d_model),
                                          cfg.dtype)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step_smoke(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    loss, aux = jax.jit(model.train_forward)(params, _batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    # gradient flows and is finite
    g = jax.grad(lambda p: model.train_forward(p, _batch(cfg))[0])(params)
    gn = sum(float(jnp.sum(jnp.square(l.astype(jnp.float32))))
             for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, f"{arch} grad degenerate"


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_shapes(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    B = 2
    cache = model.init_cache(B, 64)
    tok = jnp.zeros((B, 1), jnp.int32)
    extra = {}
    if cfg.family == "audio":
        extra["enc_out"] = jnp.zeros((B, 16, cfg.d_model), cfg.dtype)
    logits, new_cache = model.decode_step(params, cache, tok, **extra)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(new_cache["length"]) == 1


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "falcon_mamba_7b",
                                  "recurrentgemma_9b"])
def test_prefill_equals_decode_loop(arch):
    """prefill(prompt) logits == feeding the prompt token-by-token."""
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 1, 8
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    lg_pre, _ = model.prefill(params, toks)
    cache = model.init_cache(B, S + 4)
    lg_dec = None
    for t in range(S):
        lg_dec, cache = model.decode_step(params, cache, toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(lg_dec),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "granite_moe_1b_a400m",
                                  "falcon_mamba_7b", "recurrentgemma_9b"])
def test_pallas_mode_matches_ref_mode(arch):
    """Whole-model forward under kernel_mode('pallas') == ref mode."""
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, B=2, S=32)
    with kernel_mode("ref"):
        l_ref, _ = model.train_forward(params, batch)
    with kernel_mode("pallas"):
        l_pal, _ = model.train_forward(params, batch)
    assert abs(float(l_ref) - float(l_pal)) < 5e-3, \
        f"{arch}: pallas {float(l_pal)} vs ref {float(l_ref)}"


def test_moe_capacity_drop_accounting():
    cfg = get_reduced("qwen2_moe_a2_7b")
    model = build_model(cfg)
    params = model.init(KEY)
    _, aux = model.train_forward(params, _batch(cfg, B=2, S=64))
    assert 0.0 <= float(aux["moe_drop_frac"]) < 0.5
    assert float(aux["moe_aux"]) > 0.5  # load-balance loss near 1 for uniform


def test_moe_block_horizontal_packing_acceptance():
    """Wide-expert MoE block (paper §4.2 acceptance): the planner must form
    >= 1 horizontal pack over the per-expert chains, compress stitched
    kernels >= 4x vs ``pack_patterns=False``, and the packed execution must
    stay bitwise-equal to ``jax.jit`` of the block."""
    from repro.core import StitchCompiler
    from repro.core.fusiongen import GenConfig
    from repro.core.trace import trace_to_graph

    cfg = get_reduced("qwen2_moe_a2_7b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, n_experts=16, top_k=2, d_expert=8192, n_shared=0))
    model = build_model(cfg)
    params = model.init(KEY)
    lp = model.layer_params(params, 0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)) * 0.1, cfg.dtype)
    g, names = trace_to_graph(model.block_fn, lp, x, name="moe_block")
    env = dict(zip(names, jax.tree_util.tree_leaves((lp, x))))
    ref_leaves = jax.tree_util.tree_leaves(jax.jit(model.block_fn)(lp, x))

    packed = StitchCompiler(mode="stitch", gen_cfg=GenConfig(
        pack_patterns=True)).compile(g, bypass_cache_lookup=True)
    unpacked = StitchCompiler(mode="stitch", gen_cfg=GenConfig(
        pack_patterns=False)).compile(g, bypass_cache_lookup=True)

    assert packed.stats.packs >= 1
    assert packed.stats.packed_subgraphs >= 2 * packed.stats.packs
    assert unpacked.stats.n_kernels >= 4 * packed.stats.n_kernels, (
        f"packing compression eroded: {unpacked.stats.n_kernels} unpacked "
        f"vs {packed.stats.n_kernels} packed")
    out = packed(env)
    for name, want in zip(g.outputs, ref_leaves):
        np.testing.assert_array_equal(np.asarray(out[name]),
                                      np.asarray(want))


def test_param_count_analytic_close_to_actual():
    for arch in ("qwen3_1_7b", "phi3_mini_3_8b", "granite_moe_1b_a400m",
                 "falcon_mamba_7b"):
        cfg = get_reduced(arch)
        model = build_model(cfg)
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree.leaves(model.init(KEY)))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, \
            f"{arch}: analytic {analytic} vs actual {actual}"


def test_full_configs_match_spec():
    """The full (non-reduced) configs carry the exact assigned sizes."""
    c = get_config("qwen2.5-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (64, 5120, 40, 8, 27648, 152064)
    assert c.qkv_bias
    c = get_config("granite-moe-1b-a400m")
    assert c.moe.n_experts == 32 and c.moe.top_k == 8 and c.vocab == 49155
    c = get_config("qwen2-moe-a2.7b")
    assert c.moe.n_experts == 60 and c.moe.top_k == 4 and c.moe.n_shared == 4
    c = get_config("falcon-mamba-7b")
    assert c.n_layers == 64 and c.ssm.d_state == 16 and c.vocab == 65024
    c = get_config("recurrentgemma-9b")
    assert c.n_layers == 38 and c.hybrid.window == 2048
    c = get_config("seamless-m4t-medium")
    assert c.n_encoder_layers == 12 and c.vocab == 256206
    c = get_config("llava-next-mistral-7b")
    assert c.n_patch_tokens == 576 and c.d_ff == 14336
    c = get_config("nemotron-4-15b")
    assert c.act == "sqrelu" and c.norm == "ln" and c.vocab == 256000
    c = get_config("phi3-mini-3.8b")
    assert c.d_model == 3072 and c.d_ff == 8192
    c = get_config("qwen3-1.7b")
    assert c.qk_norm and c.head_dim == 128
