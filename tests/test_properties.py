"""Hypothesis property tests on the fusion system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    CostModel, FusionPattern, GraphBuilder, ILPSolver, ScratchAllocator,
    build_reference_fn, generate_patterns, solve_fusion_plan,
)
from repro.core.ilp import _find_cycle_patterns


# -------------------------------------------------- random DAG strategy -----

@st.composite
def random_graph(draw):
    """Random elementwise/reduction/broadcast DAG over (r, c) tensors."""
    r = draw(st.sampled_from([8, 16, 32]))
    c = draw(st.sampled_from([16, 64, 128]))
    n_params = draw(st.integers(1, 3))
    n_ops = draw(st.integers(2, 14))
    b = GraphBuilder("rand")
    mat = [b.param(f"p{i}", (r, c)) for i in range(n_params)]  # (r,c) pool
    vec = []                                                   # (r,) pool
    unary = ["exp", "neg", "relu", "tanh", "square", "abs"]
    binary = ["add", "mul", "sub", "max", "min"]
    for i in range(n_ops):
        kind = draw(st.sampled_from(
            ["unary", "binary", "reduce", "bcast" if vec else "unary"]))
        if kind == "unary":
            mat.append(b.ew(draw(st.sampled_from(unary)),
                            draw(st.sampled_from(mat))))
        elif kind == "binary":
            mat.append(b.ew(draw(st.sampled_from(binary)),
                            draw(st.sampled_from(mat)),
                            draw(st.sampled_from(mat))))
        elif kind == "reduce":
            vec.append(b.reduce(draw(st.sampled_from(["sum", "max"])),
                                draw(st.sampled_from(mat)), axes=(1,)))
        else:
            mat.append(b.bcast(draw(st.sampled_from(vec)), (r, c), (0,)))
    outs = draw(st.lists(st.sampled_from(mat + (vec or mat)),
                         min_size=1, max_size=3, unique=True))
    return b.build(outputs=list(dict.fromkeys(outs))), r, c


@st.composite
def packing_instance(draw):
    n = draw(st.integers(1, 14))
    w = [draw(st.floats(0.1, 10.0)) for _ in range(n)]
    overlaps = [set() for _ in range(n)]
    for i in range(n):
        for j in range(i + 1, n):
            if draw(st.booleans()):
                overlaps[i].add(j)
                overlaps[j].add(i)
    return w, overlaps


# ------------------------------------------------------------- properties ---

@settings(max_examples=25, deadline=None)
@given(random_graph())
def test_plan_invariants(gr):
    """Any plan: patterns disjoint, contracted graph acyclic, scores > 0."""
    g, r, c = gr
    pats = generate_patterns(g)
    cost = CostModel()
    scores = [cost.score(p).score for p in pats]
    res = solve_fusion_plan(g, pats, scores)
    seen = set()
    for i, p in enumerate(res.chosen):
        assert not (p.members & seen)
        seen |= p.members
    assert _find_cycle_patterns(g, res.chosen) is None
    assert res.objective >= 0


@settings(max_examples=15, deadline=None)
@given(random_graph(), st.integers(0, 2**31 - 1))
def test_stitch_mode_matches_oracle(gr, seed):
    """Compiled stitch-mode execution == pure-jnp oracle on random DAGs."""
    from repro.core import StitchCompiler
    g, r, c = gr
    rng = np.random.default_rng(seed)
    inputs = {n: rng.uniform(-2, 2, size=g[n].shape).astype(np.float32)
              for n in g.nodes if g[n].is_source()}
    ref = build_reference_fn(g)(inputs)
    out = StitchCompiler(mode="stitch").compile(g)(inputs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=3e-4, atol=3e-4)


@settings(max_examples=30, deadline=None)
@given(packing_instance())
def test_ilp_optimality_vs_bruteforce(inst):
    """B&B solution == brute-force optimum for small instances."""
    w, overlaps = inst
    n = len(w)
    sel, val = ILPSolver(w, overlaps).solve()
    best = 0.0
    for mask in range(1 << n):
        chosen = [i for i in range(n) if mask >> i & 1]
        ok = all(j not in overlaps[i]
                 for a, i in enumerate(chosen) for j in chosen[a + 1:])
        if ok:
            best = max(best, sum(w[i] for i in chosen))
    assert abs(val - best) < 1e-9


@settings(max_examples=25, deadline=None)
@given(random_graph(), st.data())
def test_scratch_allocator_soundness(gr, data):
    """alloc <= request; every requesting op gets a buffer >= its request;
    two ops sharing a buffer are never live simultaneously."""
    g, r, c = gr
    candidates = [n.name for n in g.compute_nodes()]
    if not candidates:
        return
    req_ops = data.draw(st.lists(st.sampled_from(candidates), min_size=1,
                                 max_size=min(6, len(candidates)), unique=True))
    req = {n: int(g[n].bytes) for n in req_ops}
    plan = ScratchAllocator(g).allocate(req)
    assert plan.allocated <= plan.requested
    for op, buf in plan.assignment.items():
        assert plan.buffers[buf] >= req[op]
    # liveness check: if two ops share a buffer, the later one (topo order)
    # must post-dominate the earlier one
    from repro.core.scratch import _postdom_idom, post_dominates
    idom = _postdom_idom(g)
    topo = {n: i for i, n in enumerate(g.topo_order())}
    by_buf: dict[int, list[str]] = {}
    for op, buf in plan.assignment.items():
        by_buf.setdefault(buf, []).append(op)
    for buf, ops_ in by_buf.items():
        ops_ = sorted(ops_, key=lambda o: topo[o])
        for a, bnode in zip(ops_, ops_[1:]):
            assert post_dominates(idom, bnode, a), \
                f"{bnode} reuses {a}'s buffer but does not post-dominate it"


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 30), st.floats(1.0, 3.0))
def test_bandwidth_model_monotone(exp, mult):
    from repro.core import TPU_V5E
    v = 2 ** exp
    assert TPU_V5E.mem_time(v * mult) >= TPU_V5E.mem_time(v)
    assert 0 < TPU_V5E.efficiency(v) < 1


@st.composite
def gemm_chain(draw):
    """Random GEMM-bearing function: projections + nonlinearities over a
    (r, k) activation, in f32 or bf16, with 1-3 chained dots."""
    r = draw(st.sampled_from([8, 16, 32]))
    k = draw(st.sampled_from([16, 32, 64]))
    dims = [k] + [draw(st.sampled_from([16, 32, 64]))
                  for _ in range(draw(st.integers(1, 3)))]
    acts = [draw(st.sampled_from(["tanh", "gelu", "relu", "none"]))
            for _ in range(len(dims) - 1)]
    dtype = draw(st.sampled_from(["float32", "bfloat16"]))
    return r, dims, acts, dtype


@settings(max_examples=15, deadline=None)
@given(gemm_chain(), st.integers(0, 2**31 - 1))
def test_gemm_partitions_match_jit_bitwise(chain, seed):
    """Stitched execution of GEMM-bearing partitions is BITWISE equal to
    ``jax.jit`` of the same function — the accumulation dtype each dot was
    traced with (``preferred_element_type``) is replayed explicitly, so the
    op-by-op and fused executors round exactly where XLA rounds (the
    logit-wobble regression)."""
    import jax
    import jax.numpy as jnp

    from repro.core import StitchCompiler, OpKind
    from repro.core.codegen import accumulation_dtype
    from repro.core.trace import trace_to_graph

    r, dims, acts, dtype = chain
    rng = np.random.default_rng(seed)

    def mk(*shape):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 0.3,
                           dtype)

    ws = [mk(a, b) for a, b in zip(dims, dims[1:])]
    x = mk(r, dims[0])

    def f(x, *ws):
        h = x
        for w, act in zip(ws, acts):
            h = h @ w
            if act != "none":
                h = getattr(jax.nn, act, jnp.tanh)(h)
        return h

    ref = np.asarray(jax.jit(f)(x, *ws))
    g, names = trace_to_graph(f, x, *ws)
    gemms = [n for n in g.nodes.values()
             if n.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM)]
    assert gemms, "chain must trace to GEMM nodes"
    for n in gemms:
        acc = accumulation_dtype(n)
        pref = n.attrs.get("preferred")
        if pref is not None:
            # the jaxpr's traced accumulation request is replayed verbatim
            assert acc == jnp.dtype(pref)
        else:
            # float dots with no traced preference accumulate at >= f32
            assert jnp.promote_types(acc, jnp.float32) == acc
    out = StitchCompiler(mode="stitch").compile(g)(dict(zip(names, (x, *ws))))
    np.testing.assert_array_equal(np.asarray(out[g.outputs[0]]), ref)


# ------------------------------------------- training-path properties -------

@settings(max_examples=8, deadline=None)
@given(random_graph(), st.integers(0, 2**31 - 1),
       st.sampled_from(["float32", "bfloat16"]))
def test_traced_vjp_matches_stitched_execution(gr, seed, dtype):
    """The gradient of build_reference_fn's outputs, traced through
    trace_to_graph and compiled in stitch mode, equals jax.grad of the
    reference directly — backward graphs (with their backward-only
    primitives) are first-class citizens of the pipeline."""
    import jax
    import jax.numpy as jnp
    from hypothesis import assume

    from repro.core import StitchCompiler
    from repro.core.trace import trace_to_graph

    g, r, c = gr
    param_names = [n.name for n in g.nodes.values() if n.is_source()]
    ref = build_reference_fn(g)

    def scalar_fn(*flat):
        inputs = {n: x.astype(jnp.float32) for n, x in zip(param_names, flat)}
        out = ref(inputs)
        total = 0.0
        for v in out.values():
            total = total + jnp.sum(v)
        return total

    rng = np.random.default_rng(seed)
    vals = [jnp.asarray(rng.uniform(-1, 1, size=g[n].shape).astype(np.float32),
                        dtype) for n in param_names]
    argnums = tuple(range(len(vals)))
    vjp_fn = jax.grad(scalar_fn, argnums=argnums)
    grads_ref = vjp_fn(*vals)

    gg, names = trace_to_graph(vjp_fn, *vals, name="vjp")
    # duplicated outvars collapse in the IR's output list; skip those draws
    assume(len(gg.outputs) == len(vals))
    compiled = StitchCompiler(mode="stitch").compile(gg)
    out = compiled(dict(zip(names, vals)))
    tol = 3e-4 if dtype == "float32" else 2e-2
    for o, want in zip(gg.outputs, grads_ref):
        np.testing.assert_allclose(
            np.asarray(out[o], np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)


# ------------------------------------------- horizontal packing (§4.2) -----

@st.composite
def twin_chain_graph(draw):
    """K structurally-identical independent chains over private params — the
    shape horizontal packing targets (per-expert FFN tails, per-head
    epilogues).  Chains are twins by construction so the packer's structural
    twin classes must find them."""
    k = draw(st.integers(3, 6))
    r = draw(st.sampled_from([8, 16]))
    c = draw(st.sampled_from([64, 128]))
    unary = ["exp", "neg", "relu", "tanh", "square", "abs"]
    binary = ["add", "mul", "sub", "max"]
    ops = [(draw(st.sampled_from(["u", "b"])),
            draw(st.sampled_from(unary)),
            draw(st.sampled_from(binary)))
           for _ in range(draw(st.integers(2, 5)))]
    reduce_tail = draw(st.booleans())
    b = GraphBuilder("twins")
    outs = []
    for i in range(k):
        h = b.param(f"p{i}", (r, c))
        w = b.param(f"w{i}", (r, c))
        for kind, u, bi in ops:
            h = b.ew(u, h) if kind == "u" else b.ew(bi, h, w)
        outs.append(b.reduce("sum", h, axes=(1,)) if reduce_tail else h)
    return b.build(outputs=outs), k


@settings(max_examples=10, deadline=None)
@given(twin_chain_graph(), st.integers(0, 2**31 - 1))
def test_packed_independent_chains_match_jit_bitwise(gr, seed):
    """Mutually independent twin chains: the planner must form >= 1
    horizontal pack, and the packed stitched execution must be BITWISE
    equal to ``jax.jit`` of the reference function — packing shares the
    launch, it must not perturb a single bit of any member subgraph."""
    import jax

    from repro.core import StitchCompiler
    from repro.core.fusiongen import GenConfig

    g, k = gr
    rng = np.random.default_rng(seed)
    inputs = {n: rng.uniform(-1, 1, size=g[n].shape).astype(np.float32)
              for n in g.nodes if g[n].is_source()}
    ref = jax.jit(build_reference_fn(g))(inputs)
    cg = StitchCompiler(mode="stitch",
                        gen_cfg=GenConfig(pack_patterns=True)).compile(g)
    assert cg.stats.packs >= 1, "independent twins must actually pack"
    assert cg.stats.packed_subgraphs >= 2
    out = cg(inputs)
    for name in ref:
        np.testing.assert_array_equal(np.asarray(out[name]),
                                      np.asarray(ref[name]))


@st.composite
def adamw_pytree(draw):
    """Random params pytree: 1-4 leaves of rank 0-3, mixed dtypes."""
    n = draw(st.integers(1, 4))
    spec = []
    for _ in range(n):
        rank = draw(st.integers(0, 3))
        shape = tuple(draw(st.integers(1, 8)) for _ in range(rank))
        spec.append((shape, draw(st.sampled_from(["float32", "bfloat16"]))))
    rows = draw(st.sampled_from([4, 8]))
    return spec, rows


@settings(max_examples=20, deadline=None)
@given(adamw_pytree(), st.integers(0, 2**31 - 1))
def test_packed_update_matches_per_tensor_loop(inst, seed):
    """The packed multi-tensor AdamW+clip update over shared-row panels ==
    the per-tensor reference loop, for arbitrary pytree layouts (zero
    padding is a fixed point of the update)."""
    import jax.numpy as jnp

    from repro.optim import PackedAdamW, adamw

    spec, rows = inst
    rng = np.random.default_rng(seed)
    params = {f"p{i}": jnp.asarray(rng.standard_normal(s).astype(np.float32), dt)
              for i, (s, dt) in enumerate(spec)}
    grads = {f"p{i}": jnp.asarray(rng.standard_normal(s).astype(np.float32), dt)
             for i, (s, dt) in enumerate(spec)}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig()

    # pure-jnp packed execution: the property under test is the packing math
    # (the compiled single-kernel path is covered by tests/test_train_stitched)
    pa = PackedAdamW(cfg, params, rows=rows, use_compiler=False)
    new_p, new_s, metrics = pa.update(grads, state, params)
    ref_p, ref_s, ref_m = adamw.update(cfg, grads, state, params)

    np.testing.assert_allclose(float(metrics["grad_norm"]),
                               float(ref_m["grad_norm"]), rtol=1e-5)
    for i, (s, dt) in enumerate(spec):
        k = f"p{i}"
        tol = (1e-5, 1e-6) if dt == "float32" else (2e-2, 2e-2)
        assert new_p[k].dtype == ref_p[k].dtype
        np.testing.assert_allclose(np.asarray(new_p[k], np.float32),
                                   np.asarray(ref_p[k], np.float32),
                                   rtol=tol[0], atol=tol[1])
        # moments stay float32 regardless of leaf dtype
        assert new_s.m[k].dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(new_s.m[k]),
                                   np.asarray(ref_s.m[k]),
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(np.asarray(new_s.v[k]),
                                   np.asarray(ref_s.v[k]),
                                   rtol=1e-5, atol=1e-7)
