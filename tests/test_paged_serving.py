"""Disaggregated serving API: paged KV, prefix cache, and stitched
prefills.

Covers the three-stage prefill -> insert(slot) -> generate_step surface:
page-allocator invariants, paged-vs-dense token equality (including
mid-stream evict/refill and EOS truncation), stitched-prefill
miss-then-upgrade, prefix-cache hit determinism and shared-page
refcounting, the bounded prefill-specialization LRU, and the legacy
rectangular generate() deprecation."""

import numpy as np
import pytest

import jax

from repro.cache import CompilationService
from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import (Engine, PageAllocator, PageExhausted, ServeConfig)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen3_1_7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _workload(cfg, seed=42):
    rng = np.random.default_rng(seed)
    lens = [5, 12, 9, 3, 17, 7, 11]
    news = [6, 3, 9, 5, 4, 8, 2]
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32) for p in lens]
    return prompts, news


def _drain_tokens(eng, prompts, news):
    """rid is normalized per round so repeat drains of one engine compare."""
    for p, n in zip(prompts, news):
        eng.submit(p, max_new_tokens=n)
    return {f.rid % len(prompts): (list(map(int, f.tokens)), f.finish_reason)
            for f in eng.drain()}


# -- page allocator ------------------------------------------------------------

def test_allocator_exhaustion_and_lifo_reuse():
    a = PageAllocator(5)                  # pages 1..4 usable, 0 is the sink
    first = a.alloc(2)
    assert first == [1, 2]                # lowest pages first
    assert a.used == 2 and a.free_count == 2
    with pytest.raises(PageExhausted):
        a.alloc(3)                        # all-or-nothing: nothing consumed
    assert a.used == 2 and a.free_count == 2
    a.free([2])
    assert a.alloc(1) == [2]              # freed pages reused first (LIFO)
    a.free([1, 2])
    rest = a.alloc(4)
    assert sorted(rest) == [1, 2, 3, 4] and 0 not in rest
    assert a.peak_used == 4
    with pytest.raises(PageExhausted):
        a.alloc(1)


def test_allocator_refcounts_shared_pages():
    a = PageAllocator(4)
    pages = a.alloc(2)
    a.incref(pages)                       # second owner (prefix-cache share)
    a.free(pages)
    assert a.used == 2                    # still held by the other owner
    assert a.free_count == 1
    a.free(pages)
    assert a.used == 0 and a.free_count == 3


def test_allocator_rejects_degenerate_pool():
    with pytest.raises(ValueError):
        PageAllocator(1)                  # only the sink page


# -- paged vs dense equality ---------------------------------------------------

def test_paged_matches_dense_through_evict_refill(setup):
    """7 requests through 3 slots force mid-stream evict + refill; the
    paged engine (small pages, so several pages per slot) must emit exactly
    the dense engine's tokens."""
    cfg, model, params = setup
    prompts, news = _workload(cfg)
    dense = Engine(model, params, ServeConfig(batch=3, max_len=64, paged=False))
    paged = Engine(model, params,
                   ServeConfig(batch=3, max_len=64, paged=True, page_size=8))
    assert paged.paged and not dense.paged
    ref = _drain_tokens(dense, prompts, news)
    got = _drain_tokens(paged, prompts, news)
    assert got == ref
    rep = paged.kv.report()
    assert rep["used"] == 0               # every slot released on finish
    assert rep["peak_used"] > 0
    assert rep["slot_pages"] == [0, 0, 0]


def test_paged_matches_dense_with_eos_truncation(setup):
    """EOS mid-stream truncates identically on both layouts (finish_reason
    and token streams byte-for-byte)."""
    cfg, model, params = setup
    prompts, news = _workload(cfg, seed=7)
    # pick an eos id that actually occurs in the dense reference stream
    dense = Engine(model, params, ServeConfig(batch=2, max_len=64, paged=False))
    ref0 = _drain_tokens(dense, prompts, news)
    eos = ref0[0][0][-1]                  # guaranteed to appear at least once

    def run(paged):
        eng = Engine(model, params,
                     ServeConfig(batch=2, max_len=64, eos_id=eos,
                                 paged=paged, page_size=8))
        return _drain_tokens(eng, prompts, news)

    ref, got = run(False), run(True)
    assert got == ref
    assert any(r[1] == "eos" for r in ref.values())


def test_staged_generate_matches_dense(setup):
    """generate(prompts, prompt_lens=...) — the staged three-call path —
    is layout-independent."""
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    lens = [5, 12, 9]
    prompts = np.zeros((3, max(lens)), np.int32)
    for i, n in enumerate(lens):
        prompts[i, :n] = rng.integers(0, cfg.vocab, (n,))

    def run(paged):
        eng = Engine(model, params,
                     ServeConfig(batch=3, max_len=32, max_new_tokens=6,
                                 paged=paged, page_size=4))
        return eng.generate(prompts.copy(), prompt_lens=lens)

    np.testing.assert_array_equal(run(True), run(False))


# -- the three-stage API directly ----------------------------------------------

def test_manual_prefill_insert_generate_loop(setup):
    """Drive the stages by hand: prefill two prompts, insert into chosen
    slots, chunked generate, release, and reuse the freed slot."""
    cfg, model, params = setup
    rng = np.random.default_rng(11)
    eng = Engine(model, params,
                 ServeConfig(batch=2, max_len=32, page_size=4))
    p0 = rng.integers(0, cfg.vocab, (6,)).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab, (10,)).astype(np.int32)

    with pytest.raises(RuntimeError):
        eng.generate_step()               # nothing inserted yet
    px0, px1 = eng.prefill(p0), eng.prefill(p1)
    assert px0.batch == 1 and px0.bucket == 8
    eng.insert(px0, slot=0)
    eng.insert(px1, slot=1)
    with pytest.raises(RuntimeError):
        eng.insert(px1, slot=1)           # occupied
    with pytest.raises(IndexError):
        eng.insert(px1, slot=2)
    assert eng.occupied == frozenset({0, 1})
    out = eng.generate_step(steps=3)
    assert out.shape == (2, 3)

    # staged run == the same prompts through the reference engine
    ref = Engine(model, params,
                 ServeConfig(batch=1, max_len=32, max_new_tokens=4,
                             paged=False))
    for slot, p, px in ((0, p0, px0), (1, p1, px1)):
        want = ref.generate(p[None].copy(), prompt_lens=[len(p)])[0]
        stream = [int(px.first_tokens[0])] + list(map(int, out[slot]))
        assert stream == list(map(int, want))

    eng.release(0)
    assert eng.occupied == frozenset({1})
    # freed pages make the slot reusable immediately
    px2 = eng.prefill(p0)
    eng.insert(px2, slot=0)
    assert eng.occupied == frozenset({0, 1})


def test_pool_exhaustion_surfaces_at_insert(setup):
    """An undersized explicit pool raises PageExhausted instead of
    silently corrupting a live page."""
    cfg, model, params = setup
    rng = np.random.default_rng(5)
    eng = Engine(model, params,
                 ServeConfig(batch=2, max_len=32, page_size=4, num_pages=4))
    p = rng.integers(0, cfg.vocab, (12,)).astype(np.int32)   # needs 3 pages
    eng.insert(eng.prefill(p), slot=0)
    with pytest.raises(PageExhausted):
        eng.insert(eng.prefill(p), slot=1)


# -- stitched prefill ----------------------------------------------------------

def test_stitched_prefill_miss_then_upgrade(setup):
    """Prefills route through stitch(): before any plan lands each pow2
    bucket serves through the compiled fallback artifact (status pending),
    explicitly landed per-bucket plans upgrade later prefills, and tokens
    are identical before and after the upgrade.

    Seed 8 is deliberately tie-prone: before widening converts were folded
    into GEMMs (trace._fold_widening_converts) the artifact executor's
    logits wobbled one bf16 ulp off plain jit and this stream's argmax
    flipped — the equality below is the regression test for that bug."""
    cfg, model, params = setup
    rng = np.random.default_rng(8)
    plens, news = (5, 12, 9, 17), (6, 3, 9, 4)
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
               for p in plens]
    svc = CompilationService(max_background=0)   # nothing lands by itself
    eng = Engine(model, params,
                 ServeConfig(batch=2, max_len=64, stitch_execute=True),
                 stitch_service=svc)
    ref = _drain_tokens(
        Engine(model, params, ServeConfig(batch=2, max_len=64)),
        prompts, news)
    assert _drain_tokens(eng, prompts, news) == ref   # plans still pending

    rep = eng.report()["prefill"]
    assert rep["calls"]["stitched"] == len(prompts)
    plans = rep["plans"]
    assert plans and all(k.startswith("prefill@") for k in plans)
    assert {p["status"] for p in plans.values()} == {"pending"}
    assert len(plans) == 3                # buckets 8, 16, 32

    # land every plan (decode + per-bucket prefills) by hand, then re-serve
    for exec_ in (eng._prefill_exec, eng._exec):
        for sp in exec_._specs.values():
            art = svc.compiler("stitch", sp.placement).compile(
                sp.graph, bypass_cache_lookup=True)
            assert art.stats.n_kernels >= 1
    assert eng.land_plans(timeout=5.0) == 0
    assert _drain_tokens(eng, prompts, news) == ref   # upgraded round
    rep = eng.report()["prefill"]
    assert all(p["status"] == "hit" for p in rep["plans"].values())
    assert all(p["plan"]["n_kernels"] >= 1 for p in rep["plans"].values())


def test_prefill_specialization_lru_bounded(setup):
    """The prefill memo is capped at prefill_cache_size (the old
    Scheduler._prefill_fns dict grew without bound)."""
    cfg, model, params = setup
    rng = np.random.default_rng(9)
    eng = Engine(model, params,
                 ServeConfig(batch=1, max_len=64, prefill_cache_size=2))
    for plen in (3, 5, 9, 17, 33):        # buckets 4, 8, 16, 32, 64
        eng.prefill(rng.integers(0, cfg.vocab, (plen,)).astype(np.int32))
    rep = eng.report()["cache"]
    assert rep["prefill_cap"] == 2
    assert rep["prefill_entries"] == 2    # LRU evicted the older buckets


# -- prefix cache --------------------------------------------------------------

def test_prefix_cache_hit_determinism(setup):
    """A repeated prompt hits the cache and the full token stream (first
    token + decode) is identical to the miss path's."""
    cfg, model, params = setup
    rng = np.random.default_rng(21)
    eng = Engine(model, params,
                 ServeConfig(batch=2, max_len=32, page_size=4,
                             prefix_cache=True))
    p = rng.integers(0, cfg.vocab, (11,)).astype(np.int32)

    miss = eng.prefill(p)
    assert not miss.cached
    eng.insert(miss, slot=0)
    miss_toks = [int(miss.first_tokens[0])] + \
        list(map(int, eng.generate_step(steps=4)[0]))
    eng.release(0)

    hit = eng.prefill(p)
    assert hit.cached and hit.pages is not None
    assert int(hit.lengths[0]) == 11
    eng.insert(hit, slot=1)
    hit_toks = [int(hit.first_tokens[0])] + \
        list(map(int, eng.generate_step(steps=4)[1]))
    assert hit_toks == miss_toks

    rep = eng.prefix_cache.report()
    assert rep == {"hits": 1, "misses": 1, "hit_rate": 0.5,
                   "entries": 1, "pages_held": 2}   # 11 // 4 full pages


def test_prefix_cache_shared_pages_across_slots(setup):
    """Two live slots share one prefix's full pages; each decodes onto its
    private tail page, so their streams match the unshared reference and
    releasing one slot leaves the other (and the cache) intact."""
    cfg, model, params = setup
    rng = np.random.default_rng(23)
    eng = Engine(model, params,
                 ServeConfig(batch=2, max_len=32, page_size=4,
                             prefix_cache=True))
    p = rng.integers(0, cfg.vocab, (9,)).astype(np.int32)
    ref = Engine(model, params,
                 ServeConfig(batch=1, max_len=32, max_new_tokens=7,
                             paged=False))
    want = list(map(int, ref.generate(p[None].copy(), prompt_lens=[9])[0]))

    px0 = eng.prefill(p)                  # miss: registers 2 full pages
    eng.insert(px0, slot=0)
    px1 = eng.prefill(p)                  # hit: shared pages, private tail
    assert px1.cached
    eng.insert(px1, slot=1)
    out = eng.generate_step(steps=5)
    for slot, px in ((0, px0), (1, px1)):
        stream = [int(px.first_tokens[0])] + list(map(int, out[slot]))
        assert stream == want[:6], f"slot {slot}"

    held = eng.prefix_cache.pages_held
    eng.release(1)                        # decrefs shared pages
    assert eng.prefix_cache.pages_held == held   # cache still owns them
    out2 = eng.generate_step(steps=1)     # slot 0 unaffected
    assert int(out2[0, 0]) == want[6]


def test_prefix_cache_evicts_under_pool_pressure(setup):
    """Allocator pressure reclaims cold prefix entries (via the reclaim
    callback) before raising PageExhausted."""
    cfg, model, params = setup
    rng = np.random.default_rng(27)
    # 7 usable pages; each 8-token prompt costs 2 full cached pages and
    # each insert 3 (2 shared-incref'd + 1 private tail here: miss path
    # allocates ceil(8/4)=2 private pages)
    eng = Engine(model, params,
                 ServeConfig(batch=2, max_len=16, page_size=4, num_pages=8,
                             prefix_cache=True))
    p0 = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    eng.insert(eng.prefill(p0), slot=0)   # 2 slot pages + 2 cached
    assert len(eng.prefix_cache) == 1
    eng.insert(eng.prefill(p1), slot=1)   # needs 2 + 2 but only 3 free:
    assert eng.occupied == {0, 1}         # pressure evicted p0's entry
    assert len(eng.prefix_cache) == 1


def test_prefix_cache_requires_paged(setup):
    cfg, model, params = setup
    with pytest.raises(ValueError):
        Engine(model, params,
               ServeConfig(batch=1, max_len=16, paged=False,
                           prefix_cache=True))


def test_scheduler_counts_prefix_hits(setup):
    """The continuous path reports prefix hits end-to-end and repeated
    prompts still produce the reference tokens."""
    cfg, model, params = setup
    rng = np.random.default_rng(31)
    p = rng.integers(0, cfg.vocab, (9,)).astype(np.int32)
    eng = Engine(model, params,
                 ServeConfig(batch=2, max_len=32, page_size=4,
                             prefix_cache=True))
    for _ in range(4):
        eng.submit(p.copy(), max_new_tokens=5)
    fins = {f.rid: f for f in eng.drain()}
    streams = {rid: list(map(int, f.tokens)) for rid, f in fins.items()}
    assert len(set(map(tuple, streams.values()))) == 1   # all identical
    assert sum(f.prefix_cached for f in fins.values()) == 3
    assert eng.serve_report()["prefix_hits"] == 3
    assert eng.report()["prefix_cache"]["hit_rate"] == 0.75


# -- registered Pallas decode attention ----------------------------------------

def test_pallas_decode_attention_collapses_plan(setup):
    """Under kernel_mode('pallas') with unrolled layers, decode routes the
    cache attention through the registered ``_decode_attn_kernel``: paged
    tokens still match dense, the per-layer masked-softmax einsum chain is
    ONE custom node, and the stitched decode plan collapses because the
    registered kernels fuse with their neighbours instead of partitioning
    them."""
    import dataclasses

    from repro.kernels import ops

    cfg, _, _ = setup
    cfg = dataclasses.replace(cfg, scan_layers=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts, news = _workload(cfg)
    prompts, news = prompts[:3], news[:3]

    with ops.kernel_mode("pallas"):
        svc = CompilationService(max_background=0)
        paged = Engine(model, params,
                       ServeConfig(batch=2, max_len=64, paged=True,
                                   page_size=8, stitch_execute=True),
                       stitch_service=svc)
        dense = Engine(model, params,
                       ServeConfig(batch=2, max_len=64, paged=False))
        ref = _drain_tokens(dense, prompts, news)
        got = _drain_tokens(paged, prompts, news)
        assert got == ref

        g = paged._exec._active.graph
        decode_customs = [
            n for n in g.nodes.values()
            if n.kind.value == "custom"
            and n.attrs.get("kernel") == "_decode_attn_kernel"
        ]
        assert len(decode_customs) == cfg.n_layers   # one per layer
        art = svc.compiler("stitch").compile(g, bypass_cache_lookup=True)
        # the registered-kernel plan: 333 ops into 57 kernels for the
        # 2-layer reduced config (82 kernels before _decode_attn_kernel and
        # the VPU kernels were registered; the ref-mode einsum plan needs 70)
        assert art.stats.n_kernels <= 60
        assert art.stats.pallas_groups >= 10


# -- deprecation ---------------------------------------------------------------

def test_legacy_rect_generate_warns_once(setup):
    cfg, model, params = setup
    import repro.serve.engine as engine_mod
    engine_mod._LEGACY_RECT_WARNED = False
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32)
    eng = Engine(model, params,
                 ServeConfig(batch=1, max_len=16, max_new_tokens=2))
    with pytest.warns(DeprecationWarning, match="prefill"):
        eng.generate(prompts.copy())
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)   # second call: silent
        eng.generate(prompts.copy())


def test_paged_rejects_mesh_config(setup):
    cfg, model, params = setup
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >= 2 devices for a mesh")
    from jax.sharding import Mesh
    mesh = Mesh(np.array(devs[:2]), ("dp",))
    with pytest.raises(ValueError, match="paged"):
        Engine(model, params,
               ServeConfig(batch=2, max_len=16, paged=True), mesh=mesh)
