"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret mode)
against its pure-jnp oracle in ref.py."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import norms, activations, softmax as ksoftmax, rope as krope
from repro.kernels import cross_entropy as kxent, flash_attention as kflash
from repro.kernels import mamba_scan as kmamba, rg_lru as krglru, router as krouter

RNG = np.random.default_rng(7)


def _x(shape, dtype="float32", scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(dtype)


SHAPES_2D = [(8, 128), (64, 256), (33, 512), (128, 96)]
DTYPES = ["float32", "bfloat16"]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == "bfloat16" else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("dtype", DTYPES)
def test_rmsnorm_sweep(shape, dtype):
    x, g = _x(shape, dtype), _x(shape[-1:], dtype)
    np.testing.assert_allclose(
        np.asarray(norms.rmsnorm(x, g), np.float32),
        np.asarray(ref.rmsnorm(x, g), np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES_2D)
def test_rmsnorm_residual_sweep(shape):
    x, r, g = _x(shape), _x(shape), _x(shape[-1:])
    got = norms.rmsnorm_residual(x, r, g)
    want = ref.rmsnorm_residual(x, r, g)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("dtype", DTYPES)
def test_layernorm_sweep(shape, dtype):
    x = _x(shape, dtype)
    g, b = _x(shape[-1:]), _x(shape[-1:])
    np.testing.assert_allclose(
        np.asarray(norms.layernorm(x, g, b), np.float32),
        np.asarray(ref.layernorm(x, g, b), np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES_2D + [(4, 16, 64)])
@pytest.mark.parametrize("scale", [1.0, 0.125])
def test_softmax_sweep(shape, scale):
    x = _x(shape, scale=3.0)
    np.testing.assert_allclose(
        np.asarray(ksoftmax.softmax(x, scale)),
        np.asarray(ref.softmax(x, scale)), rtol=2e-5, atol=2e-6)


def test_softmax_masked_fully_masked_row():
    x = _x((4, 64))
    mask = np.ones((4, 64), bool)
    mask[2] = False  # fully-masked row must not produce NaN
    out = np.asarray(ksoftmax.softmax(x, 1.0, mask))
    assert np.isfinite(out).all()


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("dtype", DTYPES)
def test_glu_sweep(shape, dtype):
    g, u = _x(shape, dtype), _x(shape, dtype)
    np.testing.assert_allclose(
        np.asarray(activations.swiglu(g, u), np.float32),
        np.asarray(ref.swiglu(g, u), np.float32), **_tol(dtype))
    np.testing.assert_allclose(
        np.asarray(activations.geglu(g, u), np.float32),
        np.asarray(ref.geglu(g, u), np.float32), **_tol(dtype))
    np.testing.assert_allclose(
        np.asarray(activations.squared_relu(g), np.float32),
        np.asarray(ref.squared_relu(g), np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,L,H,dh", [(1, 16, 2, 32), (2, 64, 8, 64), (3, 128, 4, 128)])
@pytest.mark.parametrize("theta", [10000.0, 1e6])
def test_rope_sweep(B, L, H, dh, theta):
    x = _x((B, L, H, dh))
    pos = np.broadcast_to(np.arange(L, dtype=np.int32), (B, L))
    np.testing.assert_allclose(
        np.asarray(krope.rope(x, pos, theta)),
        np.asarray(ref.rope(x, pos, theta)), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,V", [(16, 512), (64, 4096), (33, 1000)])
def test_cross_entropy_sweep(B, V):
    logits = _x((B, V), scale=4.0)
    labels = RNG.integers(0, V, B).astype(np.int32)
    np.testing.assert_allclose(
        float(kxent.cross_entropy(logits, labels)),
        float(ref.cross_entropy(logits, labels)), rtol=1e-5)


@pytest.mark.parametrize("Hq,Hkv", [(8, 8), (8, 2), (16, 1)])
@pytest.mark.parametrize("window", [None, 32])
def test_flash_attention_sweep(Hq, Hkv, window):
    q = _x((2, 128, Hq, 64), scale=0.5)
    k = _x((2, 128, Hkv, 64), scale=0.5)
    v = _x((2, 128, Hkv, 64))
    np.testing.assert_allclose(
        np.asarray(kflash.flash_attention(q, k, v, window=window)),
        np.asarray(ref.attention(q, k, v, window=window)),
        rtol=2e-4, atol=2e-4)


def test_flash_attention_q_offset_decode_chunk():
    """Chunked prefill: second half attends to full first half."""
    q = _x((1, 64, 4, 32))
    k = _x((1, 128, 4, 32))
    v = _x((1, 128, 4, 32))
    got = kflash.flash_attention(q, k, v, q_offset=64)
    pos_q = (64 + np.arange(64))[None]
    want = ref.attention(q, k, v, positions_q=pos_q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("Bb,L,Dm,N", [(1, 16, 32, 8), (2, 48, 64, 16), (2, 33, 128, 16)])
def test_mamba_scan_sweep(Bb, L, Dm, N):
    x = _x((Bb, L, Dm), scale=0.5)
    dt = np.abs(_x((Bb, L, Dm), scale=0.1))
    A = -np.abs(_x((Dm, N)))
    B = _x((Bb, L, N), scale=0.3)
    C = _x((Bb, L, N), scale=0.3)
    D = _x((Dm,))
    np.testing.assert_allclose(
        np.asarray(kmamba.mamba_scan(x, dt, A, B, C, D)),
        np.asarray(ref.mamba_scan(x, dt, A, B, C, D)), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,L,D", [(1, 16, 64), (2, 48, 128), (2, 37, 256)])
def test_rg_lru_sweep(B, L, D):
    x, ig, rg_, lam = _x((B, L, D)), _x((B, L, D)), _x((B, L, D)), _x((D,))
    np.testing.assert_allclose(
        np.asarray(krglru.rg_lru(x, ig, rg_, lam)),
        np.asarray(ref.rg_lru(x, ig, rg_, lam)), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("T,E,k", [(64, 8, 2), (256, 60, 4), (128, 32, 8)])
def test_router_sweep(T, E, k):
    logits = _x((T, E), scale=2.0)
    w1, i1 = krouter.topk_router(logits, k)
    w2, i2 = ref.topk_router(logits, k)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-5, atol=1e-6)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


def test_router_weights_sum_to_one():
    logits = _x((64, 16), scale=2.0)
    w, _ = krouter.topk_router(logits, 4)
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, rtol=1e-5)


def test_mamba_final_state_matches_incremental():
    """prefill state == running decode steps one by one."""
    Bb, L, Dm, N = 1, 8, 16, 4
    x = _x((Bb, L, Dm), scale=0.5)
    dt = np.abs(_x((Bb, L, Dm), scale=0.1))
    A = -np.abs(_x((Dm, N)))
    B = _x((Bb, L, N), scale=0.3)
    C = _x((Bb, L, N), scale=0.3)
    D = _x((Dm,))
    _, h = ref.mamba_scan(x, dt, A, B, C, D, return_state=True)
    hinc = np.zeros((Bb, Dm, N), np.float32)
    for t in range(L):
        dA = np.exp(dt[:, t, :, None] * A[None])
        dBx = (dt[:, t] * x[:, t])[..., None] * B[:, t][:, None, :]
        hinc = dA * hinc + dBx
    np.testing.assert_allclose(np.asarray(h), hinc, rtol=1e-4, atol=1e-5)
