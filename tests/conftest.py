# The tier-1 suite runs against a forced 8-device host platform so the
# sharded-stitching equality tests exercise a real (4, 2) mesh in CI and
# locally without extra flags.  Must happen before the first jax import —
# jax locks the device count at first init.  An operator-provided count
# (XLA_FLAGS already set) is respected; hostenv itself is jax-free.
from repro.launch.hostenv import force_host_devices

force_host_devices(8)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_softmax_graph(rows=64, cols=256):
    from repro.core import GraphBuilder

    b = GraphBuilder("softmax")
    x = b.param("x", (rows, cols))
    m = b.reduce("max", x, axes=(1,))
    mb = b.bcast(m, (rows, cols), (0,))
    e = b.ew("exp", b.ew("sub", x, mb))
    s = b.reduce("sum", e, axes=(1,))
    sb = b.bcast(s, (rows, cols), (0,))
    y = b.ew("div", e, sb)
    return b.build(outputs=[y]), x, y


def make_mlp_norm_graph(rows=128, d=256):
    """gemm + layernorm-ish + activation: mixes all op classes."""
    from repro.core import GraphBuilder

    b = GraphBuilder("mlp_norm")
    x = b.param("x", (rows, d))
    w = b.param("w", (d, d))
    g = b.param("gamma", (d,))
    h = b.dot(x, w, name="dot_0")
    mu = b.reduce("mean", h, axes=(1,), keepdims=True)
    dlt = b.ew("sub", h, b.bcast(mu, (rows, d), (0, 1)))
    v = b.reduce("mean", b.ew("square", dlt), axes=(1,), keepdims=True)
    r = b.ew("rsqrt", b.ew("add", v, b.const("eps", ())))
    y = b.ew("mul", b.ew("mul", dlt, b.bcast(r, (rows, d), (0, 1))),
             b.bcast(g, (rows, d), (1,)))
    z = b.ew("relu", y)
    return b.build(outputs=[z])
