"""TrainState + train_step factory: grad accumulation (microbatching),
clipping, AdamW, metrics — the function the launcher jits with shardings.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: jax.Array


def init_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_loss_and_grad(model: Model, microbatches: int = 1) -> Callable:
    """Returns grad_phase(params, batch) -> (loss, aux, grads) — the
    ``jax.value_and_grad``-built backward phase of a train step, shared by
    the jitted step below and the stitched step
    (:mod:`repro.train.stitched_step`), which traces it to StitchIR.

    ``microbatches > 1`` splits the per-step batch on the leading axis and
    accumulates grads sequentially (same math, 1/microbatches the activation
    memory) — gradient accumulation for large global batches."""

    def loss_fn(params, batch):
        loss, aux = model.train_forward(params, batch)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, aux), grads = grad_fn(params, batch)
        return loss, aux, grads

    def accumulated(params, batch):
        def micro(carry, mb):
            acc, loss_sum = carry
            (loss, _aux), grads = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_sum + loss), None

        mbs = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]),
            batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        return loss_sum / microbatches, {}, grads

    return accumulated if microbatches > 1 else single


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    microbatches: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    grad_phase = make_loss_and_grad(model, microbatches)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, aux, grads = grad_phase(state.params, batch)
        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, state.opt, state.params)
        metrics = {"loss": loss, "step": state.step + 1, **opt_metrics, **aux}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
