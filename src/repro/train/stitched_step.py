"""Stitched training step — the fusion pipeline applied to the backward pass
and the optimizer phase.

Training is the paper's canonical memory-intensive workload: the backward
pass of norms/softmax/cross-entropy and the AdamW+clip update are pure
elementwise+reduction traffic over every parameter.  This module routes both
phases of :func:`repro.train.step.make_train_step` through the stitch
compiler:

* **Backward phase** — the ``jax.value_and_grad``-built loss+grad function
  (:func:`~repro.train.step.make_loss_and_grad`, including microbatch
  accumulation) is traced to StitchIR with
  :func:`~repro.core.trace.trace_to_graph`.  Backward-only primitives are
  covered first-class where the IR has a kind (scatter-add from embedding
  gradients, ``add_any`` grad accumulation, trig from RoPE) and fall back to
  executable CUSTOM nodes otherwise (``scan`` bodies, iota) — those
  partition fusion exactly like the paper's opaque ops but keep the graph
  runnable end-to-end.
* **Optimizer phase** — the params pytree is flattened into shared-row
  panels and the whole AdamW+global-norm-clip update becomes ONE packed
  kernel (:class:`repro.optim.packed.PackedAdamW`): independent per-tensor
  update chains sharing a single kernel's grid, the paper's "fusion without
  data dependences".

Both graphs compile through :class:`repro.cache.CompilationService`
miss-then-upgrade: step 0 executes the instantly-available XLA-mode
fallback artifact (identical numerics), the full stitch pipeline runs on a
background thread, and every later step polls the cache so the run upgrades
to stitched plans mid-flight — mirroring the serving engine's behavior.

If tracing or compilation fails outright the step degrades to the plain
jitted reference (status ``"error"``); a per-call shape drift (e.g. a
last-partial batch) falls back to the jitted step for that call only.
"""

from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim import adamw
from repro.optim.packed import PackedAdamW

from .step import TrainState, make_loss_and_grad, make_train_step


def _avals(tree) -> tuple:
    return tuple(
        (getattr(x, "shape", ()), str(getattr(x, "dtype", type(x).__name__)))
        for x in jax.tree_util.tree_leaves(tree))


class _TracedPhase:
    """One traced-and-compiled function with miss-then-upgrade polling."""

    def __init__(self, fn, example_args, service, name: str):
        from repro.cache.signature import compute_signature
        from repro.core.trace import trace_to_graph

        self.status = "error"
        self.graph = None
        self.compiled = None
        try:
            self.graph, self.names = trace_to_graph(fn, *example_args, name=name)
            self.out_tree = jax.tree_util.tree_structure(
                jax.eval_shape(fn, *example_args))
            if self.out_tree.num_leaves != len(self.graph.outputs):
                return                       # duplicated outputs: not executable
            self.compiled, self.status = service.compile_or_fallback(self.graph)
            self.sig = compute_signature(self.graph)
            self.compiler = service.compiler("stitch")
            self.service = service
            self.in_avals = _avals(example_args)
        except Exception:
            self.graph = None
            self.compiled = None

    @property
    def ok(self) -> bool:
        return self.compiled is not None

    def eligible(self, args) -> bool:
        return self.ok and _avals(args) == self.in_avals

    def poll_upgrade(self) -> None:
        if not self.ok or self.status not in ("miss", "pending"):
            return
        hit = self.service.cache.lookup(self.graph, self.compiler,
                                        sig=self.sig, count=False)
        if hit is not None:
            self.compiled = hit
            self.status = "hit"
        else:
            # re-kick if the background compile was deferred (worker cap) or
            # died — a training run must not serve the fallback forever
            self.service.ensure_compiling(self.graph, sig=self.sig)

    def run(self, *args):
        env = dict(zip(self.names, jax.tree_util.tree_leaves(args)))
        outs = self.compiled(env)
        flat = [outs[o] for o in self.graph.outputs]
        return jax.tree_util.tree_unflatten(self.out_tree, flat)

    def plan_stats(self) -> dict | None:
        if self.compiled is None:
            return None
        s = self.compiled.stats
        return {"mode": s.mode, "n_kernels": s.n_kernels, "n_ops": s.n_ops,
                "pallas_groups": s.pallas_groups, "modeled_time": s.modeled_time,
                "cache_status": s.cache_status}


class StitchedTrainStep:
    """Drop-in for :func:`make_train_step`'s returned callable:
    ``step(state, batch) -> (state, metrics)`` with identical numerics, the
    backward pass and the packed optimizer executing through stitched
    artifacts (upgrading from the XLA fallback as background compiles land).
    """

    def __init__(self, model: Model, opt_cfg: adamw.AdamWConfig,
                 microbatches: int = 1, service=None,
                 rows: int = 8):
        if service is None:
            from repro.cache import CompilationService
            service = CompilationService()
        self.model = model
        self.opt_cfg = opt_cfg
        self.microbatches = microbatches
        self.service = service
        self.rows = rows
        self._grad_fn = make_loss_and_grad(model, microbatches)
        # reference step: full-jit fallback for trace failures / shape drift
        self._jit_step = jax.jit(make_train_step(model, opt_cfg, microbatches))
        self._grad: _TracedPhase | None = None
        self._packed: PackedAdamW | None = None
        self.fallback_steps = 0              # calls served by the jitted step

    # -- lazy preparation ------------------------------------------------------
    def _prepare(self, state: TrainState, batch) -> None:
        self._grad = _TracedPhase(self._grad_fn, (state.params, batch),
                                  self.service, name="train_grad")
        try:
            self._packed = PackedAdamW(self.opt_cfg, state.params,
                                       rows=self.rows, service=self.service)
        except Exception:
            self._packed = None

    # -- observability --------------------------------------------------------
    def report(self) -> dict:
        out: dict[str, Any] = {
            "grad": {"status": self._grad.status if self._grad else None},
            "optimizer": self._packed.report() if self._packed else {"status": None},
            "fallback_steps": self.fallback_steps,
        }
        if self._grad is not None and self._grad.plan_stats() is not None:
            out["grad"]["plan"] = self._grad.plan_stats()
        if self.service is not None:
            out["cache"] = self.service.cache.report()
            out["service_error"] = self.service.last_error
        return out

    # -- the step --------------------------------------------------------------
    def __call__(self, state: TrainState, batch) -> tuple[TrainState, dict]:
        if self._grad is None:
            self._prepare(state, batch)
        grad_ok = self._grad.eligible((state.params, batch))
        if not grad_ok or self._packed is None:
            self.fallback_steps += 1
            return self._jit_step(state, batch)
        self._grad.poll_upgrade()
        loss, aux, grads = self._grad.run(state.params, batch)
        new_params, new_opt, opt_metrics = self._packed.update(
            grads, state.opt, state.params)
        metrics = {"loss": loss, "step": state.step + 1, **opt_metrics, **aux}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    # -- orderly shutdown ------------------------------------------------------
    def wait(self, timeout: float | None = None) -> None:
        """Join in-flight background compiles (tests / clean exit)."""
        self.service.wait(timeout)
