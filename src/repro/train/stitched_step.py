"""Stitched training step — the fusion pipeline applied to the backward pass
and the optimizer phase, on one device or over a whole mesh.

Training is the paper's canonical memory-intensive workload: the backward
pass of norms/softmax/cross-entropy and the AdamW+clip update are pure
elementwise+reduction traffic over every parameter.  This module routes both
phases of :func:`repro.train.step.make_train_step` through the stitch
compiler:

* **Backward phase** — the ``jax.value_and_grad``-built loss+grad function
  (:func:`~repro.train.step.make_loss_and_grad`, including microbatch
  accumulation) is traced to StitchIR with
  :func:`~repro.core.trace.trace_to_graph`.  Backward-only primitives are
  covered first-class where the IR has a kind (scatter-add from embedding
  gradients, ``add_any`` grad accumulation, trig from RoPE) and fall back to
  executable CUSTOM nodes otherwise (``scan`` bodies, iota) — those
  partition fusion exactly like the paper's opaque ops but keep the graph
  runnable end-to-end.
* **Optimizer phase** — the params pytree is flattened into shared-row
  panels and the whole AdamW+global-norm-clip update becomes ONE packed
  kernel (:class:`repro.optim.packed.PackedAdamW`): independent per-tensor
  update chains sharing a single kernel's grid, the paper's "fusion without
  data dependences".

Both graphs compile through :class:`repro.cache.CompilationService`
miss-then-upgrade: step 0 executes the instantly-available XLA-mode
fallback artifact (identical numerics), the full stitch pipeline runs on a
background thread, and every later step polls the cache so the run upgrades
to stitched plans mid-flight — mirroring the serving engine's behavior.

Mesh-aware execution (``mesh=`` + forced host devices, or a real slice):
both stitched phases dispatch through :func:`jax.experimental.shard_map`
with *per-shard* graphs traced and solved at shard-local shapes, and their
cache keys carry a mesh+PartitionSpec placement component so a plan solved
at one mesh never replays at another:

* the **backward** body sees the params gathered (``in_specs=P()``; params
  may live TP-sharded at rest) and the batch rows split over every mesh
  axis that divides them — the model axis moonlights as extra data
  parallelism, since the shard-local body contains no TP collectives.  The
  DP gradient/loss ``psum``-mean runs *outside* the stitched region, at the
  tail of the shard_map body.
* the **optimizer** body updates TP-shard-local parameter panels: the
  packed kernel's operands are each shard's slice of the param/grad/moment
  trees (the shard_map boundary does the slicing), with the global-norm
  clip scale fed in as a scalar computed from the reduced full gradients
  (``PackedAdamW(external_ssq=True)``).  New params come back TP-sharded;
  opt moments stay co-located with their params (no ZeRO offset — the
  panels must be shard-local slices of both).

The consumed ``TrainState`` is donated by default (``donate=False`` opts
out): the jit fallback uses ``donate_argnums`` and the stitched dispatch
deletes the old params/moments once the update has been dispatched, so peak
memory holds one copy of params+opt, not two.

If tracing or compilation fails outright the step degrades to the plain
jitted reference (status ``"error"``); a per-call shape drift (e.g. a
last-partial batch) falls back to the jitted step for that call only.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.api import Model
from repro.optim import adamw
from repro.optim.packed import PackedAdamW

from .step import TrainState, make_loss_and_grad, make_train_step


def _avals(tree) -> tuple:
    return tuple(
        (getattr(x, "shape", ()), str(getattr(x, "dtype", type(x).__name__)))
        for x in jax.tree_util.tree_leaves(tree))


class _TracedPhase:
    """One traced-and-compiled function with miss-then-upgrade polling."""

    def __init__(self, fn, example_args, service, name: str,
                 placement: str = ""):
        from repro.cache.signature import compute_signature
        from repro.core.trace import trace_to_graph

        self.status = "error"
        self.graph = None
        self.compiled = None
        self.placement = placement
        try:
            self.graph, self.names = trace_to_graph(fn, *example_args, name=name)
            self.out_tree = jax.tree_util.tree_structure(
                jax.eval_shape(fn, *example_args))
            if self.out_tree.num_leaves != len(self.graph.outputs):
                return                       # duplicated outputs: not executable
            self.compiled, self.status = service.compile_or_fallback(
                self.graph, placement=placement)
            self.sig = compute_signature(self.graph)
            self.compiler = service.compiler("stitch", placement)
            self.service = service
            self.in_avals = _avals(example_args)
        except Exception:
            self.graph = None
            self.compiled = None

    @property
    def ok(self) -> bool:
        return self.compiled is not None

    def eligible(self, args) -> bool:
        return self.ok and _avals(args) == self.in_avals

    def poll_upgrade(self) -> None:
        if not self.ok or self.status not in ("miss", "pending"):
            return
        hit = self.service.cache.lookup(self.graph, self.compiler,
                                        sig=self.sig, count=False)
        if hit is not None:
            self.compiled = hit
            self.status = "hit"
        else:
            # re-kick if the background compile was deferred (worker cap) or
            # died — a training run must not serve the fallback forever
            self.service.ensure_compiling(self.graph, sig=self.sig,
                                          placement=self.placement)

    def run(self, *args):
        env = dict(zip(self.names, jax.tree_util.tree_leaves(args)))
        outs = self.compiled(env)
        flat = [outs[o] for o in self.graph.outputs]
        return jax.tree_util.tree_unflatten(self.out_tree, flat)

    def plan_stats(self) -> dict | None:
        if self.compiled is None:
            return None
        s = self.compiled.stats
        return {"mode": s.mode, "n_kernels": s.n_kernels, "n_ops": s.n_ops,
                "pallas_groups": s.pallas_groups, "modeled_time": s.modeled_time,
                "cache_status": s.cache_status}


class StitchedTrainStep:
    """Drop-in for :func:`make_train_step`'s returned callable:
    ``step(state, batch) -> (state, metrics)`` with identical numerics, the
    backward pass and the packed optimizer executing through stitched
    artifacts (upgrading from the XLA fallback as background compiles land).

    With ``mesh`` (size > 1) both phases run under ``shard_map`` on
    per-shard graphs — see the module docstring.  ``param_specs`` overrides
    the TP rule table; ``donate=False`` keeps the consumed state alive.
    """

    def __init__(self, model: Model, opt_cfg: adamw.AdamWConfig,
                 microbatches: int = 1, service=None,
                 rows: int = 8, mesh: Mesh | None = None,
                 param_specs=None, donate: bool = True):
        if service is None:
            from repro.cache import CompilationService
            service = CompilationService()
        self.model = model
        self.opt_cfg = opt_cfg
        self.microbatches = microbatches
        self.service = service
        self.rows = rows
        self.donate = donate
        self.mesh = mesh if (mesh is not None and mesh.size > 1) else None
        self.param_specs = None
        if self.mesh is not None:
            from repro.models.sharding import param_pspecs
            self.param_specs = (param_specs if param_specs is not None else
                                param_pspecs(model.abstract_params(),
                                             model.cfg, self.mesh))
        self._grad_fn = make_loss_and_grad(model, microbatches)
        # reference step: full-jit fallback for trace failures / shape drift
        # (donating, like the launcher's jit path; under a mesh it picks the
        # sharded layout up from its operands via GSPMD)
        self._jit_step = jax.jit(make_train_step(model, opt_cfg, microbatches),
                                 donate_argnums=(0,) if donate else ())
        self._prepared = False
        self._grad: _TracedPhase | None = None
        self._packed: PackedAdamW | None = None
        self._grad_sm = None                 # shard_map'd backward dispatch
        self._upd_sm = None                  # shard_map'd optimizer dispatch
        self._global_avals = None            # sharded-path eligibility key
        self.fallback_steps = 0              # calls served by the jitted step

    # -- lazy preparation ------------------------------------------------------
    def _prepare(self, state: TrainState, batch) -> None:
        self._prepared = True
        if self.mesh is not None:
            self._prepare_sharded(state, batch)
            return
        self._grad = _TracedPhase(self._grad_fn, (state.params, batch),
                                  self.service, name="train_grad")
        try:
            self._packed = PackedAdamW(self.opt_cfg, state.params,
                                       rows=self.rows, service=self.service)
        except Exception:
            self._packed = None

    def _prepare_sharded(self, state: TrainState, batch) -> None:
        from repro.cache.signature import placement_key
        from repro.models.sharding import (batch_shard_axes, local_avals)

        mesh = self.mesh
        self._global_avals = _avals((state.params, batch))
        aparams = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state.params)
        pspecs = self.param_specs
        B = jax.tree_util.tree_leaves(batch)[0].shape[0]
        axes = batch_shard_axes(mesh, B)
        bspecs = jax.tree.map(
            lambda l: P() if l.ndim == 0 else
            P(*((axes if axes else None,) + (None,) * (l.ndim - 1))),
            batch)
        local_batch = local_avals(batch, bspecs, mesh)
        B_local = jax.tree_util.tree_leaves(local_batch)[0].shape[0]
        if self.microbatches > 1 and B_local % self.microbatches:
            # shard-local rows don't split into microbatches: serve the
            # sharded jit fallback rather than change the accumulation math
            self._grad = None
            self._packed = None
            return
        # backward: per-shard graph at (full params, shard-local batch)
        grad_pl = placement_key(mesh, (P(), bspecs))
        self._grad = _TracedPhase(self._grad_fn, (aparams, local_batch),
                                  self.service, name="train_grad",
                                  placement=grad_pl)
        # optimizer: per-shard packed panels over TP-local param slices
        try:
            local_params = local_avals(aparams, pspecs, mesh)
            self._packed = PackedAdamW(
                self.opt_cfg, local_params, rows=self.rows,
                service=self.service, external_ssq=True,
                placement=placement_key(mesh, pspecs))
        except Exception:
            self._packed = None
        if self._grad is None or not self._grad.ok or self._packed is None:
            return

        allax = tuple(mesh.axis_names)

        def local_grad(params, b):
            loss, aux, grads = self._grad.run(params, b)
            # DP psum-mean OUTSIDE the stitched region: the executable above
            # computed this shard's rows only
            loss = jax.lax.pmean(loss, allax)
            aux = jax.tree.map(lambda a: jax.lax.pmean(a, allax), aux)
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g.astype(jnp.float32), allax), grads)
            return loss, aux, grads

        self._grad_sm = shard_map(
            local_grad, mesh=mesh, in_specs=(P(), bspecs),
            out_specs=(P(), P(), P()), check_rep=False)

        def local_update(params, grads, m, v, lr, b1c, b2c, gss):
            return self._packed.update_local(params, grads, m, v,
                                             lr, b1c, b2c, gss=gss)

        sc = P()
        self._upd_sm = shard_map(
            local_update, mesh=mesh,
            in_specs=(pspecs, pspecs, pspecs, pspecs, sc, sc, sc, sc),
            out_specs=(pspecs, pspecs, pspecs, sc), check_rep=False)

    # -- mesh placement for the launcher --------------------------------------
    def state_shardings(self) -> TrainState:
        """NamedSharding placement for the ``TrainState`` this step expects:
        params TP-sharded per the rule table, opt m/v co-located with their
        params (the packed panels update shard-local slices of both — a
        ZeRO offset would misalign them), count/step replicated."""
        assert self.mesh is not None, "state_shardings requires a mesh"
        sh = lambda s: NamedSharding(self.mesh, s)
        pshard = jax.tree.map(sh, self.param_specs,
                              is_leaf=lambda x: isinstance(x, P))
        return TrainState(
            params=pshard,
            opt=adamw.AdamWState(m=pshard, v=pshard, count=sh(P())),
            step=sh(P()))

    # -- donation --------------------------------------------------------------
    def _delete_consumed(self, state: TrainState) -> None:
        """Free the old params and moments once the update is dispatched —
        the stitched analogue of the jit path's ``donate_argnums=(0,)``.
        Without it the consumed state stays alive across the step and peak
        memory holds params+opt twice."""
        for leaf in jax.tree_util.tree_leaves(
                (state.params, state.opt.m, state.opt.v)):
            if isinstance(leaf, jax.Array) and not leaf.is_deleted():
                leaf.delete()

    # -- observability --------------------------------------------------------
    def report(self) -> dict:
        out: dict[str, Any] = {
            "grad": {"status": self._grad.status if self._grad else None},
            "optimizer": self._packed.report() if self._packed else {"status": None},
            "fallback_steps": self.fallback_steps,
        }
        if self.mesh is not None:
            out["mesh"] = dict(self.mesh.shape)
        if self._grad is not None and self._grad.plan_stats() is not None:
            out["grad"]["plan"] = self._grad.plan_stats()
        if self.service is not None:
            out["cache"] = self.service.cache.report()
            out["service_error"] = self.service.last_error
        return out

    # -- the step --------------------------------------------------------------
    def __call__(self, state: TrainState, batch) -> tuple[TrainState, dict]:
        if not self._prepared:
            self._prepare(state, batch)
        if self.mesh is not None:
            return self._call_sharded(state, batch)
        grad_ok = self._grad.eligible((state.params, batch))
        if not grad_ok or self._packed is None:
            self.fallback_steps += 1
            return self._jit_step(state, batch)
        self._grad.poll_upgrade()
        loss, aux, grads = self._grad.run(state.params, batch)
        new_params, new_opt, opt_metrics = self._packed.update(
            grads, state.opt, state.params)
        metrics = {"loss": loss, "step": state.step + 1, **opt_metrics, **aux}
        out = TrainState(new_params, new_opt, state.step + 1), metrics
        if self.donate:
            self._delete_consumed(state)
        return out

    def _call_sharded(self, state: TrainState, batch) -> tuple[TrainState, dict]:
        ok = (self._grad is not None and self._grad.ok
              and self._packed is not None and self._upd_sm is not None
              and _avals((state.params, batch)) == self._global_avals)
        if not ok:
            self.fallback_steps += 1
            return self._jit_step(state, batch)
        self._grad.poll_upgrade()
        self._packed.poll_upgrade()
        loss, aux, grads = self._grad_sm(state.params, batch)
        cfg = self.opt_cfg
        count = state.opt.count + 1
        lr = adamw.schedule(cfg, count)
        cf = count.astype(jnp.float32)
        b1c = 1 - cfg.b1 ** cf
        b2c = 1 - cfg.b2 ** cf
        # global clip scale from the reduced full grads — replicated, so
        # every shard's packed kernel sees the same scalar
        gss = functools.reduce(
            jnp.add, [jnp.sum(jnp.square(g))
                      for g in jax.tree_util.tree_leaves(grads)])
        new_p, new_m, new_v, gnorm = self._upd_sm(
            state.params, grads, state.opt.m, state.opt.v,
            jnp.asarray(lr, jnp.float32), jnp.asarray(b1c, jnp.float32),
            jnp.asarray(b2c, jnp.float32), gss)
        metrics = {"loss": loss, "step": state.step + 1, "grad_norm": gnorm,
                   "lr": lr, **aux}
        out = (TrainState(new_p, adamw.AdamWState(new_m, new_v, count),
                          state.step + 1), metrics)
        if self.donate:
            self._delete_consumed(state)
        return out

    # -- orderly shutdown ------------------------------------------------------
    def wait(self, timeout: float | None = None) -> None:
        """Join in-flight background compiles (tests / clean exit)."""
        self.service.wait(timeout)
