"""Stitched training step — two :func:`repro.exec.stitch` callables plus
training-specific glue.

Training is the paper's canonical memory-intensive workload: the backward
pass of norms/softmax/cross-entropy and the AdamW+clip update are pure
elementwise+reduction traffic over every parameter.  Since the
``repro.exec`` refactor this module owns only the *training* decisions —
everything about tracing, compile-or-fallback, miss-then-upgrade polling,
shard_map construction, and placement-keyed caching lives in the shared
execution layer:

* **Backward phase** — the ``jax.value_and_grad``-built loss+grad function
  (:func:`~repro.train.step.make_loss_and_grad`, including microbatch
  accumulation) becomes one ``stitch()``-produced callable.  Under a mesh
  the stitched function is the *shard-local* body with the DP ``pmean`` of
  loss/aux/grads written at its tail (the psum-mean placement is glue; the
  collectives trace via ``axis_env`` into executable CUSTOM fusion
  partitions), ``in_specs=(P(), batch_specs)`` so params arrive gathered
  (TP-at-rest storage fine) and batch rows split over every dividing mesh
  axis.
* **Optimizer phase** — :class:`repro.optim.packed.PackedAdamW` (itself
  built on ``stitch()``): the whole AdamW+global-norm-clip update is ONE
  packed kernel over shared-row panels.  Under a mesh the packed kernel
  updates TP-shard-local param panels inside a
  :func:`repro.exec.shard_wrap` dispatch (rebuilt when an upgrade swaps
  the artifact), with the clip scale fed as a scalar from the reduced full
  gradients (``external_ssq=True``); m/v stay co-located with params via
  :meth:`state_shardings` — no ZeRO on this path.

The consumed ``TrainState`` is donated by default (``donate=False`` opts
out): the jit fallback uses ``donate_argnums`` and the stitched dispatch
deletes the old params/moments once the update has been dispatched, so peak
memory holds one copy of params+opt, not two.

If tracing or compilation fails outright the step degrades to the plain
jitted reference; a per-call shape drift (e.g. a last-partial batch) falls
back to the jitted step for that call only (``fallback_steps`` counts
both).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.exec import shard_wrap, stitch, tree_avals as _avals
from repro.models.api import Model
from repro.optim import adamw
from repro.optim.packed import PackedAdamW

from .step import TrainState, make_loss_and_grad, make_train_step


class StitchedTrainStep:
    """Drop-in for :func:`make_train_step`'s returned callable:
    ``step(state, batch) -> (state, metrics)`` with identical numerics, the
    backward pass and the packed optimizer executing through stitched
    artifacts (upgrading from the XLA fallback as background compiles land).

    With ``mesh`` (size > 1) both phases run under ``shard_map`` on
    per-shard graphs — see the module docstring.  ``param_specs`` overrides
    the TP rule table; ``donate=False`` keeps the consumed state alive.
    """

    def __init__(self, model: Model, opt_cfg: adamw.AdamWConfig,
                 microbatches: int = 1, service=None,
                 rows: int = 8, mesh: Mesh | None = None,
                 param_specs=None, donate: bool = True):
        if service is None:
            from repro.cache import CompilationService
            service = CompilationService()
        self.model = model
        self.opt_cfg = opt_cfg
        self.microbatches = microbatches
        self.service = service
        self.rows = rows
        self.donate = donate
        self.mesh = mesh if (mesh is not None and mesh.size > 1) else None
        self.param_specs = None
        if self.mesh is not None:
            from repro.models.sharding import param_pspecs
            self.param_specs = (param_specs if param_specs is not None else
                                param_pspecs(model.abstract_params(),
                                             model.cfg, self.mesh))
        self._grad_fn = make_loss_and_grad(model, microbatches)
        # reference step: full-jit fallback for trace failures / shape drift
        # (donating, like the launcher's jit path; under a mesh it picks the
        # sharded layout up from its operands via GSPMD)
        self._jit_step = jax.jit(make_train_step(model, opt_cfg, microbatches),
                                 donate_argnums=(0,) if donate else ())
        self._prepared = False
        self._grad = None                    # stitch()-produced backward phase
        self._packed: PackedAdamW | None = None
        self._upd_dispatch = None            # shard_wrap'd optimizer dispatch
        self._sharded_ok = False
        self._global_avals = None            # sharded-path eligibility key
        self.fallback_steps = 0              # calls served by the jitted step

    # -- lazy preparation ------------------------------------------------------
    def _prepare(self, state: TrainState, batch) -> None:
        self._prepared = True
        if self.mesh is not None:
            self._prepare_sharded(state, batch)
            return
        self._grad = stitch(self._grad_fn, service=self.service,
                            name="train_grad")
        self._grad.warmup(state.params, batch)
        try:
            self._packed = PackedAdamW(self.opt_cfg, state.params,
                                       rows=self.rows, service=self.service)
        except Exception:
            self._packed = None

    def _prepare_sharded(self, state: TrainState, batch) -> None:
        from repro.cache.signature import placement_key
        from repro.models.sharding import batch_shard_axes, local_avals

        mesh = self.mesh
        self._global_avals = _avals((state.params, batch))
        aparams = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state.params)
        pspecs = self.param_specs
        B = jax.tree_util.tree_leaves(batch)[0].shape[0]
        axes = batch_shard_axes(mesh, B)
        bspecs = jax.tree.map(
            lambda l: P() if l.ndim == 0 else
            P(*((axes if axes else None,) + (None,) * (l.ndim - 1))),
            batch)
        local_batch = local_avals(batch, bspecs, mesh)
        B_local = jax.tree_util.tree_leaves(local_batch)[0].shape[0]
        if self.microbatches > 1 and B_local % self.microbatches:
            # shard-local rows don't split into microbatches: serve the
            # sharded jit fallback rather than change the accumulation math
            return

        # backward: the shard-local body ends with the DP psum-mean — the
        # training-specific placement decision — and stitch() traces the
        # collectives via axis_env into executable CUSTOM fusion partitions
        allax = tuple(mesh.axis_names)
        grad_fn = self._grad_fn

        def local_grad(params, b):
            loss, aux, grads = grad_fn(params, b)
            loss = jax.lax.pmean(loss, allax)
            aux = jax.tree.map(lambda a: jax.lax.pmean(a, allax), aux)
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g.astype(jnp.float32), allax), grads)
            return loss, aux, grads

        self._grad = stitch(local_grad, service=self.service, mesh=mesh,
                            in_specs=(P(), bspecs),
                            out_specs=(P(), P(), P()), name="train_grad")
        self._grad.warmup(state.params, batch)

        # optimizer: per-shard packed panels over TP-local param slices
        try:
            local_params = local_avals(aparams, pspecs, mesh)
            self._packed = PackedAdamW(
                self.opt_cfg, local_params, rows=self.rows,
                service=self.service, external_ssq=True,
                placement=placement_key(mesh, pspecs))
        except Exception:
            self._packed = None
        if not self._grad.ok or self._packed is None:
            return

        packed = self._packed

        def local_update(params, grads, m, v, lr, b1c, b2c, gss):
            return packed.update_local(params, grads, m, v,
                                       lr, b1c, b2c, gss=gss)

        sc = P()
        self._upd_dispatch = shard_wrap(
            local_update, mesh,
            in_specs=(pspecs, pspecs, pspecs, pspecs, sc, sc, sc, sc),
            out_specs=(pspecs, pspecs, pspecs, sc),
            refresh_key=lambda: packed._compiled)
        self._sharded_ok = True

    # -- mesh placement for the launcher --------------------------------------
    def state_shardings(self) -> TrainState:
        """NamedSharding placement for the ``TrainState`` this step expects:
        params TP-sharded per the rule table, opt m/v co-located with their
        params (the packed panels update shard-local slices of both — a
        ZeRO offset would misalign them), count/step replicated."""
        assert self.mesh is not None, "state_shardings requires a mesh"
        sh = lambda s: NamedSharding(self.mesh, s)
        pshard = jax.tree.map(sh, self.param_specs,
                              is_leaf=lambda x: isinstance(x, P))
        return TrainState(
            params=pshard,
            opt=adamw.AdamWState(m=pshard, v=pshard, count=sh(P())),
            step=sh(P()))

    # -- donation --------------------------------------------------------------
    def _delete_consumed(self, state: TrainState) -> None:
        """Free the old params and moments once the update is dispatched —
        the stitched analogue of the jit path's ``donate_argnums=(0,)``.
        Without it the consumed state stays alive across the step and peak
        memory holds params+opt twice."""
        for leaf in jax.tree_util.tree_leaves(
                (state.params, state.opt.m, state.opt.v)):
            if isinstance(leaf, jax.Array) and not leaf.is_deleted():
                leaf.delete()

    # -- observability --------------------------------------------------------
    def report(self) -> dict:
        """``grad`` and ``optimizer`` are each a full unified exec report
        (:data:`repro.obs.EXEC_REPORT_SCHEMA`) — the same shape the serving
        engine's ``stitch_report()`` returns — plus the training-level
        ``fallback_steps`` / ``mesh`` / ``cache`` context."""
        out: dict[str, Any] = {
            "grad": (self._grad.report() if self._grad is not None
                     else {"status": None}),
            "optimizer": (self._packed.report() if self._packed is not None
                          else {"status": None}),
            "fallback_steps": self.fallback_steps,
        }
        if self.mesh is not None:
            out["mesh"] = dict(self.mesh.shape)
        if self.service is not None:
            out["cache"] = self.service.cache.report()
            out["service_error"] = self.service.last_error
        return out

    # -- the step --------------------------------------------------------------
    def __call__(self, state: TrainState, batch) -> tuple[TrainState, dict]:
        if not obs.tracer.enabled:
            return self._step(state, batch)
        with obs.span("train.step", cat="train") as s:
            out = self._step(state, batch)
            s.set(fallback_steps=self.fallback_steps)
            return out

    def _step(self, state: TrainState, batch) -> tuple[TrainState, dict]:
        if not self._prepared:
            self._prepare(state, batch)
        if self.mesh is not None:
            return self._call_sharded(state, batch)
        if (self._grad is None or self._packed is None
                or not self._grad.eligible(state.params, batch)):
            self.fallback_steps += 1
            return self._jit_step(state, batch)
        loss, aux, grads = self._grad(state.params, batch)
        new_params, new_opt, opt_metrics = self._packed.update(
            grads, state.opt, state.params)
        metrics = {"loss": loss, "step": state.step + 1, **opt_metrics, **aux}
        out = TrainState(new_params, new_opt, state.step + 1), metrics
        if self.donate:
            self._delete_consumed(state)
        return out

    def _call_sharded(self, state: TrainState, batch) -> tuple[TrainState, dict]:
        ok = (self._sharded_ok and self._grad is not None and self._grad.ok
              and _avals((state.params, batch)) == self._global_avals)
        if not ok:
            self.fallback_steps += 1
            return self._jit_step(state, batch)
        self._packed.poll_upgrade()
        loss, aux, grads = self._grad(state.params, batch)
        cfg = self.opt_cfg
        count = state.opt.count + 1
        lr = adamw.schedule(cfg, count)
        cf = count.astype(jnp.float32)
        b1c = 1 - cfg.b1 ** cf
        b2c = 1 - cfg.b2 ** cf
        # global clip scale from the reduced full grads — replicated, so
        # every shard's packed kernel sees the same scalar
        gss = functools.reduce(
            jnp.add, [jnp.sum(jnp.square(g))
                      for g in jax.tree_util.tree_leaves(grads)])
        new_p, new_m, new_v, gnorm = self._upd_dispatch(
            state.params, grads, state.opt.m, state.opt.v,
            jnp.asarray(lr, jnp.float32), jnp.asarray(b1c, jnp.float32),
            jnp.asarray(b2c, jnp.float32), gss)
        metrics = {"loss": loss, "step": state.step + 1, "grad_norm": gnorm,
                   "lr": lr, **aux}
        out = (TrainState(new_p, adamw.AdamWState(new_m, new_v, count),
                          state.step + 1), metrics)
        if self.donate:
            self._delete_consumed(state)
        return out

    # -- orderly shutdown ------------------------------------------------------
    def wait(self, timeout: float | None = None) -> None:
        """Join in-flight background compiles (tests / clean exit)."""
        self.service.wait(timeout)
