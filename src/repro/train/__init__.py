from .step import TrainState, init_state, make_loss_and_grad, make_train_step
from .stitched_step import StitchedTrainStep
