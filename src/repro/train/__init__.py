from .step import TrainState, init_state, make_train_step
