from .supervisor import (StepFailure, StragglerMonitor, TrainSupervisor,
                         elastic_remesh, usable_mesh_shape)
