"""Fault tolerance: checkpoint/restart supervision, straggler mitigation,
elastic re-meshing.

On a real cluster the failure signals are process exits / heartbeat
timeouts; in this container they are injected exceptions and simulated
per-host step times, but the *control logic* below is the deployable part:

* :class:`TrainSupervisor` — runs the step loop, checkpoints every
  ``ckpt_every`` steps (async), and on any step failure restores the last
  checkpoint and replays the data stream from the restored step (the data
  pipeline is stateless-by-step so replay is exact).
* :class:`StragglerMonitor` — per-host step-time EWMA; a host slower than
  ``threshold`` x median is flagged; the launcher's response (documented,
  simulated in tests) is to re-mesh without the slow host at the next
  checkpoint boundary — the backup-worker pattern without 2x compute.
* :func:`elastic_remesh` — given the devices still alive, build the largest
  usable (data, model) mesh and return shardings to re-load the checkpoint
  under; paired with mesh-agnostic checkpoints this is elastic scaling.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt import store


class StepFailure(Exception):
    """Raised (or injected) when a step dies (lost node, NaN, timeout)."""


@dataclass
class StragglerMonitor:
    n_hosts: int
    threshold: float = 1.8
    alpha: float = 0.3
    ewma: np.ndarray | None = None

    def observe(self, host_times: np.ndarray) -> list[int]:
        """Feed per-host step seconds; returns indices of flagged hosts."""
        if self.ewma is None:
            self.ewma = host_times.astype(np.float64).copy()
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * host_times
        med = float(np.median(self.ewma))
        return [i for i, t in enumerate(self.ewma) if t > self.threshold * med]


def usable_mesh_shape(n_devices: int, model_parallel: int) -> tuple[int, int]:
    """Largest (data, model) grid from surviving devices (elastic down-size):
    keep TP fixed (weights are sharded that way), shrink DP."""
    data = n_devices // model_parallel
    if data < 1:
        raise ValueError(
            f"{n_devices} devices cannot host model_parallel={model_parallel}")
    return (data, model_parallel)


def elastic_remesh(devices, model_parallel: int):
    """Build the largest valid mesh over surviving devices."""
    import numpy as _np
    from jax.sharding import Mesh
    data, model = usable_mesh_shape(len(devices), model_parallel)
    grid = _np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(grid, ("data", "model"))


@dataclass
class TrainSupervisor:
    train_step: Callable[[Any, Any], tuple[Any, dict]]
    data_fn: Callable[[int], Any]          # step -> batch (stateless replay)
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 3
    on_restore: Callable[[Any], Any] | None = None

    restarts: int = 0
    metrics_log: list = field(default_factory=list)

    def run(self, state, n_steps: int, fail_at: dict[int, Exception] | None = None):
        """Run to `n_steps`, surviving injected failures. Returns final state."""
        ckpt = store.AsyncCheckpointer(self.ckpt_dir)
        fail_at = dict(fail_at or {})
        step = int(jax.device_get(state.step))
        store.save(state, self.ckpt_dir, step)  # step-0 baseline

        while step < n_steps:
            try:
                if step in fail_at:
                    exc = fail_at.pop(step)
                    raise exc
                batch = self.data_fn(step)
                state, metrics = self.train_step(state, batch)
                step = int(jax.device_get(state.step))
                self.metrics_log.append(
                    {k: float(jax.device_get(v)) for k, v in metrics.items()})
                if step % self.ckpt_every == 0:
                    ckpt.save_async(state, step)
            except StepFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                ckpt.wait()
                like = jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
                state, step = store.restore(self.ckpt_dir, like)
                if self.on_restore is not None:
                    state = self.on_restore(state)
        ckpt.wait()
        return state
