"""Single-token decode attention (Pallas TPU) against a dense KV view.

Serving decode is one query row per sequence against the (possibly paged,
already gathered) KV cache: q (B, 1, Hq, Dh) vs k/v (B, Smax, Hkv, Dh)
with per-row validity ``positions`` (the new token's absolute position —
exactly the ``kpos <= qpos`` mask of the reference einsum path).  The
unfused chain is 8+ kernels per layer (two einsums, mask build, select,
softmax, casts); this kernel is the online-softmax flash loop with Lq = 1,
blocked over kv, GQA via the BlockSpec index maps like
:mod:`.flash_attention`.

Registered in :mod:`.registry` as ``_decode_attn_kernel`` so the fusion
planner treats the traced CUSTOM node as a stitchable citizen instead of a
hard partition boundary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                        m_scr, l_scr, acc_scr, *,
                        scale: float, window: int | None, kb: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32)                  # (1, dh)
    k = k_ref[0, 0].astype(jnp.float32)                  # (kb, dh)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (1, kb)

    qpos = pos_ref[0, 0]
    kpos = ik * kb + jax.lax.broadcasted_iota(jnp.int32, (1, kb), 1)
    mask = kpos <= qpos
    if window is not None:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_old - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, positions, *, scale: float | None = None,
                     window: int | None = None, block_k: int = 128,
                     interpret: bool = True):
    """q: (B, 1, Hq, Dh); k, v: (B, Smax, Hkv, Dh); positions: (B,) int32
    absolute position of each row's new token -> (B, 1, Hq, Dh).

    Cache rows past ``positions[b]`` are masked, so stale/sink pages in a
    gathered paged view never contribute."""
    B, Lq, Hq, Dh = q.shape
    if Lq != 1:
        raise ValueError(f"decode_attention is single-token (Lq={Lq})")
    _, Smax, Hkv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else float(1.0 / np.sqrt(Dh))

    kb = min(block_k, Smax)
    while Smax % kb:
        kb -= 1
    nk = Smax // kb

    pos = positions.astype(jnp.int32).reshape(B, 1)
    qt = q.transpose(0, 2, 1, 3)      # (B, Hq, 1, Dh)
    kt = k.transpose(0, 2, 1, 3)      # (B, Hkv, Smax, Dh)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(
            _decode_attn_kernel, scale=scale, window=window, kb=kb, nk=nk,
        ),
        grid=(B, Hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0)),
            pl.BlockSpec((1, 1, 1, Dh), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, kb, Dh),
                         lambda b, h, ik, _g=group: (b, h // _g, ik, 0)),
            pl.BlockSpec((1, 1, kb, Dh),
                         lambda b, h, ik, _g=group: (b, h // _g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Dh), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(pos, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
