"""Rotary position embedding kernel (Pallas TPU).

Stitches the cos/sin table computation with the rotation: the (L, half)
angle tables are recomputed in VREG from the position block (compute is
free; HBM traffic is the bottleneck), so the kernel reads q/k once and
writes once — vs. the unfused path that materializes cos/sin and the two
rotated halves.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rope_kernel(x_ref, pos_ref, o_ref, *, theta: float, half: int):
    x = x_ref[...].astype(jnp.float32)          # (br, H*Dh) flattened heads
    pos = pos_ref[...].astype(jnp.float32)      # (br,)
    n_heads = x.shape[-1] // (2 * half)
    x = x.reshape(x.shape[0], n_heads, 2 * half)
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = pos[:, None] * freq[None, :]          # (br, half)
    cos = jnp.cos(ang)[:, None, :]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def rope(x, positions, theta: float = 10000.0, *, block_rows: int = 256,
         interpret: bool = True):
    """x: (B, L, H, Dh); positions: (B, L).  Returns rotated x."""
    B, L, H, Dh = x.shape
    half = Dh // 2
    x2 = x.reshape(B * L, H * Dh)
    p2 = positions.reshape(B * L)
    rows = B * L
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    out = pl.pallas_call(
        functools.partial(_rope_kernel, theta=theta, half=half),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, H * Dh), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br, H * Dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, p2)
    return out.reshape(B, L, H, Dh)
