"""Fused cross-entropy kernel (Pallas TPU) — blocked online logsumexp.

For LM heads the logits tensor (tokens x vocab, vocab up to 256k here) is
the single largest activation in the step; the unfused path reads it 3-4x
(max, exp-sum, gather, grad). This kernel streams vocab blocks through VMEM
once, maintaining running (max, sumexp, gold-logit) per row in VMEM scratch
across the vocab-block grid dimension — the paper's block composition with
cross-block accumulation.

Returns per-row NLL; the (tiny) mean is taken by the caller.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _xent_kernel(x_ref, lbl_ref, loss_ref, m_scr, l_scr, g_scr, *, nv: int, vb: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, -jnp.inf, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        g_scr[...] = jnp.zeros(g_scr.shape, jnp.float32)

    x = x_ref[...].astype(jnp.float32)                       # (br, vb)
    lbl = lbl_ref[...]                                        # (br,)
    cols = iv * vb + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    local_m = jnp.max(x, axis=-1)
    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, local_m)
    l_scr[...] = l_scr[...] * jnp.exp(m_old - m_new) + jnp.sum(
        jnp.exp(x - m_new[:, None]), axis=-1)
    m_scr[...] = m_new
    hit = cols == lbl[:, None].astype(jnp.int32)
    g_scr[...] = g_scr[...] + jnp.sum(jnp.where(hit, x, 0.0), axis=-1)

    @pl.when(iv == nv - 1)
    def _fin():
        loss_ref[...] = (m_scr[...] + jnp.log(l_scr[...]) - g_scr[...]).astype(
            loss_ref.dtype)


def cross_entropy(logits, labels, *, block_rows: int = 128,
                  block_vocab: int = 2048, interpret: bool = True):
    """logits (B, V), labels (B,) -> mean NLL (scalar)."""
    B, V = logits.shape
    br = min(block_rows, B)
    while B % br:
        br -= 1
    vb = min(block_vocab, V)
    while V % vb:
        vb -= 1
    nv = V // vb
    per_row = pl.pallas_call(
        functools.partial(_xent_kernel, nv=nv, vb=vb),
        grid=(B // br, nv),
        in_specs=[
            pl.BlockSpec((br, vb), lambda i, j: (i, j)),
            pl.BlockSpec((br,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((br,), jnp.float32),
            pltpu.VMEM((br,), jnp.float32),
            pltpu.VMEM((br,), jnp.float32),
        ],
        interpret=interpret,
    )(logits, labels.astype(jnp.int32))
    return jnp.mean(per_row)
