"""RG-LRU recurrence kernel (Pallas TPU) — RecurrentGemma's gated linear
recurrence.

The gate chain (two sigmoids, softplus, exp, sqrt) is stitched with the
recurrence itself: one read of (x, gates), one write of h, gates never
materialize in HBM.  Grid (batch, channel_blocks); diagonal recurrence means
each channel slab scans independently with a (db,) VREG carry.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(x_ref, ig_ref, rg_ref, lam_ref, o_ref, *, L: int, c: float):
    lam = jax.nn.softplus(lam_ref[...].astype(jnp.float32))     # (db,)
    db = lam.shape[0]

    def step(t, h):
        x_t = x_ref[0, t, :].astype(jnp.float32)
        ig_t = jax.nn.sigmoid(ig_ref[0, t, :].astype(jnp.float32))
        rg_t = jax.nn.sigmoid(rg_ref[0, t, :].astype(jnp.float32))
        log_a = -c * lam * rg_t
        a = jnp.exp(log_a)
        mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
        h = a * h + mult * (ig_t * x_t)
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    jax.lax.fori_loop(0, L, step, jnp.zeros((db,), jnp.float32))


def rg_lru(x, input_gate, rec_gate, Lambda, c: float = 8.0, *,
           block_channels: int = 512, interpret: bool = True):
    """x, input_gate, rec_gate: (B, L, D); Lambda: (D,)."""
    B, L, D = x.shape
    db = min(block_channels, D)
    while D % db:
        db -= 1
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, L=L, c=c),
        grid=(B, D // db),
        in_specs=[
            pl.BlockSpec((1, L, db), lambda b, ch: (b, 0, ch)),
            pl.BlockSpec((1, L, db), lambda b, ch: (b, 0, ch)),
            pl.BlockSpec((1, L, db), lambda b, ch: (b, 0, ch)),
            pl.BlockSpec((db,), lambda b, ch: (ch,)),
        ],
        out_specs=pl.BlockSpec((1, L, db), lambda b, ch: (b, 0, ch)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, input_gate, rec_gate, Lambda)
    return out
