"""Generic stitched-kernel emitter — paper §5 mapped to Pallas/TPU.

Given a :class:`FusionPattern` and an implementation :class:`Template`, emit
ONE ``pl.pallas_call`` computing the whole pattern.  The four composition
mechanisms of the paper map as:

* kernel packing       -> independent member ops share the kernel's grid and
                          write separate output refs (their loops are fused);
* thread composition   -> member chains evaluated value-to-value inside the
                          body; intermediates are VREG-resident jnp values;
* warp composition     -> row reductions computed on the VPU tile
                          (``jnp.sum/max(axis=-1)``) feeding dependent
                          elementwise ops in the same body;
* block composition    -> intermediates the template marks ``S`` are routed
                          through explicit VMEM ``scratch_shapes`` refs;
                          column/scalar reductions and row-contracting GEMMs
                          accumulate across sequential grid steps into their
                          output ref (TPU grids are sequential, so
                          cross-block accumulation is well-defined — the TPU
                          analogue of the paper's independent parallel loops
                          inside one kernel).

Supported pattern class — *row-parallel patterns*: there is a leading "row"
dimension R such that every member op either works row-locally (elementwise,
row broadcast/reduction, batched GEMM with batch=rows, gather from a
row-invariant table) or is an explicit cross-row accumulator (column/scalar
reduction, GEMM contracting over R).  The paper's layout constraint (§5.3:
"shared space accessed within a single thread block context") reappears
verbatim: a cross-row accumulator may feed other members only when the whole
row space fits in one block (grid == 1).

Everything is validated in ``interpret=True`` mode on CPU; BlockSpecs are
written for TPU VMEM tiling (row-block x full minor dims, sublane multiples
of 8 preferred by the tuner).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.codegen import EW_OPS, canonical_dtype, eval_node
from repro.core.ir import Graph, OpKind, OpNode
from repro.core.pattern import FusionPattern
from repro.core.templates import Template

__all__ = ["StitchAnalysis", "analyze_pattern", "build_stitched_callable",
           "StitchInfeasible"]


class StitchInfeasible(Exception):
    """Pattern not in the emitter's supported class (caller falls back to the
    fused-jnp path; the tuner scores the template negative)."""


ROW = "row"          # leading dim == R, sliced per block
INV = "invariant"    # no row dim; fully resident per block
ACC = "accumulator"  # produced by cross-row accumulation over grid steps


@dataclass
class StitchAnalysis:
    rows: int                               # R
    roles: dict[str, str]                   # node -> ROW | INV | ACC
    acc_init: dict[str, tuple[str, float]]  # acc node -> (combine, init value)
    feasible_blocks: list[int]              # row-block sizes that divide R
    single_block: bool = False              # an ACC feeds members (grid must be 1)


def _role_of_input(node: OpNode, rows: int) -> str:
    return ROW if node.shape and node.shape[0] == rows else INV


def analyze_pattern(p: FusionPattern) -> StitchAnalysis:
    """Try candidate row dimensions in priority order (output leading dims
    first — outputs define the kernel's write parallelism — then input
    leading dims); the first candidate under which every member op is
    row-local or an accumulator wins."""
    g = p.graph
    outs = p.external_outputs
    if not outs:
        raise StitchInfeasible("pattern has no outputs")

    cands: dict[int, float] = {}
    for n in outs:
        shp = g[n].shape
        if shp and shp[0] > 1:  # rows=1 is degenerate (everything aliases)
            cands[shp[0]] = cands.get(shp[0], 0) + 1000.0
    for n in p.external_inputs:
        shp = g[n].shape
        if shp and shp[0] > 1:
            cands[shp[0]] = cands.get(shp[0], 0) + 1.0
    if not cands:
        raise StitchInfeasible("no shaped tensors")
    order = sorted(cands, key=lambda k: (-cands[k], -k))
    # inputs consumed ONLY as gemm rhs / gather tables are weights: even when
    # their leading dim coincides with R (square matrices), they are
    # row-invariant.  Tried as a fallback classification.
    def _is_weight_use(user: str, name: str) -> bool:
        node = g[user]
        if node.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM):
            return len(node.operands) > 1 and node.operands[1] == name
        if node.kind is OpKind.GATHER:
            return node.operands[0] == name
        if node.kind is OpKind.BROADCAST:
            # operand axis 0 maps to a non-leading target axis -> per-channel
            # weight broadcast (gamma etc.), not a per-row tensor
            dims = tuple(node.attrs.get("bcast_dims", ()))
            return bool(dims) and dims[0] != 0
        return False

    rhs_only: set[str] = set()
    for name in p.external_inputs:
        users = [u for u in g.users(name) if u in p.members]
        if users and all(_is_weight_use(u, name) for u in users):
            rhs_only.add(name)
    last_err: StitchInfeasible | None = None
    for rows in order:
        for force_inv in ((frozenset(), frozenset(rhs_only))
                          if rhs_only else (frozenset(),)):
            try:
                return _analyze_with_rows(p, rows, force_inv)
            except StitchInfeasible as e:
                last_err = e
    raise last_err if last_err is not None else StitchInfeasible("no viable rows")


def _analyze_with_rows(p: FusionPattern, rows: int,
                       force_inv: frozenset[str] = frozenset()) -> StitchAnalysis:
    g = p.graph

    roles: dict[str, str] = {}
    acc_init: dict[str, tuple[str, float]] = {}
    for name in p.external_inputs:
        roles[name] = INV if name in force_inv else _role_of_input(g[name], rows)

    topo_members = [n.name for n in p.nodes if not n.is_source()]
    single_block = False
    for name in topo_members:
        node = g[name]
        ops = node.operands
        op_roles = [roles.get(o) for o in ops]
        if any(r is None for r in op_roles):
            # operand outside pattern and not an external input -> impossible
            raise StitchInfeasible(f"unrooted operand of {name}")
        if any(r == ACC for r in op_roles):
            # §5.3 layout constraint: an accumulator's value only exists once
            # the whole row space has been visited, so a member may consume it
            # only when the entire row space is one block (grid == 1) — then
            # the fully-reduced value is live in the body and behaves like a
            # row-invariant operand.
            single_block = True
            op_roles = [INV if r == ACC else r for r in op_roles]

        k = node.kind
        if k is OpKind.ELEMENTWISE:
            # a ROW operand arrives as an (rb, ...) block; any other operand
            # spanning the full row space cannot be combined with it
            # value-to-value (it would need per-block slicing)
            if ROW in op_roles:
                for o, r in zip(ops, op_roles):
                    oshape = g[o].shape
                    if (r == INV and oshape and oshape[0] == rows
                            and roles.get(o) != ACC):
                        raise StitchInfeasible(
                            f"{name} mixes a row block with full-rows operand {o}")
            roles[name] = ROW if ROW in op_roles else INV
        elif k is OpKind.BROADCAST:
            dims = tuple(node.attrs.get("bcast_dims", ()))
            src_shape = g[ops[0]].shape
            if op_roles[0] == ROW:
                # the operand's row axis (its dim 0) must land on the target's
                # leading axis, and the target must keep the row extent
                if (dims and dims[0] == 0 and node.shape
                        and node.shape[0] == rows):
                    roles[name] = ROW
                else:
                    raise StitchInfeasible(
                        f"broadcast {name} moves a row-blocked operand off the row axis")
            elif node.shape and node.shape[0] == rows:
                # target spans rows; sound only if no operand dim carrying
                # real extent maps onto the row axis (pure replication)
                if dims and dims[0] == 0 and src_shape and src_shape[0] != 1:
                    raise StitchInfeasible(
                        f"broadcast {name} needs per-block rows of invariant {ops[0]}")
                roles[name] = ROW
            else:
                roles[name] = INV
        elif k is OpKind.RESHAPE:
            src = g[ops[0]]
            if roles[ops[0]] == ROW:
                if node.shape and node.shape[0] == rows and src.shape and src.shape[0] == rows:
                    roles[name] = ROW      # row-local reshape of trailing dims
                else:
                    raise StitchInfeasible(f"reshape {name} mixes rows")
            else:
                roles[name] = INV
        elif k is OpKind.SLICE:
            starts = node.attrs["starts"]
            src_shape = g[ops[0]].shape
            if roles[ops[0]] == ROW:
                if starts[0] == 0 and node.shape[0] == src_shape[0]:
                    roles[name] = ROW     # trailing-dim slice, row-local
                else:
                    raise StitchInfeasible(f"slice {name} cuts the row axis")
            else:
                roles[name] = INV
        elif k is OpKind.TRANSPOSE:
            perm = tuple(node.attrs["perm"])
            if roles[ops[0]] == ROW:
                if perm and perm[0] == 0:
                    roles[name] = ROW
                else:
                    raise StitchInfeasible(f"transpose {name} moves row axis")
            else:
                roles[name] = INV
        elif k is OpKind.REDUCTION:
            axes = tuple(node.attrs["axes"])
            if roles[ops[0]] == ROW and 0 in axes:
                red = node.attrs.get("op", "sum")
                if red not in ("sum", "max", "min"):
                    raise StitchInfeasible(f"cross-row reduce op {red}")
                roles[name] = ACC
                acc_init[name] = {
                    "sum": ("add", 0.0),
                    "max": ("max", -jnp.inf),
                    "min": ("min", jnp.inf),
                }[red]
            elif roles[ops[0]] == ROW:
                roles[name] = ROW
            else:
                roles[name] = INV
        elif k in (OpKind.GEMM, OpKind.BATCHED_GEMM):
            (lc, rc) = node.attrs["contract"]
            (lb, rb_) = node.attrs.get("batch", ((), ()))
            lrole, rrole = roles[ops[0]], roles[ops[1]]
            if lrole == ROW and rrole == ROW and 0 in lb and 0 in rb_:
                roles[name] = ROW          # batched over rows
            elif lrole == ROW and rrole == INV and 0 not in lc:
                roles[name] = ROW          # (R, k) @ (k, n)
            elif lrole == ROW and rrole == ROW and 0 in lc and 0 in rc:
                roles[name] = ACC          # contract over rows -> accumulate
                acc_init[name] = ("add", 0.0)
            elif lrole == INV and rrole == INV:
                roles[name] = INV
            else:
                raise StitchInfeasible(f"gemm {name} row structure unsupported")
        elif k is OpKind.GATHER:
            trole, irole = roles[ops[0]], roles[ops[1]]
            if trole == INV:
                roles[name] = irole
            else:
                raise StitchInfeasible(f"gather {name} from row-varying table")
        elif k is OpKind.TUPLE:
            roles[name] = INV
        elif k is OpKind.CUSTOM:
            if "project" in node.attrs:
                # projection of a multi-output custom base: its own shape
                # decides the role; the base is a shapeless tuple carrier
                roles[name] = (ROW if node.shape and node.shape[0] == rows
                               else INV)
                continue
            from .registry import lookup
            if lookup(node) is None:
                raise StitchInfeasible(f"unregistered custom kernel {name}")
            if node.attrs.get("multi") and name in p.external_outputs:
                raise StitchInfeasible(
                    f"multi-output custom base {name} escapes the pattern")
            # the saved eval_fn replays the pallas_call at its full traced
            # shapes; one grid step over the whole row space makes every
            # blocked shape equal its full shape, so the replay composes
            single_block = True
            roles[name] = (ROW if node.shape and node.shape[0] == rows
                           else INV)
        else:
            raise StitchInfeasible(f"unsupported kind {k} in stitched kernel")

    blocks = [b for b in (8, 16, 32, 64, 128, 256, 512, rows) if b <= rows and rows % b == 0]
    if single_block:
        blocks = [rows]
    if not blocks:
        blocks = [rows]
    return StitchAnalysis(rows, roles, acc_init, sorted(set(blocks)), single_block)


def _block_shape(shape: tuple[int, ...], role: str, rb: int) -> tuple[int, ...]:
    if role == ROW and shape:
        return (rb,) + shape[1:]
    return shape


def _subst_rows(shape: tuple[int, ...], rows: int, rb: int) -> tuple[int, ...]:
    if shape and shape[0] == rows:
        return (rb,) + shape[1:]
    return shape


def _eval_rowlocal(node: OpNode, operands: list, rows: int, rb: int):
    """eval_node, with row-parallel target shapes rewritten R -> rb."""
    k = node.kind
    if k is OpKind.SLICE and node.shape and node.shape[0] == rows:
        starts = list(node.attrs["starts"])
        limits = list(node.attrs["limits"])
        limits[0] = operands[0].shape[0]   # row axis handled by the grid
        return lax.slice(operands[0], starts, limits)
    if k is OpKind.BROADCAST:
        return lax.broadcast_in_dim(
            operands[0], _subst_rows(node.shape, rows, rb), tuple(node.attrs["bcast_dims"])
        )
    if k is OpKind.RESHAPE:
        return jnp.reshape(operands[0], _subst_rows(node.shape, rows, rb))
    return eval_node(node, operands)


def build_stitched_callable(
    p: FusionPattern,
    template: Template | None = None,
    *,
    row_block: int | None = None,
    scratch_ops: Sequence[str] = (),
    interpret: bool = True,
) -> Callable[..., tuple]:
    """Emit the fused kernel.  Returns ``f(*external_inputs) -> tuple(outputs)``
    (input/output order = ``p.external_inputs`` / ``p.external_outputs``)."""
    g = p.graph
    ana = analyze_pattern(p)
    rows = ana.rows

    if template is not None:
        scratch_ops = tuple(template.scratch_ops)
        for s in template:
            for a in s.attrs:
                for lvl in a.levels:
                    if lvl.kind == "GRID" and lvl.factor:
                        row_block = lvl.factor
    rb = row_block or ana.feasible_blocks[0]
    if rb not in ana.feasible_blocks:
        # snap to the largest feasible block <= requested
        rb = max((b for b in ana.feasible_blocks if b <= rb), default=ana.feasible_blocks[0])
    grid = rows // rb

    ins = list(p.external_inputs)
    outs = list(p.external_outputs)
    roles = ana.roles
    member_topo = [n for n in p.nodes if not n.is_source()]
    scratch_set = {s for s in scratch_ops if s in p.members and roles.get(s) == ROW}

    in_specs = []
    for name in ins:
        node = g[name]
        bs = _block_shape(node.shape, roles[name], rb)
        if roles[name] == ROW:
            in_specs.append(
                pl.BlockSpec(bs, lambda i, _n=len(bs): (i,) + (0,) * (_n - 1))
            )
        else:
            nd = len(node.shape)
            in_specs.append(pl.BlockSpec(node.shape or (1,), lambda i, _n=nd: (0,) * max(_n, 1)))

    out_specs = []
    out_shapes = []
    for name in outs:
        node = g[name]
        role = roles[name]
        shp = node.shape or (1,)
        if role == ROW:
            bs = _block_shape(shp, ROW, rb)
            out_specs.append(pl.BlockSpec(bs, lambda i, _n=len(bs): (i,) + (0,) * (_n - 1)))
        else:  # INV or ACC: full tensor every step
            out_specs.append(pl.BlockSpec(shp, lambda i, _n=len(shp): (0,) * _n))
        out_shapes.append(jax.ShapeDtypeStruct(shp, canonical_dtype(node.dtype)))

    scratch_shapes = []
    scratch_order = sorted(scratch_set)
    for name in scratch_order:
        node = g[name]
        bs = _block_shape(node.shape, ROW, rb)
        # VMEM scratch for TPU; plain ANY in interpret mode still allocates
        try:
            from jax.experimental.pallas import tpu as pltpu
            scratch_shapes.append(pltpu.VMEM(bs, canonical_dtype(node.dtype)))
        except Exception:  # pragma: no cover - pltpu always importable in jax>=0.4
            scratch_shapes.append(jax.ShapeDtypeStruct(bs, canonical_dtype(node.dtype)))

    n_in, n_out = len(ins), len(outs)

    def body(*refs):
        in_refs = refs[:n_in]
        out_refs = refs[n_in:n_in + n_out]
        scr_refs = refs[n_in + n_out:]
        scr_map = dict(zip(scratch_order, scr_refs))
        pid = pl.program_id(0)

        env: dict[str, jax.Array] = {}
        for name, ref in zip(ins, in_refs):
            val = ref[...]
            if not g[name].shape:  # scalars arrive as (1,) blocks
                val = val.reshape(())
            env[name] = val

        for node in member_topo:
            name = node.name
            vals = [env[o] for o in node.operands]
            role = roles[name]
            if role == ACC:
                # partial contribution of this row block
                partial_val = eval_node(node, vals)
                combine, init = ana.acc_init[name]
                if grid == 1:
                    # fully reduced in one step: the value is live in the body
                    # and may feed other members (block composition, §5.3)
                    env[name] = partial_val
                    if name in outs:
                        oref = out_refs[outs.index(name)]
                        oref[...] = partial_val.reshape(oref.shape)
                else:
                    oref = out_refs[outs.index(name)]

                    @pl.when(pid == 0)
                    def _init(oref=oref, init=init):
                        oref[...] = jnp.full(oref.shape, init, oref.dtype)
                    cur = oref[...]
                    upd = {
                        "add": lambda a, b: a + b,
                        "max": jnp.maximum,
                        "min": jnp.minimum,
                    }[combine](cur, partial_val.reshape(oref.shape))
                    oref[...] = upd
                    env[name] = None  # not consumable (layout constraint)
                continue
            val = _eval_rowlocal(node, vals, rows, rb)
            if name in scr_map:                 # block composition via VMEM
                scr_map[name][...] = val
                val = scr_map[name][...]
            env[name] = val

        for name, oref in zip(outs, out_refs):
            if roles[name] == ACC:
                continue  # written by the accumulator path above
            val = env[name]
            oref[...] = val.reshape(oref.shape)

    call = pl.pallas_call(
        body,
        grid=(grid,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )

    def run(*inputs):
        prepared = []
        for name, x in zip(ins, inputs):
            # canonicalized: the graph dtype stays authoritative without ever
            # requesting an x64 width this runtime doesn't provide
            x = jnp.asarray(x, dtype=canonical_dtype(g[name].dtype))
            if not g[name].shape:
                x = x.reshape(1)
            prepared.append(x)
        res = call(*prepared)
        if not isinstance(res, (tuple, list)):
            res = (res,)
        fixed = []
        for name, r in zip(outs, res):
            fixed.append(r.reshape(g[name].shape))
        return tuple(fixed)

    return run
