"""Fused normalization kernels (Pallas TPU).

The archetypal FusionStitching win: RMSNorm / LayerNorm are
elementwise+row-reduction chains (paper §5.1 "warp composition" — here VPU
sublane/lane composition).  The fused kernel reads the activation once from
HBM and writes once; the row statistics never leave VREG.

``rmsnorm_residual`` additionally stitches the residual add (the paper's
kernel-packing of the pre-norm transformer's ``x + attn_out`` with the norm
that follows), saving one full round-trip of the hidden tensor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _row_grid(shape2d, block_rows):
    rows = shape2d[0]
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    return (rows // br,), br


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, gamma, eps: float = 1e-6, *, block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = True):
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    grid, br = _row_grid(x2.shape, block_rows)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, gamma)
    return out.reshape(orig_shape)


def _rmsnorm_residual_kernel(x_ref, r_ref, g_ref, o_ref, res_ref, *, eps: float):
    s = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    res_ref[...] = s.astype(res_ref.dtype)
    var = jnp.mean(s * s, axis=-1, keepdims=True)
    o_ref[...] = (s * jax.lax.rsqrt(var + eps) * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_residual(x, res, gamma, eps: float = 1e-6, *,
                     block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    orig_shape = x.shape
    d = x.shape[-1]
    x2, r2 = x.reshape(-1, d), res.reshape(-1, d)
    grid, br = _row_grid(x2.shape, block_rows)
    normed, new_res = pl.pallas_call(
        functools.partial(_rmsnorm_residual_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, x.dtype),
            jax.ShapeDtypeStruct(x2.shape, x.dtype),
        ],
        interpret=interpret,
    )(x2, r2, gamma)
    return normed.reshape(orig_shape), new_res.reshape(orig_shape)


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    o_ref[...] = ((x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def layernorm(x, gamma, beta, eps: float = 1e-5, *,
              block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    grid, br = _row_grid(x2.shape, block_rows)
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, gamma, beta)
    return out.reshape(orig_shape)
