"""Registry of *stitchable* custom (Pallas) kernels.

The tracer lowers every ``pallas_call`` to an opaque ``OpKind.CUSTOM`` node
carrying an ``eval_fn`` that replays the saved primitive.  By default the
fusion generator treats CUSTOM as a hard partition boundary — correct for
arbitrary foreign ops, but it forces e.g. a transformer decode step into
``gemm | attention | gemm | ...`` islands even though the attention kernel's
body is perfectly composable with its surrounding projections.

This module is the allow-list that relaxes that: a kernel registered here
declares the two facts the compiler needs to treat its CUSTOM node as a
first-class stitching citizen —

* ``flops``  — an MXU/compute estimate so the cost model's roofline sees
  the kernel as compute-bearing rather than free;
* ``scratch_bytes`` — the on-chip (VMEM) footprint its body allocates, so
  the ILP can reject partitions whose combined scratch would not fit.

The registry is keyed on the Pallas *kernel-body function name* (what
``pl.pallas_call`` records as ``name_and_src_info``), which the tracer tags
onto the node as ``attrs["kernel"]``.  Only :mod:`repro.core.ir` is imported
here — no Pallas, no jax — so ``core.fusiongen -> kernels.registry`` adds no
import cycles and no accelerator requirements at planning time.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.ir import Graph, OpKind, OpNode

__all__ = ["StitchableKernel", "register", "lookup", "registered_names"]


@dataclass(frozen=True)
class StitchableKernel:
    """Compiler-facing descriptor of one registered Pallas kernel.

    ``flops``/``scratch_bytes`` receive the CUSTOM node and its graph and
    derive estimates from the *operand* shapes (output shapes are unreliable
    for multi-output kernels, whose base node is shapeless)."""

    name: str
    flops: Callable[[OpNode, Graph], float]
    scratch_bytes: Callable[[OpNode, Graph], int]


_REGISTRY: dict[str, StitchableKernel] = {}


def register(desc: StitchableKernel) -> StitchableKernel:
    """Install (or replace) a descriptor under ``desc.name``."""
    _REGISTRY[desc.name] = desc
    return desc


def registered_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def lookup(node: OpNode) -> Optional[StitchableKernel]:
    """Descriptor for a CUSTOM node whose traced kernel tag is registered.

    Projection nodes (``attrs["project"]``) resolve through the same tag as
    their multi-output base, so callers can ask about either."""
    if node.kind is not OpKind.CUSTOM:
        return None
    tag = node.attrs.get("kernel")
    if not tag:
        return None
    return _REGISTRY.get(tag)


# -- built-in descriptors -----------------------------------------------------
#
# The formulas mirror the actual kernel bodies (kernels/flash_attention.py,
# kernels/router.py): flash keeps a (qb,) m/l pair plus a (qb, Dh) f32
# accumulator in VMEM per grid step; the router is purely row-blocked with
# no explicit scratch.


def _flash_flops(node: OpNode, g: Graph) -> float:
    q = g[node.operands[0]].shape            # (B, Lq, Hq, Dh)
    kv = g[node.operands[1]].shape           # (B, Lkv, Hkv, Dh)
    if len(q) != 4 or len(kv) != 4:
        return 0.0
    b, lq, hq, dh = q
    lkv = kv[1]
    # QK^T and PV each cost 2*Lq*Lkv*Dh MACs per (batch, head)
    return 4.0 * b * hq * lq * lkv * dh


def _flash_scratch(node: OpNode, g: Graph) -> int:
    q = g[node.operands[0]].shape
    if len(q) != 4:
        return 0
    _, lq, _, dh = q
    qb = min(128, lq)                        # default block_q in the kernel
    return qb * (2 + dh) * 4                 # f32 m + l + (qb, Dh) acc


def _router_flops(node: OpNode, g: Graph) -> float:
    logits = g[node.operands[0]].shape       # (T, E)
    if len(logits) != 2:
        return 0.0
    t, e = logits
    # per row: k iterative max-scans over E plus softmax-ish normalisation
    return float(t * e * 8)


def _decode_attn_flops(node: OpNode, g: Graph) -> float:
    # pallas_call operands: (positions, q^T, k^T, v^T) — see
    # kernels/decode_attention.py; q^T is (B, Hq, 1, Dh), kv^T (B, Hkv, Smax, Dh)
    if len(node.operands) < 3:
        return 0.0
    q = g[node.operands[1]].shape
    kv = g[node.operands[2]].shape
    if len(q) != 4 or len(kv) != 4:
        return 0.0
    b, hq, _, dh = q
    smax = kv[2]
    # one QK^T row and one PV row per (batch, head): 2*Smax*Dh MACs each
    return 4.0 * b * hq * smax * dh


def _decode_attn_scratch(node: OpNode, g: Graph) -> int:
    if len(node.operands) < 2:
        return 0
    q = g[node.operands[1]].shape
    if len(q) != 4:
        return 0
    dh = q[3]
    return (2 + dh) * 4                      # f32 m + l + (1, Dh) acc


def _vpu_flops(per_elem: float, operand: int = 0):
    """Memory-bound VPU kernels (norms, rope, GLU): a few ops per element of
    the named operand, no MXU work, no explicit scratch (VREG-only)."""

    def flops(node: OpNode, g: Graph) -> float:
        if len(node.operands) <= operand:
            return 0.0
        return per_elem * float(g[node.operands[operand]].size)

    return flops


register(StitchableKernel("_flash_kernel", _flash_flops, _flash_scratch))
register(StitchableKernel("_router_kernel", _router_flops, lambda n, g: 0))
register(StitchableKernel("_decode_attn_kernel", _decode_attn_flops,
                          _decode_attn_scratch))
register(StitchableKernel("_rmsnorm_kernel", _vpu_flops(4.0), lambda n, g: 0))
register(StitchableKernel("_rmsnorm_residual_kernel", _vpu_flops(5.0),
                          lambda n, g: 0))
register(StitchableKernel("_layernorm_kernel", _vpu_flops(6.0), lambda n, g: 0))
register(StitchableKernel("_rope_kernel", _vpu_flops(6.0), lambda n, g: 0))
register(StitchableKernel("_glu_kernel", _vpu_flops(4.0), lambda n, g: 0))
