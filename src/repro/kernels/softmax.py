"""Fused scaled/masked softmax kernel (Pallas TPU).

The classic warp-composition pattern (paper Fig. 5(c)): scale + mask + max +
exp + sum + div in one kernel; row statistics stay in VREG.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .norms import DEFAULT_BLOCK_ROWS, _row_grid


def _softmax_kernel(x_ref, o_ref, *, scale: float):
    x = x_ref[...].astype(jnp.float32) * scale
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def _softmax_masked_kernel(x_ref, m_ref, o_ref, *, scale: float):
    x = x_ref[...].astype(jnp.float32) * scale
    x = jnp.where(m_ref[...], x, -jnp.inf)
    mx = jnp.max(x, axis=-1, keepdims=True)
    # rows that are fully masked: keep exp(-inf - -inf)=exp(nan) out
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    e = jnp.exp(x - mx)
    s = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (e / jnp.maximum(s, 1e-30)).astype(o_ref.dtype)


def softmax(x, scale: float = 1.0, mask=None, *,
            block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    grid, br = _row_grid(x2.shape, block_rows)
    if mask is None:
        out = pl.pallas_call(
            functools.partial(_softmax_kernel, scale=scale),
            grid=grid,
            in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
            interpret=interpret,
        )(x2)
    else:
        m2 = jnp.broadcast_to(mask, orig_shape).reshape(-1, d)
        out = pl.pallas_call(
            functools.partial(_softmax_masked_kernel, scale=scale),
            grid=grid,
            in_specs=[
                pl.BlockSpec((br, d), lambda i: (i, 0)),
                pl.BlockSpec((br, d), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
            interpret=interpret,
        )(x2, m2)
    return out.reshape(orig_shape)
