"""Pure-jnp oracles for every hand-written Pallas kernel.

These are the ground truth the kernel tests assert against, AND the
lowering path used by the multi-pod dry-run (interpret-mode Pallas does not
produce clean TPU HLO, so ``use_kernels=False`` model builds call these).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rmsnorm", "rmsnorm_residual", "layernorm", "softmax", "swiglu", "geglu",
    "squared_relu", "rope", "cross_entropy", "attention", "mamba_scan",
    "rg_lru", "topk_router",
]


def rmsnorm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_residual(x, res, gamma, eps: float = 1e-6):
    """Fused residual-add + RMSNorm; returns (normed, new_residual)."""
    s = x.astype(jnp.float32) + res.astype(jnp.float32)
    return rmsnorm(s, gamma, eps).astype(x.dtype), s.astype(x.dtype)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(x.dtype)


def softmax(x, scale: float = 1.0, mask=None):
    xf = x.astype(jnp.float32) * scale
    if mask is not None:
        xf = jnp.where(mask, xf, -jnp.inf)
    m = jnp.max(xf, axis=-1, keepdims=True)
    e = jnp.exp(xf - m)
    out = e / jnp.sum(e, axis=-1, keepdims=True)
    return out.astype(x.dtype)


def swiglu(gate, up):
    return (jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)).astype(gate.dtype)


def geglu(gate, up):
    return (jax.nn.gelu(gate.astype(jnp.float32)) * up.astype(jnp.float32)).astype(gate.dtype)


def squared_relu(x):
    r = jnp.maximum(x.astype(jnp.float32), 0.0)
    return (r * r).astype(x.dtype)


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x: (..., L, H, Dh) or (..., L, Dh); positions (..., L)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq      # (..., L, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:                           # (..., L, H, Dh)
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels):
    """Mean token NLL. logits (B, V) float, labels (B,) int."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(lf, labels[:, None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              positions_q=None, positions_kv=None, window: int | None = None):
    """GQA attention oracle.
    q: (B, Lq, Hq, Dh), k/v: (B, Lkv, Hkv, Dh); Hq % Hkv == 0.
    window: local-attention window size (RecurrentGemma-style)."""
    B, Lq, Hq, Dh = q.shape
    _, Lkv, Hkv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(Dh)
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)) * scale
    pq = positions_q if positions_q is not None else jnp.arange(Lq)[None]
    pk = positions_kv if positions_kv is not None else jnp.arange(Lkv)[None]
    mask = jnp.ones((B, 1, Lq, Lkv), dtype=bool)
    if causal:
        mask = mask & (pq[:, None, :, None] >= pk[:, None, None, :])
    if window is not None:
        mask = mask & (pq[:, None, :, None] - pk[:, None, None, :] < window)
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def mamba_scan(x, delta, A, B, C, D, return_state: bool = False):
    """Mamba-1 selective scan oracle.
    x, delta: (Bb, L, Dm); A: (Dm, N); B, C: (Bb, L, N); D: (Dm,).
    Returns y: (Bb, L, Dm) [, final state (Bb, Dm, N)]."""
    xf, df = x.astype(jnp.float32), delta.astype(jnp.float32)
    Af, Bf, Cf = A.astype(jnp.float32), B.astype(jnp.float32), C.astype(jnp.float32)

    def step(h, inp):
        # per-step discretization: the (Bb, Dm, N) tile lives only inside the
        # step — never materialize (Bb, L, Dm, N) in HBM.
        x_t, d_t, B_t, C_t = inp
        dA_t = jnp.exp(d_t[..., None] * Af)              # (Bb, Dm, N)
        dBx_t = (d_t * x_t)[..., None] * B_t[:, None, :]
        h = dA_t * h + dBx_t
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    h0 = jnp.zeros((x.shape[0], x.shape[2], A.shape[1]), jnp.float32)
    h_fin, ys = jax.lax.scan(
        step, h0,
        (xf.transpose(1, 0, 2), df.transpose(1, 0, 2),
         Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2) + xf * D.astype(jnp.float32)
    if return_state:
        return y.astype(x.dtype), h_fin
    return y.astype(x.dtype)


def rg_lru(x, input_gate, rec_gate, Lambda, c: float = 8.0,
           return_state: bool = False):
    """RG-LRU (RecurrentGemma) oracle.
    x, input_gate, rec_gate: (B, L, D); Lambda: (D,) learnable.
    a_t = exp(-c * softplus(Lambda) * sigmoid(rec_gate));
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(input_gate) * x_t)."""
    xf = x.astype(jnp.float32)
    log_a = -c * jax.nn.softplus(Lambda.astype(jnp.float32)) * jax.nn.sigmoid(
        rec_gate.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(input_gate.astype(jnp.float32)) * xf
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))

    def step(h, inp):
        a_t, gx_t = inp
        h = a_t * h + gx_t
        return h, h

    inp = (a.transpose(1, 0, 2), (mult * gated).transpose(1, 0, 2))
    h0 = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)
    h_fin, hs = jax.lax.scan(step, h0, inp)
    if return_state:
        return hs.transpose(1, 0, 2).astype(x.dtype), h_fin
    return hs.transpose(1, 0, 2).astype(x.dtype)


def topk_router(logits, k: int, renormalize: bool = True):
    """MoE router oracle: softmax over experts, top-k, optional renorm.
    logits: (T, E). Returns (weights (T, k), indices (T, k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, k)
    if renormalize:
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights.astype(logits.dtype), idx.astype(jnp.int32)
