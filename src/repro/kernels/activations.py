"""Fused MLP activation kernels (Pallas TPU): SwiGLU, GeGLU, squared-ReLU.

Pure thread-composition (VREG) stitches: two reads + one write instead of the
unfused 4-5 HBM round-trips (silu -> mul; gelu -> mul; relu -> square).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .norms import DEFAULT_BLOCK_ROWS, _row_grid


def _glu_kernel(g_ref, u_ref, o_ref, *, act: str):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    if act == "silu":
        a = g * jax.nn.sigmoid(g)
    else:  # gelu (tanh approx is fine for both archs using GeGLU)
        a = jax.nn.gelu(g)
    o_ref[...] = (a * u).astype(o_ref.dtype)


def _glu(gate, up, act: str, block_rows: int, interpret: bool):
    orig_shape = gate.shape
    d = gate.shape[-1]
    g2, u2 = gate.reshape(-1, d), up.reshape(-1, d)
    grid, br = _row_grid(g2.shape, block_rows)
    import functools
    out = pl.pallas_call(
        functools.partial(_glu_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(g2.shape, gate.dtype),
        interpret=interpret,
    )(g2, u2)
    return out.reshape(orig_shape)


def swiglu(gate, up, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    return _glu(gate, up, "silu", block_rows, interpret)


def geglu(gate, up, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    return _glu(gate, up, "gelu", block_rows, interpret)


def _sqrelu_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    r = jnp.maximum(x, 0.0)
    o_ref[...] = (r * r).astype(o_ref.dtype)


def squared_relu(x, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    grid, br = _row_grid(x2.shape, block_rows)
    out = pl.pallas_call(
        _sqrelu_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(orig_shape)
