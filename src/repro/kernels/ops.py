"""Public kernel API — jit-friendly wrappers that dispatch Pallas <-> oracle.

Models call these; ``KernelMode`` decides the backend:

* ``"pallas"``  — the Pallas kernels (interpret=True on CPU; on TPU this is
                  where ``interpret=False`` would flip).
* ``"ref"``     — the pure-jnp oracles; clean HLO for the multi-pod dry-run
                  and for gradient tracing (several kernels are fwd-only).

Default is "ref" so distributed lowering is always clean; tests/examples opt
in to "pallas".  The switch is a context var, so nested code needs no
threading of flags.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Literal

import jax
import jax.numpy as jnp

from . import ref as _ref
from . import norms as _norms
from . import activations as _act
from . import softmax as _softmax
from . import rope as _rope
from . import cross_entropy as _xent
from . import decode_attention as _decode
from . import flash_attention as _flash
from . import mamba_scan as _mamba
from . import rg_lru as _rglru
from . import router as _router

KernelMode = Literal["pallas", "ref"]
_mode: contextvars.ContextVar[str] = contextvars.ContextVar("kernel_mode", default="ref")


def get_mode() -> str:
    return _mode.get()


@contextlib.contextmanager
def kernel_mode(mode: KernelMode):
    tok = _mode.set(mode)
    try:
        yield
    finally:
        _mode.reset(tok)


def _use_pallas() -> bool:
    return _mode.get() == "pallas"


# -- wrappers -----------------------------------------------------------------

def rmsnorm(x, gamma, eps: float = 1e-6):
    if _use_pallas():
        return _norms.rmsnorm(x, gamma, eps)
    return _ref.rmsnorm(x, gamma, eps)


def rmsnorm_residual(x, res, gamma, eps: float = 1e-6):
    if _use_pallas():
        return _norms.rmsnorm_residual(x, res, gamma, eps)
    return _ref.rmsnorm_residual(x, res, gamma, eps)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    if _use_pallas():
        return _norms.layernorm(x, gamma, beta, eps)
    return _ref.layernorm(x, gamma, beta, eps)


def softmax(x, scale: float = 1.0, mask=None):
    if _use_pallas():
        return _softmax.softmax(x, scale, mask)
    return _ref.softmax(x, scale, mask)


def swiglu(gate, up):
    if _use_pallas():
        return _act.swiglu(gate, up)
    return _ref.swiglu(gate, up)


def geglu(gate, up):
    if _use_pallas():
        return _act.geglu(gate, up)
    return _ref.geglu(gate, up)


def squared_relu(x):
    if _use_pallas():
        return _act.squared_relu(x)
    return _ref.squared_relu(x)


def rope(x, positions, theta: float = 10000.0):
    if _use_pallas():
        return _rope.rope(x, positions, theta)
    return _ref.rope(x, positions, theta)


def cross_entropy(logits, labels):
    if _use_pallas():
        return _xent.cross_entropy(logits, labels)
    return _ref.cross_entropy(logits, labels)


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              window: int | None = None, q_offset: int = 0):
    if _use_pallas():
        return _flash.flash_attention(
            q, k, v, causal=causal, scale=scale, window=window, q_offset=q_offset)
    pos_q = None
    if q_offset:
        pos_q = (q_offset + jnp.arange(q.shape[1]))[None, :]
    return _ref.attention(q, k, v, causal=causal, scale=scale, window=window,
                          positions_q=pos_q)


def decode_attention(q, k, v, positions, *, scale: float | None = None,
                     window: int | None = None):
    """Single-token decode attention against a dense KV view; pallas-only
    (callers gate on :func:`get_mode` — the ref path is the einsum chain in
    :func:`repro.models.layers.apply_attention`)."""
    return _decode.decode_attention(q, k, v, positions, scale=scale,
                                    window=window)


def mamba_scan(x, delta, A, B, C, D, return_state: bool = False):
    if _use_pallas() and not return_state:
        return _mamba.mamba_scan(x, delta, A, B, C, D)
    return _ref.mamba_scan(x, delta, A, B, C, D, return_state=return_state)


def rg_lru(x, input_gate, rec_gate, Lambda, c: float = 8.0,
           return_state: bool = False):
    if _use_pallas() and not return_state:
        return _rglru.rg_lru(x, input_gate, rec_gate, Lambda, c)
    return _ref.rg_lru(x, input_gate, rec_gate, Lambda, c, return_state=return_state)


def topk_router(logits, k: int, renormalize: bool = True):
    if _use_pallas():
        return _router.topk_router(logits, k, renormalize)
    return _ref.topk_router(logits, k, renormalize)
