"""MoE router kernel (Pallas TPU): fused softmax + top-k + renormalize.

Router logits are tiny per token but the op chain (softmax -> top-k ->
renorm -> scatter metadata) dispatches 4+ kernels in the unfused path, and
at MoE train batch sizes (256 x 4096 tokens) the intermediates are hundreds
of MB.  One kernel, one read, two small writes.  Top-k (k <= 8, E <= 64)
is k rounds of max+mask on the VPU — argmax via iota compare, no sort.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(x_ref, w_ref, i_ref, *, k: int, renorm: bool):
    x = x_ref[...].astype(jnp.float32)                    # (br, E)
    br, E = x.shape
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)

    cols = jax.lax.broadcasted_iota(jnp.int32, (br, E), 1)
    ws = []
    ids = []
    for _ in range(k):
        w = jnp.max(probs, axis=-1)                        # (br,)
        idx = jnp.argmax(probs, axis=-1).astype(jnp.int32)  # (br,)
        ws.append(w)
        ids.append(idx)
        probs = jnp.where(cols == idx[:, None], -1.0, probs)
    W = jnp.stack(ws, axis=-1)                             # (br, k)
    I = jnp.stack(ids, axis=-1)
    if renorm:
        W = W / jnp.maximum(jnp.sum(W, axis=-1, keepdims=True), 1e-30)
    w_ref[...] = W.astype(w_ref.dtype)
    i_ref[...] = I


def topk_router(logits, k: int, renormalize: bool = True, *,
                block_rows: int = 1024, interpret: bool = True):
    """logits (T, E) -> (weights (T, k), indices (T, k) int32)."""
    T, E = logits.shape
    br = min(block_rows, T)
    while T % br:
        br -= 1
    weights, idx = pl.pallas_call(
        functools.partial(_router_kernel, k=k, renorm=renormalize),
        grid=(T // br,),
        in_specs=[pl.BlockSpec((br, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), logits.dtype),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
    return weights, idx
