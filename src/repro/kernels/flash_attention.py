"""Flash attention (Pallas TPU) with GQA, causal and local-window masking.

Online-softmax blocked attention: grid (batch, q_head, q_blocks, kv_blocks)
with the kv dimension innermost; running (m, l, acc) live in VMEM scratch
across kv blocks (paper block composition + cross-block accumulation — the
same mechanism the generic stitched emitter uses, hand-tuned for the MXU:
the two dots per block are (qb, dh) @ (dh, kb) and (qb, kb) @ (kb, dh),
both MXU-aligned for qb = kb = 128, dh in {64, 128}).

GQA is handled in the BlockSpec index maps: the kv block loaded for q-head h
is kv-head ``h // group`` — no repeat/materialization of K/V.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  qb: int, kb: int, nk: int, q_offset: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32)                  # (qb, dh)
    k = k_ref[0, 0].astype(jnp.float32)                  # (kb, dh)
    v = v_ref[0, 0].astype(jnp.float32)                  # (kb, dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (qb, kb)

    iq = pl.program_id(2)
    qpos = q_offset + iq * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    kpos = ik * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = jnp.ones((qb, kb), dtype=bool)
    if causal:
        mask = mask & (qpos >= kpos)
    if window is not None:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_old - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    window: int | None = None, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, Lq, Hq, Dh); k, v: (B, Lkv, Hkv, Dh) -> (B, Lq, Hq, Dh).

    ``q_offset``: absolute position of q[0] (for chunked prefill / decode)."""
    B, Lq, Hq, Dh = q.shape
    _, Lkv, Hkv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else float(1.0 / np.sqrt(Dh))

    qb = min(block_q, Lq)
    while Lq % qb:
        qb -= 1
    kb = min(block_k, Lkv)
    while Lkv % kb:
        kb -= 1
    nk = Lkv // kb

    qt = q.transpose(0, 2, 1, 3)      # (B, Hq, Lq, Dh)
    kt = k.transpose(0, 2, 1, 3)      # (B, Hkv, Lkv, Dh)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            qb=qb, kb=kb, nk=nk, q_offset=q_offset,
        ),
        grid=(B, Hq, Lq // qb, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qb, Dh), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, kb, Dh),
                         lambda b, h, iq, ik, _g=group: (b, h // _g, ik, 0)),
            pl.BlockSpec((1, 1, kb, Dh),
                         lambda b, h, iq, ik, _g=group: (b, h // _g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, Dh), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
