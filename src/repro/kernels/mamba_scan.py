"""Mamba-1 selective scan kernel (Pallas TPU).

The scan is the paper's "large-granularity dedicated implementation" case
(§6.5: granular enough ops go to the native library; here, to a hand
kernel), with its pointwise halo (softplus/gating) stitched around it by the
fusion planner.

Layout: grid (batch, channel_blocks); each body instance owns a (db,)
channel slab, scans L sequentially with ``lax.fori_loop`` carrying the
(db, N) SSM state in VREG.  Channels sit in lanes (minor dim), the per-step
(db, N) outer products and the (db,) output contraction are VPU-friendly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mamba_kernel(x_ref, d_ref, A_ref, B_ref, C_ref, D_ref, o_ref, *, L: int):
    A = A_ref[...].astype(jnp.float32)            # (db, N)
    Dd = D_ref[...].astype(jnp.float32)           # (db,)
    db, N = A.shape

    def step(t, h):
        x_t = x_ref[0, t, :].astype(jnp.float32)          # (db,)
        dt_t = d_ref[0, t, :].astype(jnp.float32)         # (db,)
        B_t = B_ref[0, t, :].astype(jnp.float32)          # (N,)
        C_t = C_ref[0, t, :].astype(jnp.float32)          # (N,)
        dA = jnp.exp(dt_t[:, None] * A)                   # (db, N)
        dBx = (dt_t * x_t)[:, None] * B_t[None, :]        # (db, N)
        h = dA * h + dBx
        y = jnp.sum(h * C_t[None, :], axis=-1)            # (db,)
        o_ref[0, t, :] = (y + Dd * x_t).astype(o_ref.dtype)
        return h

    h0 = jnp.zeros((db, N), jnp.float32)
    jax.lax.fori_loop(0, L, step, h0)


def mamba_scan(x, delta, A, B, C, D, *, block_channels: int = 256,
               interpret: bool = True):
    """x, delta: (Bb, L, Dm); A: (Dm, N); B, C: (Bb, L, N); D: (Dm,)."""
    Bb, L, Dm = x.shape
    N = A.shape[1]
    db = min(block_channels, Dm)
    while Dm % db:
        db -= 1
    out = pl.pallas_call(
        functools.partial(_mamba_kernel, L=L),
        grid=(Bb, Dm // db),
        in_specs=[
            pl.BlockSpec((1, L, db), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, L, db), lambda b, c: (b, 0, c)),
            pl.BlockSpec((db, N), lambda b, c: (c, 0)),
            pl.BlockSpec((1, L, N), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, L, N), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((db,), lambda b, c: (c,)),
        ],
        out_specs=pl.BlockSpec((1, L, db), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, delta, A, B, C, D)
    return out
