"""AdamW with global-norm clipping — pure-JAX, pytree-native.

State layout mirrors params; :func:`opt_state_pspecs` adds ZeRO-1 sharding
(m/v sharded over the data axis on the first evenly-divisible unsharded
dimension — optimizer memory scales down with DP size; params stay whole)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init(params) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(m=z, v=jax.tree.map(jnp.copy, z),
                      count=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.count + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state.v, grads)

    def step_leaf(p, m, v):
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

    new_params = jax.tree.map(step_leaf, params, new_m, new_v)
    return new_params, AdamWState(new_m, new_v, count), {
        "grad_norm": gnorm, "lr": lr}


# -- ZeRO-1 sharding ----------------------------------------------------------

def opt_state_pspecs(state: AdamWState, param_specs, mesh: Mesh,
                     skip_leading: bool = False) -> AdamWState:
    """m/v: take the param spec and additionally shard the first unsharded,
    evenly-divisible dim over the data axis (classic optimizer-state
    partitioning).

    ``skip_leading``: never shard dim 0 of rank>=2 leaves — dim 0 is the
    scanned layer-stack axis, and sharding it makes every per-layer slice a
    cross-shard access (see EXPERIMENTS.md §Perf iteration log)."""
    from repro.models.sharding import mesh_axes
    dp, _tp = mesh_axes(mesh)
    dp_inner = dp[-1]                       # 'data' (not 'pod': DCN too slow)
    dsize = mesh.shape[dp_inner]

    def zero1(spec: P, leaf):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        start = 1 if (skip_leading and len(leaf.shape) >= 2) else 0
        for i, (s, dim) in enumerate(zip(parts, leaf.shape)):
            if i < start:
                continue
            if s is None and dim % dsize == 0 and dim >= dsize:
                parts[i] = dp_inner
                break
        return P(*parts)

    m_specs = jax.tree.map(zero1, param_specs, state.m,
                           is_leaf=lambda x: isinstance(x, P))
    return AdamWState(m=m_specs, v=m_specs, count=P())
