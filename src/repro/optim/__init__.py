from . import adamw, compress, packed
from .adamw import AdamWConfig, AdamWState
from .packed import PackedAdamW, PackedLayout, make_layout, pack_tree, unpack_tree
