"""Packed multi-tensor AdamW — the paper's "fusion without data dependences"
applied to the optimizer phase of training.

A plain AdamW step dispatches O(leaves) elementwise kernels per parameter
tensor (m, v, update, decay, cast — every one memory-bound), plus a
global-norm reduction tree.  FusionStitching's headline capability is
packing *independent* ops into one kernel so their loops share a launch
(§4.2, kernel packing); the per-tensor update chains are exactly such a set:
after the shared clip scale is known they have no data dependences between
them.

Mechanism
---------
* :func:`make_layout` flattens the params pytree: each leaf is padded to a
  multiple of ``rows`` and viewed as a ``(rows, cols_i)`` float32 panel, so
  every per-tensor chain shares the one row space a stitched kernel's grid
  iterates over (leaves only differ in their minor dimension, which the
  row-parallel emitter allows per member).
* :func:`packed_update_fn` spells the whole AdamW+global-norm-clip update
  over the packed panels with exactly :mod:`repro.optim.adamw`'s formulas —
  the per-leaf sum-of-squares reductions are cross-row accumulators feeding
  the shared clip scale, which is the emitter's grid==1 block-composition
  path (§5.3 layout constraint).
* :class:`PackedAdamW` wraps that function with :func:`repro.exec.stitch`
  — the shared execution layer owns tracing, compile-or-fallback, and
  miss-then-upgrade polling.  The substitution search collapses the entire
  update into ONE fusion pattern (there are no partition ops), so the
  compiled artifact is a single packed Pallas kernel covering clip + m/v
  moments + decay + step for every tensor.  With a
  :class:`repro.cache.CompilationService` the compile is miss-then-upgrade:
  step 0 runs the XLA-mode fallback artifact (same numerics), later steps
  replay the cached packed plan.

Scheduling scalars (lr, bias corrections) are computed outside the kernel —
they are O(1) flops on the step counter; the kernel takes them as scalar
operands so one compiled artifact serves every step.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import StitchCompiler
from repro.exec import stitch

from . import adamw

__all__ = ["PackedLayout", "make_layout", "pack_tree", "unpack_tree",
           "packed_update_fn", "PackedAdamW"]


DEFAULT_ROWS = 8   # one TPU sublane group; every leaf pads to a multiple


@dataclass(frozen=True)
class PackedLayout:
    """How a params pytree maps onto shared-row float32 panels."""
    rows: int
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]   # original leaf shapes
    dtypes: tuple[str, ...]               # original leaf dtypes
    cols: tuple[int, ...]                 # minor dim of each packed panel

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    def panel_shape(self, i: int) -> tuple[int, int]:
        return (self.rows, self.cols[i])


def make_layout(tree, rows: int = DEFAULT_ROWS) -> PackedLayout:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes, dtypes, cols = [], [], []
    for leaf in leaves:
        n = int(math.prod(leaf.shape)) if leaf.shape else 1
        padded = n + (-n) % rows
        shapes.append(tuple(leaf.shape))
        dtypes.append(str(leaf.dtype))
        cols.append(padded // rows)
    return PackedLayout(rows, treedef, tuple(shapes), tuple(dtypes), tuple(cols))


def _pack_leaf(leaf, rows: int, cols: int):
    flat = jnp.ravel(leaf).astype(jnp.float32)
    pad = rows * cols - flat.shape[0]
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat.reshape(rows, cols)


def pack_tree(layout: PackedLayout, tree) -> list[jax.Array]:
    """Pytree -> list of zero-padded (rows, cols_i) float32 panels."""
    leaves = jax.tree_util.tree_leaves(tree)
    if len(leaves) != layout.n_leaves:
        raise ValueError(f"tree has {len(leaves)} leaves, layout expects "
                         f"{layout.n_leaves}")
    return [_pack_leaf(l, layout.rows, c) for l, c in zip(leaves, layout.cols)]


def unpack_tree(layout: PackedLayout, panels, dtypes=None):
    """Inverse of :func:`pack_tree`; casts each leaf back to its dtype
    (``dtypes=None``) or to an explicit per-leaf dtype list (e.g. float32
    for optimizer moments)."""
    leaves = []
    for i, panel in enumerate(panels):
        shape = layout.shapes[i]
        n = int(math.prod(shape)) if shape else 1
        dt = layout.dtypes[i] if dtypes is None else dtypes[i]
        leaves.append(panel.reshape(-1)[:n].reshape(shape).astype(dt))
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def packed_update_fn(cfg: adamw.AdamWConfig, external_ssq: bool = False) -> Callable:
    """The update over packed panels, formula-for-formula equal to
    :func:`repro.optim.adamw.update` (zero padding is a fixed point of the
    update: g=0, p=0 stay 0, so panels never leak across steps).

    ``external_ssq`` is the sharded (shard_map) variant: the panels are
    TP-shard-local slices, so the global-norm sum-of-squares cannot be
    formed inside the kernel — it arrives as one extra scalar operand
    (computed from the psum-mean'd full gradients outside the stitched
    region) and the kernel stays a pure per-shard packed update."""

    def update(ps, gs, ms, vs, lr, b1c, b2c, gss=None):
        if external_ssq:
            norm = jnp.sqrt(gss)
        else:
            ssq = None
            for g in gs:                   # leaf order == reference leaf order
                s = jnp.sum(jnp.square(g))
                ssq = s if ssq is None else ssq + s
            norm = jnp.sqrt(ssq)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(norm, 1e-12))
        new_p, new_m, new_v = [], [], []
        for p, g, m, v in zip(ps, gs, ms, vs):
            gc = g * scale
            nm = cfg.b1 * m + (1 - cfg.b1) * gc
            nv = cfg.b2 * v + (1 - cfg.b2) * gc * gc
            upd = (nm / b1c) / (jnp.sqrt(nv / b2c) + cfg.eps)
            upd = upd + cfg.weight_decay * p
            new_p.append(p - lr * upd)
            new_m.append(nm)
            new_v.append(nv)
        return new_p, new_m, new_v, norm

    return update


class PackedAdamW:
    """Compiled packed-update frontend with the reference module's API.

    ``update(grads, state, params)`` matches :func:`repro.optim.adamw.update`
    (minus the leading cfg).  Three execution paths:

    * ``service=None`` — blocking stitch compile at construction (offline).
    * with a :class:`~repro.cache.CompilationService` — miss-then-upgrade:
      the first step runs the XLA-mode fallback artifact, and every
      ``update`` polls the cache so the packed single-kernel plan takes over
      as soon as the background compile lands.
    * ``use_compiler=False`` — pure-jnp execution of the packed function
      (debug / property tests without the compile cost).
    """

    def __init__(self, cfg: adamw.AdamWConfig, params,
                 rows: int = DEFAULT_ROWS, service=None,
                 compiler: StitchCompiler | None = None,
                 use_compiler: bool = True, external_ssq: bool = False,
                 placement: str = ""):
        self.cfg = cfg
        self.layout = make_layout(params, rows=rows)
        self.service = service
        self.external_ssq = external_ssq
        self.placement = placement
        self._fn = packed_update_fn(cfg, external_ssq=external_ssq)
        # panelization is pure pad/reshape/cast glue; jitted it is two
        # compiled calls per step instead of O(leaves) host-driven dispatches
        # bracketing the packed kernel
        lay = self.layout
        self._pack4 = jax.jit(lambda p, g, m, v: (
            pack_tree(lay, p), pack_tree(lay, g),
            pack_tree(lay, m), pack_tree(lay, v)))
        f32_leaves = ["float32"] * lay.n_leaves
        self._unpack3 = jax.jit(lambda p, m, v, _dt=tuple(f32_leaves): (
            unpack_tree(lay, p),
            unpack_tree(lay, m, _dt), unpack_tree(lay, v, _dt)))
        f32 = jnp.float32
        example = tuple(
            [jnp.zeros(self.layout.panel_shape(i), f32)
             for i in range(self.layout.n_leaves)]
            for _ in range(4)
        ) + (jnp.zeros((), f32),) * (4 if external_ssq else 3)
        self._example = example
        self._exec = None
        if not use_compiler:
            return
        # all execution flows through the shared layer: "stitch" mode is the
        # miss-then-upgrade service path, "offline" blocks at trace time
        # (the legacy explicit-compiler path maps onto a one-off service)
        mode = "stitch" if service is not None else "offline"
        if service is None and compiler is not None:
            from repro.cache import CompilationService
            service = CompilationService(hw=compiler.hw,
                                         gen_cfg=compiler.gen_cfg,
                                         use_pallas=compiler.use_pallas)
        self._exec = stitch(self._fn, mode=mode, service=service,
                            placement=placement, name="packed_adamw")
        status = self._exec.warmup(*example)
        if status == "error":
            raise RuntimeError(
                f"packed AdamW trace/compile failed: "
                f"{self._exec.report().get('error')}")

    # -- observability --------------------------------------------------------
    @property
    def status(self) -> str | None:
        """jnp (no compiler) | compiled (offline) | hit/miss/pending/failed."""
        return self._exec.status if self._exec is not None else "jnp"

    @property
    def graph(self):
        return self._exec.graph if self._exec is not None else None

    @property
    def _compiled(self):
        return self._exec.compiled if self._exec is not None else None

    @property
    def kernel_count(self) -> int | None:
        """Kernels the whole AdamW+clip update dispatches (1 when packed)."""
        c = self._compiled
        return c.stats.n_kernels if c is not None else None

    def report(self) -> dict:
        """The unified exec report (:data:`repro.obs.EXEC_REPORT_SCHEMA`)
        plus the packing-specific ``n_leaves`` / ``rows``.  On the pure-jnp
        path (``use_compiler=False``) there is no exec layer; ``status`` is
        ``"jnp"`` and the exec keys are their empty defaults."""
        if self._exec is not None:
            out = self._exec.report()
        else:
            from repro.obs import EXEC_REPORT_SCHEMA
            out = {"schema": EXEC_REPORT_SCHEMA, "name": "packed_adamw",
                   "mode": "jnp", "status": self.status,
                   "calls": {"stitched": 0, "fallback": 0, "jit": 0},
                   "specializations": 0, "placement": self.placement,
                   "plan": None, "error": None, "errors": {},
                   "diagnostics": [], "cache": None, "measured": None}
        out["status"] = self.status          # "jnp" override when no exec
        out["n_leaves"] = self.layout.n_leaves
        out["rows"] = self.layout.rows
        return out

    # -- miss-then-upgrade polling --------------------------------------------
    def poll_upgrade(self) -> None:
        if self._exec is not None:
            self._exec.poll_upgrade()

    # -- the update ------------------------------------------------------------
    def _run(self, *args):
        if self._exec is None:               # pure-jnp path
            return self._fn(*args)
        return self._exec(*args)

    def update_local(self, params, grads, m, v, lr, b1c, b2c, gss=None):
        """Pure shard-local update over this layout's panels (no polling, no
        schedule handling): ``(new_params, new_m, new_v, grad_norm)``.

        This is the ``shard_map`` body of the mesh-aware stitched train step
        — each shard packs its local param/grad/moment slices, runs the one
        packed kernel, and unpacks, with the clip scale derived from the
        externally supplied global sum-of-squares (``external_ssq=True``).
        """
        ps, gs, ms, vs = self._pack4(params, grads, m, v)
        args = (ps, gs, ms, vs, jnp.asarray(lr, jnp.float32),
                jnp.asarray(b1c, jnp.float32), jnp.asarray(b2c, jnp.float32))
        if self.external_ssq:
            if gss is None:
                raise ValueError("external_ssq layout requires gss")
            args += (jnp.asarray(gss, jnp.float32),)
        new_p, new_m, new_v, gnorm = self._run(*args)
        up, um, uv = self._unpack3(new_p, new_m, new_v)
        return up, um, uv, gnorm

    def update(self, grads, state: adamw.AdamWState, params, gss=None):
        """(new_params, new_state, metrics) — drop-in for adamw.update."""
        self.poll_upgrade()
        cfg = self.cfg
        count = state.count + 1
        lr = adamw.schedule(cfg, count)
        cf = count.astype(jnp.float32)
        b1c = 1 - cfg.b1 ** cf
        b2c = 1 - cfg.b2 ** cf
        up, um, uv, gnorm = self.update_local(
            params, grads, state.m, state.v, lr, b1c, b2c, gss=gss)
        return (up, adamw.AdamWState(m=um, v=uv, count=count),
                {"grad_norm": gnorm, "lr": lr})
