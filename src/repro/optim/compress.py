"""int8 gradient compression with error feedback.

Distributed-optimization trick for DCN-bound multi-pod training: gradients
crossing the slow ``pod`` axis are quantized to int8 (per-leaf absmax scale)
before the cross-pod all-reduce; the quantization error is carried to the
next step (error feedback keeps the method unbiased in the long run).

In-graph usage (under pjit, the cast shrinks the all-reduce payload 4x):

    g_q, scales = quantize(grads)
    g_q = lax.psum(g_q, 'pod')            # int8->int32 accumulate
    grads = dequantize(g_q, scales, npods)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(tree):
    """Per-leaf symmetric int8 quantization. Returns (int8 tree, scale tree)."""
    def q(g):
        a = jnp.max(jnp.abs(g.astype(jnp.float32)))
        scale = jnp.maximum(a, 1e-12) / 127.0
        return jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8), scale

    leaves, treedef = jax.tree.flatten(tree)
    qs, scales = zip(*(q(l) for l in leaves)) if leaves else ((), ())
    return jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, scales)


def dequantize(qtree, scales, n_shards: int = 1):
    """Inverse of :func:`quantize`; `n_shards` divides an int32 psum result."""
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s / n_shards, qtree, scales)


def compress_with_feedback(grads, error):
    """Error-feedback wrapper: quantize (grads + carried error), return the
    int8 payload, scales, and the new error to carry."""
    adj = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, error)
    q, scales = quantize(adj)
    deq = dequantize(q, scales)
    new_error = jax.tree.map(lambda a, d: a - d, adj, deq)
    return q, scales, new_error


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
