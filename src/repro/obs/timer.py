"""Opt-in measured-kernel profiling — real wall clock, not the cost model.

The ROADMAP's "measured (not modeled) tuning" item and the perf-regression
gate both need *measured* timing data; the cost model alone cannot defend
real wall-clock (PowerFusion's feedback layer is the same lesson).  This
module is the measurement primitive: a ``block_until_ready``-bracketed
timer around each stitched-executable call (and its jit fallback), so the
recorded duration covers device execution, not just async dispatch.

It is **opt-in** because the bracket itself perturbs: ``block_until_ready``
serializes the dispatch pipeline, which an unobserved serving loop
deliberately keeps deep.  Disabled (the default), the check in the hot
path is one module-attribute read — free.

Measurements land in three places so every consumer sees the same numbers:

* the per-callable accumulators a ``StitchedFunction.report()`` exposes as
  ``measured`` (path -> histogram summary, with the plan's modeled time
  alongside for the measured-vs-modeled comparison);
* the process :class:`~repro.obs.metrics.MetricsRegistry`
  (``exec_measured_seconds{fn=...,path=...}`` histograms);
* the active tracer as ``exec.measured`` events, which is what lets
  ``launch/inspect.py`` print a per-plan modeled-vs-measured table from a
  trace file alone.
"""

from __future__ import annotations

__all__ = ["enabled", "enable", "disable", "is_enabled", "record"]

# module-level flag: hot paths read `timer.enabled` directly (attribute
# lookup, no call) — do NOT `from ... import enabled` (that copies)
enabled = False


def enable() -> None:
    global enabled
    enabled = True


def disable() -> None:
    global enabled
    enabled = False


def is_enabled() -> bool:
    return enabled


def record(name: str, path: str, measured_s: float,
           modeled_s: float | None = None, placement: str = "") -> None:
    """Fan one measurement out to the registry and the tracer.

    ``name`` is the stitched function's name, ``path`` is which execution
    route served the call (``stitched`` / ``fallback`` / ``jit``);
    ``modeled_s`` is the active plan's cost-model time when one exists.
    """
    from . import registry, tracer

    reg = registry()
    reg.histogram("exec_measured_seconds", fn=name, path=path).observe(
        measured_s)
    if modeled_s is not None:
        reg.gauge("exec_modeled_seconds", fn=name, path=path).set(modeled_s)
    tracer.event("exec.measured", cat="measure", fn=name, path=path,
                 measured_s=measured_s, modeled_s=modeled_s,
                 placement=placement)
