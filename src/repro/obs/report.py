"""The one execution-report schema every stitched caller shares.

Before this module, ``Engine.stitch_report()``, ``StitchedTrainStep.
report()`` and ``PackedAdamW.report()`` each exposed a slightly different
dict shape; dashboards and tests had to special-case all three.  Now every
path reports through :meth:`repro.exec.StitchedFunction.report`, whose
output conforms to :data:`EXEC_REPORT_SCHEMA` — this module documents the
schema and provides the validator the schema test (and any external
consumer) checks against.

Schema (version ``repro.obs/exec-report@1``) — keys always present:

==================  =========================================================
key                 meaning
==================  =========================================================
``schema``          the literal version string above
``name``            the stitched function's name
``mode``            ``stitch`` / ``shadow`` / ``offline`` / ``jit``
``status``          active specialization status (``hit`` / ``miss`` /
                    ``pending`` / ``compiled`` / ``failed`` / ``error``) or
                    None before the first call
``calls``           ``{"stitched": n, "fallback": n, "jit": n}`` — which
                    route served each call
``specializations`` number of traced (static-arg) specializations
``placement``       active mesh+PartitionSpec cache key ("" = single-device)
``plan``            active plan stats (mode, n_ops, n_kernels,
                    pallas_groups, modeled_time, cache_status) or None
``error``           this function's trace/compile failure message or None
``errors``          *all* per-key background-compile failures recorded by
                    the :class:`repro.cache.CompilationService`
                    (stringified key -> message; {} when none / no service)
``diagnostics``     structured ``StitchInfeasible`` records from the active
                    plan's tuning run (stage / pattern_class / members /
                    reason dicts) — why chosen patterns degraded to
                    fused-jnp; [] when none
``cache``           the cache report: total/per-bucket/per-placement
                    hits+misses, tier sizes (None without a service)
``measured``        measured-kernel timing per path (histogram summaries,
                    see :mod:`repro.obs.timer`) or None when never enabled
==================  =========================================================

Compatibility keys (``stitched_calls`` / ``fallback_calls`` /
``jit_calls`` / ``service_error``) are also emitted; new consumers should
prefer ``calls`` and ``errors``.
"""

from __future__ import annotations

__all__ = ["EXEC_REPORT_SCHEMA", "EXEC_REPORT_KEYS", "validate_exec_report"]

EXEC_REPORT_SCHEMA = "repro.obs/exec-report@1"

# keys that must be present in every StitchedFunction.report()
EXEC_REPORT_KEYS = frozenset({
    "schema", "name", "mode", "status", "calls", "specializations",
    "placement", "plan", "error", "errors", "diagnostics", "cache",
    "measured",
})

_CALL_KEYS = frozenset({"stitched", "fallback", "jit"})


def validate_exec_report(rep: dict) -> list[str]:
    """Return the list of schema violations (empty = conforming)."""
    problems: list[str] = []
    if not isinstance(rep, dict):
        return [f"report is {type(rep).__name__}, not dict"]
    for k in sorted(EXEC_REPORT_KEYS - set(rep)):
        problems.append(f"missing key {k!r}")
    if rep.get("schema") != EXEC_REPORT_SCHEMA:
        problems.append(f"schema is {rep.get('schema')!r}, "
                        f"expected {EXEC_REPORT_SCHEMA!r}")
    calls = rep.get("calls")
    if not isinstance(calls, dict) or set(calls) != _CALL_KEYS:
        problems.append(f"calls must have exactly keys {sorted(_CALL_KEYS)}, "
                        f"got {calls!r}")
    if not isinstance(rep.get("errors", None), dict):
        problems.append("errors must be a dict (possibly empty)")
    plan = rep.get("plan")
    if plan is not None and not {"n_kernels", "n_ops",
                                 "modeled_time"} <= set(plan):
        problems.append(f"plan missing kernel/op/time stats: {plan!r}")
    cache = rep.get("cache")
    if cache is not None and not {"total_hits", "total_misses",
                                  "per_placement"} <= set(cache):
        problems.append(f"cache missing hit/miss/per_placement: "
                        f"{sorted(cache) if isinstance(cache, dict) else cache}")
    return problems
