"""Metrics registry: counters / gauges / histograms with one latency-summary
implementation.

Before this module, three ad-hoc reporters each summarized latencies their
own way (``serve/metrics.py`` percentiles, the serve driver's wall-clock
report, the bench harness's per-section timings) and engine / train step /
optimizer / cache each invented a dict shape.  The registry gives them one
vocabulary:

* :class:`Counter` — monotonically increasing count (tokens emitted,
  cache hits);
* :class:`Gauge` — last-written value (slot occupancy, modeled plan time);
* :class:`Histogram` — value stream with the shared
  :func:`percentiles` summary (p50/p95/p99 plus count/sum/min/max/mean).
  Empty and single-sample streams return well-defined summaries (all-zero
  / the sample itself) instead of edge-case behavior;
* :class:`MetricsRegistry` — the namespace.  ``snapshot()`` returns one
  nested dict (JSON-ready via ``to_json``); ``to_prometheus()`` renders
  the Prometheus text exposition format.  ``register_provider(name, fn)``
  pulls existing report dicts (``Engine.stitch_report``,
  ``StitchedTrainStep.report``, ``StitchCache.report``) into the same
  snapshot, so every layer exports through one file.

Metrics may carry labels (``registry.counter("cache_lookups",
result="hit")``); label sets are part of the identity, mirroring
Prometheus semantics.
"""

from __future__ import annotations

import json
import threading
from typing import Any, Callable, Iterable

import numpy as np

__all__ = ["percentiles", "Counter", "Gauge", "Histogram",
           "MetricsRegistry"]


def percentiles(values, ps: Iterable[int] = (50, 95, 99)) -> dict[str, float]:
    """THE latency-percentile summary every reporter shares.

    Well-defined on degenerate streams: an empty stream returns 0.0 for
    every percentile; a single sample returns that sample.
    """
    values = np.asarray(list(values), np.float64)
    if values.size == 0:
        return {f"p{p}": 0.0 for p in ps}
    return {f"p{p}": float(np.percentile(values, p)) for p in ps}


class Counter:
    """Monotonic counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def export(self) -> float:
        return self.value


class Gauge:
    """Last-written value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def export(self) -> float:
        return self.value


class Histogram:
    """Bounded value stream summarized with the shared percentiles.

    Keeps at most ``capacity`` most-recent samples (count/sum stay exact);
    a long-lived serving process never grows without bound.
    """

    __slots__ = ("values", "count", "total", "_min", "_max", "capacity")

    def __init__(self, capacity: int = 4096):
        self.values: list[float] = []
        self.count = 0
        self.total = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self.capacity = capacity

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        self.values.append(v)
        if len(self.values) > self.capacity:
            del self.values[: len(self.values) // 2]

    def summary(self) -> dict[str, float]:
        """count/sum/min/max/mean + p50/p95/p99; all-zero when empty."""
        if not self.count:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, **percentiles(())}
        return {
            "count": self.count,
            "sum": self.total,
            "min": self._min,
            "max": self._max,
            "mean": self.total / self.count,
            **percentiles(self.values),
        }

    export = summary


def _metric_key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


class MetricsRegistry:
    """Thread-safe namespace of counters/gauges/histograms + providers."""

    def __init__(self):
        self._metrics: dict[tuple, Any] = {}
        self._providers: dict[str, Callable[[], dict]] = {}
        self._lock = threading.Lock()

    # -- get-or-create ---------------------------------------------------------
    def _get(self, cls, name: str, labels: dict):
        key = _metric_key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls()
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r}{labels} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def register_provider(self, name: str, fn: Callable[[], dict]) -> None:
        """Pull an existing report dict (engine / train step / cache) into
        every snapshot under ``providers.<name>``; a provider that raises
        exports its error string instead of killing the snapshot."""
        with self._lock:
            self._providers[name] = fn

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._providers.clear()

    # -- export ----------------------------------------------------------------
    def snapshot(self) -> dict:
        """One nested JSON-ready dict of everything the process reports."""
        with self._lock:
            metrics = dict(self._metrics)
            providers = dict(self._providers)
        out: dict[str, Any] = {"counters": {}, "gauges": {}, "histograms": {}}
        section = {Counter: "counters", Gauge: "gauges",
                   Histogram: "histograms"}
        for (name, labels), m in sorted(metrics.items(),
                                        key=lambda kv: kv[0]):
            out[section[type(m)]][name + _label_str(labels)] = m.export()
        if providers:
            out["providers"] = {}
            for name, fn in sorted(providers.items()):
                try:
                    out["providers"][name] = fn()
                except Exception as e:      # noqa: BLE001 — report, don't die
                    out["providers"][name] = {
                        "error": f"{type(e).__name__}: {e}"}
        return out

    def to_json(self, path: str | None = None, **extra) -> str:
        """Serialize ``snapshot() | extra``; also writes ``path`` if given."""
        text = json.dumps({**self.snapshot(), **extra}, indent=2, default=str)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_prometheus(self) -> str:
        """Prometheus text exposition: counters/gauges as-is, histograms as
        summary quantiles + ``_count``/``_sum`` series."""
        with self._lock:
            metrics = dict(self._metrics)
        lines: list[str] = []
        typed: set[str] = set()
        for (name, labels), m in sorted(metrics.items(), key=lambda kv: kv[0]):
            ls = _label_str(labels)
            if isinstance(m, Counter):
                if name not in typed:
                    lines.append(f"# TYPE {name} counter")
                    typed.add(name)
                lines.append(f"{name}{ls} {m.value:g}")
            elif isinstance(m, Gauge):
                if name not in typed:
                    lines.append(f"# TYPE {name} gauge")
                    typed.add(name)
                lines.append(f"{name}{ls} {m.value:g}")
            else:
                if name not in typed:
                    lines.append(f"# TYPE {name} summary")
                    typed.add(name)
                s = m.summary()
                for q in (50, 95, 99):
                    ql = tuple(sorted(dict(labels,
                                           quantile=f"0.{q}").items()))
                    lines.append(f"{name}{_label_str(ql)} {s[f'p{q}']:g}")
                lines.append(f"{name}_count{ls} {s['count']:g}")
                lines.append(f"{name}_sum{ls} {s['sum']:g}")
        return "\n".join(lines) + ("\n" if lines else "")
