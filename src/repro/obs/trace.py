"""Structured span tracing with Chrome-trace / Perfetto export.

The paper's argument starts from *measuring* where memory-intensive time
goes (§2 profiles op-level breakdowns); this module is the repro's
equivalent for the whole stitching pipeline.  A :class:`Tracer` records
two event kinds into an in-memory buffer:

* **spans** (``tracer.span(name)`` as a context manager) — wall-clock
  intervals, exported as Chrome-trace *complete* events (``ph: "X"`` with
  ``ts``/``dur``), nesting naturally per thread;
* **instant events** (``tracer.event(name)``) — point markers such as a
  cache hit, a background compile landing, or the fallback→stitched
  upgrade (``ph: "i"``), plus **counter events**
  (``tracer.counter_event(name, **values)``, ``ph: "C"``) for time series
  like slot occupancy.

The buffer exports as the Chrome trace-event JSON dialect
(``{"traceEvents": [...]}``) that ``chrome://tracing`` and
https://ui.perfetto.dev load directly, so one stitched train or serve run
renders as a timeline: compile stages on the background threads, per-step
decode spans on the main thread, with hit/miss/upgrade markers in between.

Overhead contract: a *disabled* tracer's ``span()`` returns a shared
no-op context manager and ``event()`` returns immediately after one
attribute check — instrumentation left in hot paths (per-token decode) is
free when tracing is off.  Timestamps are microseconds since the tracer's
epoch (``time.perf_counter`` based), the unit Chrome trace expects.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any

__all__ = ["Tracer", "NULL_SPAN"]


class _NullSpan:
    """Shared do-nothing span: the disabled tracer's fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args) -> None:
        """No-op counterpart of :meth:`_Span.set`."""


NULL_SPAN = _NullSpan()


class _Span:
    """One live span; records a ``ph: "X"`` complete event on exit."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def set(self, **args) -> None:
        """Attach/override args discovered while the span is open (e.g. a
        scheduler step's admission/eviction counts, known only at the end)."""
        self.args.update(args)

    def __enter__(self):
        self._t0 = self._tracer._now()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer._now()
        self._tracer._record({
            "ph": "X", "name": self.name, "cat": self.cat or "span",
            "ts": self._t0, "dur": t1 - self._t0,
            "pid": self._tracer.pid, "tid": threading.get_ident(),
            "args": self.args,
        })
        return False


class Tracer:
    """Thread-safe trace-event buffer (disabled by default).

    One process-wide instance lives at :data:`repro.obs.tracer`; library
    code calls the module-level :func:`repro.obs.span` /
    :func:`repro.obs.event` helpers, applications flip it on with
    :func:`repro.obs.enable_tracing` and write the file with
    :func:`repro.obs.save_trace`.
    """

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self.pid = os.getpid()
        self._events: list[dict] = []
        self._thread_names: dict[int, str] = {}
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()

    # -- clock ----------------------------------------------------------------
    def _now(self) -> float:
        """Microseconds since this tracer's epoch (Chrome-trace unit)."""
        return (time.perf_counter() - self._epoch) * 1e6

    # -- recording ------------------------------------------------------------
    def _record(self, ev: dict) -> None:
        tid = ev["tid"]
        with self._lock:
            self._events.append(ev)
            if tid not in self._thread_names:
                self._thread_names[tid] = threading.current_thread().name

    def span(self, name: str, cat: str = "", **args):
        """Context manager timing a pipeline stage; no-op when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, args)

    def event(self, name: str, cat: str = "", **args) -> None:
        """Instant marker (``ph: "i"``, thread scope)."""
        if not self.enabled:
            return
        self._record({
            "ph": "i", "name": name, "cat": cat or "event", "s": "t",
            "ts": self._now(), "pid": self.pid,
            "tid": threading.get_ident(), "args": args,
        })

    def counter_event(self, name: str, cat: str = "", **values) -> None:
        """Counter sample (``ph: "C"``) — numeric time series (occupancy,
        queue depth) Perfetto renders as stacked tracks."""
        if not self.enabled:
            return
        self._record({
            "ph": "C", "name": name, "cat": cat or "counter",
            "ts": self._now(), "pid": self.pid,
            "tid": threading.get_ident(), "args": values,
        })

    # -- lifecycle ------------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._thread_names.clear()
        self._epoch = time.perf_counter()

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    # -- export ---------------------------------------------------------------
    def events(self) -> list[dict]:
        """Snapshot of recorded events (copies the list, not the dicts)."""
        with self._lock:
            return list(self._events)

    def chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object Perfetto loads.

        Thread-name metadata events (``ph: "M"``) label the main thread and
        every background compile thread that recorded anything.
        """
        with self._lock:
            events = list(self._events)
            names = dict(self._thread_names)
        meta: list[dict[str, Any]] = [{
            "ph": "M", "name": "process_name", "pid": self.pid, "tid": 0,
            "ts": 0, "args": {"name": "repro-stitching"},
        }]
        for tid, nm in names.items():
            meta.append({"ph": "M", "name": "thread_name", "pid": self.pid,
                         "tid": tid, "ts": 0, "args": {"name": nm}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        """Write the Chrome-trace JSON file; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, default=str)
        return path
