"""``repro.obs`` — unified observability for the stitching pipeline.

One subsystem, three pieces (see the per-module docs):

* :mod:`.trace` — structured span tracing with Chrome-trace / Perfetto
  export.  Every pipeline stage is instrumented: trace → pattern-gen →
  ILP/greedy → tune, cache hit/miss/replay, background compile
  start/land/fail, the fallback→stitched upgrade, and per-step serve /
  train execution (slot occupancy, evictions).  A whole run renders as a
  timeline in https://ui.perfetto.dev.
* :mod:`.metrics` — counters / gauges / histograms with the one shared
  percentile summary, a process :class:`~.metrics.MetricsRegistry`, and
  JSON + Prometheus-text export.  Existing report dicts plug in via
  ``register_provider``.
* :mod:`.timer` — opt-in ``block_until_ready``-bracketed measured-kernel
  timing (measured-vs-modeled per plan), feeding the registry, the
  tracer, and ``benchmarks/run.py --json``.

Both the tracer and the timer are **off by default** and their hot-path
checks are single attribute reads, so instrumentation in per-token code
costs nothing unobserved.  Typical wiring (what ``launch/train.py`` and
``launch/serve.py`` do for ``--trace-out`` / ``--metrics-json``)::

    from repro import obs

    obs.enable_tracing()          # spans + events start recording
    obs.enable_timing()           # measured kernel timer on
    ... run ...
    obs.save_trace("trace.json")  # load this in Perfetto
    obs.registry().to_json("metrics.json")
    print(obs.registry().to_prometheus())

``python -m repro.launch.inspect trace.json`` prints the compile timeline
and the per-plan modeled-vs-measured table offline.
"""

from __future__ import annotations

from . import timer
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      percentiles)
from .report import (EXEC_REPORT_KEYS, EXEC_REPORT_SCHEMA,
                     validate_exec_report)
from .trace import NULL_SPAN, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "percentiles",
    "Tracer", "NULL_SPAN", "tracer", "registry",
    "span", "event", "counter_event",
    "enable_tracing", "disable_tracing", "tracing_enabled", "save_trace",
    "clear_trace",
    "enable_timing", "disable_timing", "timing_enabled",
    "EXEC_REPORT_KEYS", "EXEC_REPORT_SCHEMA", "validate_exec_report",
    "timer",
]

# the process-wide instances library code records into
tracer = Tracer()
_registry = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process metrics registry."""
    return _registry


# -- tracing façade (delegates to the process tracer) -------------------------
def span(name: str, cat: str = "", **args):
    """Time a pipeline stage: ``with obs.span("compile.ilp", graph=g.name):``.
    Returns a shared no-op context manager when tracing is disabled."""
    if not tracer.enabled:
        return NULL_SPAN
    return tracer.span(name, cat, **args)


def event(name: str, cat: str = "", **args) -> None:
    """Instant marker (cache hit, compile landed, upgrade, eviction)."""
    if tracer.enabled:
        tracer.event(name, cat, **args)


def counter_event(name: str, cat: str = "", **values) -> None:
    """Numeric time-series sample (slot occupancy, queue depth)."""
    if tracer.enabled:
        tracer.counter_event(name, cat, **values)


def enable_tracing() -> None:
    tracer.enable()


def disable_tracing() -> None:
    tracer.disable()


def tracing_enabled() -> bool:
    return tracer.enabled


def clear_trace() -> None:
    tracer.clear()


def save_trace(path: str) -> str:
    """Write the Chrome-trace JSON (loadable in Perfetto); returns path."""
    return tracer.save(path)


# -- measured-kernel timing ----------------------------------------------------
def enable_timing() -> None:
    """Turn on the opt-in block_until_ready-bracketed kernel timer."""
    timer.enable()


def disable_timing() -> None:
    timer.disable()


def timing_enabled() -> bool:
    return timer.enabled
