"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The XLA_FLAGS setup below MUST run before any jax import (jax locks the
device count on first init); 512 placeholder CPU devices back the
production meshes.  For each cell we:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., donate...).lower(*specs)
        compiled = lowered.compile()
        memory_analysis() / cost_analysis() / collective bytes from HLO

and append a JSON record to the output file (incremental: a crashed sweep
resumes where it left off).

Usage:
    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import os

# respect an operator-provided device count; keep unrelated flags intact
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=512").strip()


import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import (SHAPES, batch_pspecs, build_model, cache_pspecs,
                          param_pspecs)
from repro.models.config import ShapeConfig
from repro.optim import AdamWConfig, adamw
from repro.train import make_train_step


# ---------------------------------------------------------------------------
# cell applicability (documented skips, DESIGN.md §Arch-applicability)
# ---------------------------------------------------------------------------

def cell_status(cfg, shape: ShapeConfig) -> str:
    if shape.kind == "long_decode" and not cfg.subquadratic:
        return "skip: full-attention arch, 512k dense decode is quadratic"
    return "run"


# ---------------------------------------------------------------------------
# collective-bytes parser (post-SPMD optimized HLO)
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"\b([a-z]+\d+(?:_\d+)?|pred)\[([\d,]*)\]")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8_e4m3": 1, "f8_e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_OP_RE = re.compile(r"=\s+([a-z0-9_]+)\[([\d,]*)\][^ ]*\s+([a-z\-]+)\(")

# ops whose "bytes accessed" are CPU-lowering artifacts that a TPU pipeline
# fuses away or never materializes: bf16<->f32 converts (CPU has no native
# bf16), copies/bitcasts/GTEs (aliasing), parameter (counted at consumers),
# broadcast (fused into consumers on TPU).
_PHANTOM_OPS = {"convert", "copy", "bitcast", "get-tuple-element",
                "parameter", "broadcast", "tuple", "constant", "iota",
                "reshape"}


def op_bytes_histogram(hlo_text: str) -> dict[str, float]:
    """Output bytes per HLO op kind — the dry-run 'profile'."""
    agg: dict[str, float] = {}
    for m in _OP_RE.finditer(hlo_text):
        dt, dims, op = m.groups()
        n = 1
        for dd in dims.split(","):
            if dd:
                n *= int(dd)
        agg[op] = agg.get(op, 0.0) + n * _DTYPE_BYTES.get(dt, 4)
    return agg


def adjusted_bytes(hist: dict[str, float]) -> float:
    """HLO bytes excluding CPU-backend phantom traffic (TPU-realistic)."""
    return sum(v for k, v in hist.items() if k not in _PHANTOM_OPS)


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-op-kind wire bytes (per device) from the optimized HLO.

    Bytes-on-wire model (ring algorithms, k participants -> (k-1)/k ~ 1):
        all-reduce:        2x result bytes (reduce-scatter + all-gather phases)
        all-gather:        result bytes
        reduce-scatter:    operand bytes  (~ result x k; we take result x 1
                           conservatively from result side when operand shape
                           is unavailable on the line -> use result bytes)
        all-to-all:        result bytes
        collective-permute: result bytes
    """
    out = {k: 0.0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in _COLL_OPS:
            token = f" {op}("
            if token not in stripped or stripped.startswith("//"):
                continue
            # result shapes: everything before the op token
            head = stripped.split(token)[0]
            nbytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head))
            mult = 2.0 if op == "all-reduce" else 1.0
            out[op] += mult * nbytes
            counts[op] += 1
            break
    out["total"] = sum(out[k] for k in _COLL_OPS)
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def _shardings(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# probe configs: XLA cost analysis counts while-loop bodies ONCE, so the
# full-depth scanned compile underreports flops/bytes/collectives.  We
# compile depth-1 and depth-2 UNROLLED probes and extrapolate:
#     per_unit = probe2 - probe1 ;  total = probe1 + (units - 1) * per_unit
# which is exact for depth-homogeneous stacks (all 10 archs).  The scanned
# full-depth compile is still what proves feasibility + memory fit.
# ---------------------------------------------------------------------------

import dataclasses


def probe_cfg(cfg, depth: int):
    kwargs: dict = {"scan_layers": False}
    if cfg.family == "hybrid":
        pat = len(cfg.hybrid.pattern)
        rest = cfg.n_layers % pat
        kwargs["n_layers"] = pat * depth + rest
    else:
        kwargs["n_layers"] = depth
        if cfg.n_encoder_layers:
            kwargs["n_encoder_layers"] = depth
    return dataclasses.replace(cfg, **kwargs)


def depth_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // len(cfg.hybrid.pattern)
    return cfg.n_layers


def build_cell(cfg, shape_name: str, mesh, opt_total_steps: int = 10000,
               pin_decode_outs: bool = False):
    """Returns (fn, arg_specs, arg_shardings, donate, out_shardings)."""
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    aparams = model.abstract_params()
    pspecs = param_pspecs(aparams, cfg, mesh)
    specs_in = model.input_specs(shape)

    if shape.kind == "train":
        from repro.train.step import TrainState
        opt_shape = jax.eval_shape(adamw.init, aparams)
        opt_specs = adamw.opt_state_pspecs(opt_shape, pspecs, mesh)
        state_spec = TrainState(params=pspecs, opt=opt_specs, step=P())
        state_shape = TrainState(params=aparams, opt=opt_shape,
                                 step=jax.ShapeDtypeStruct((), jnp.int32))
        batch_shape = specs_in["batch"]
        bspecs = batch_pspecs(batch_shape, mesh)
        step_fn = make_train_step(model, AdamWConfig(total_steps=opt_total_steps))
        args = (state_shape, batch_shape)
        shardings = (_shardings(state_spec, mesh), _shardings(bspecs, mesh))
        return step_fn, args, shardings, (0,), None

    if shape.kind == "prefill":
        tok = specs_in["tokens"]
        tspec = batch_pspecs(tok, mesh)
        if cfg.family == "vlm":
            pe = specs_in["patch_embeds"]
            fn = lambda p, t, x: model.prefill(p, t, patch_embeds=x)
            args = (aparams, tok, pe)
            shardings = (_shardings(pspecs, mesh), _shardings(tspec, mesh),
                         _shardings(batch_pspecs(pe, mesh), mesh))
        elif cfg.family == "audio":
            fr = specs_in["frames"]
            fn = lambda p, t, x: model.prefill(p, t, frames=x)
            args = (aparams, tok, fr)
            shardings = (_shardings(pspecs, mesh), _shardings(tspec, mesh),
                         _shardings(batch_pspecs(fr, mesh), mesh))
        else:
            fn = lambda p, t: model.prefill(p, t)
            args = (aparams, tok)
            shardings = (_shardings(pspecs, mesh), _shardings(tspec, mesh))
        return fn, args, shardings, (), None

    # decode / long_decode
    cache = specs_in["cache"]
    tok = specs_in["tokens"]
    cspecs = cache_pspecs(cache, cfg, mesh)
    tspec = batch_pspecs(tok, mesh)
    out_shardings = None
    if pin_decode_outs:
        # (logits, new_cache): pin the new cache to the input cache layout
        # so XLA cannot round-trip it through another sharding (§Perf)
        logits_spec = jax.ShapeDtypeStruct((1,), jnp.float32)  # placeholder
        out_shardings = (None, _shardings(cspecs, mesh))
    if cfg.family == "audio":
        enc = specs_in["enc_out"]
        fn = lambda p, c, t, e: model.decode_step(p, c, t, enc_out=e)
        args = (aparams, cache, tok, enc)
        shardings = (_shardings(pspecs, mesh), _shardings(cspecs, mesh),
                     _shardings(tspec, mesh),
                     _shardings(batch_pspecs(enc, mesh), mesh))
    else:
        fn = lambda p, c, t: model.decode_step(p, c, t)
        args = (aparams, cache, tok)
        shardings = (_shardings(pspecs, mesh), _shardings(cspecs, mesh),
                     _shardings(tspec, mesh))
    return fn, args, shardings, (1,), out_shardings


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": cell_status(cfg, shape),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if rec["status"] != "run":
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)

    def _compile(use_cfg):
        fn, args, shardings, donate, _outs = build_cell(use_cfg, shape_name, mesh)
        with mesh:
            jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
            lowered = jitted.lower(*args)
            return lowered.compile()

    def _metrics(compiled) -> dict:
        out = {}
        cost = compiled.cost_analysis() or {}
        out["flops"] = float(cost.get("flops", 0.0))
        out["bytes"] = float(cost.get("bytes accessed", 0.0))
        text = compiled.as_text()
        coll = collective_bytes(text)
        out["coll_total"] = coll["total"]
        out["coll"] = {k: v for k, v in coll.items() if k != "counts"}
        out["coll_counts"] = coll["counts"]
        hist = op_bytes_histogram(text)
        out["bytes_adjusted"] = adjusted_bytes(hist)
        out["op_hist_top"] = dict(
            sorted(hist.items(), key=lambda kv: -kv[1])[:12])
        return out

    # 1) full-depth scanned compile: feasibility + memory picture
    compiled = _compile(cfg)
    t_full = time.time() - t0
    mem = compiled.memory_analysis()
    if mem is not None:
        rec["memory"] = {
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
        }
    rec["raw_scanned"] = _metrics(compiled)
    rec["hlo_bytes"] = len(compiled.as_text())
    del compiled

    # 2) depth-1/depth-2 unrolled probes -> exact per-unit extrapolation
    t1 = time.time()
    p1 = _metrics(_compile(probe_cfg(cfg, 1)))
    p2 = _metrics(_compile(probe_cfg(cfg, 2)))
    units = depth_units(cfg)

    def extrap(a, b):
        return a + (units - 1) * max(b - a, 0.0)

    rec["flops_per_device"] = extrap(p1["flops"], p2["flops"])
    rec["bytes_per_device"] = extrap(p1["bytes"], p2["bytes"])
    rec["bytes_adjusted_per_device"] = extrap(p1["bytes_adjusted"], p2["bytes_adjusted"])
    rec["collective_bytes_per_device"] = extrap(p1["coll_total"], p2["coll_total"])
    rec["collectives"] = {
        k: extrap(p1["coll"][k], p2["coll"][k])
        for k in p1["coll"] if k != "total"
    }
    rec["coll_counts_probe2"] = p2["coll_counts"]
    rec["probe"] = {"p1": p1, "p2": p2, "units": units}
    rec["t_compile_full_s"] = round(t_full, 2)
    rec["t_probes_s"] = round(time.time() - t1, 2)

    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
              f"flops/dev={rec['flops_per_device']:.3e} "
              f"bytes/dev={rec['bytes_per_device']:.3e} "
              f"coll/dev={rec['collective_bytes_per_device']:.3e}B "
              f"(full {t_full:.1f}s probes {rec['t_probes_s']:.1f}s)", flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    done = set()
    out_path = Path(args.out) if args.out else None
    if out_path and out_path.exists():
        for line in out_path.read_text().splitlines():
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass

    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            key = (arch.replace("-", "_"), shape_name, mesh_name)
            if key in done:
                continue
            try:
                rec = run_cell(arch, shape_name, multi_pod=mp)
            except Exception as e:  # a failing cell is a bug — record it loudly
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "status": f"FAIL: {type(e).__name__}: {e}"}
                failures += 1
                print(f"[dryrun] FAIL {arch} x {shape_name} x {mesh_name}: {e}",
                      file=sys.stderr, flush=True)
            if out_path:
                with open(out_path, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
