"""Offline inspector for observability artifacts — trace files, cache
directories, metrics snapshots.

A stitched run leaves three artifacts behind (`--trace-out`,
``--cache-dir``, ``--metrics-json`` on the train/serve drivers); this CLI
reads them back without re-running anything:

    # compile timeline + modeled-vs-measured table from a trace file
    PYTHONPATH=src python -m repro.launch.inspect trace.json

    # persisted fusion-plan records in a StitchCache directory
    PYTHONPATH=src python -m repro.launch.inspect --cache-dir /tmp/stitch

    # a metrics-registry snapshot
    PYTHONPATH=src python -m repro.launch.inspect --metrics metrics.json

The trace view answers the two questions an upgrade-latency investigation
always starts with: *when did each stitch compile land relative to the
serving steps* (the compile timeline, with cache hit/miss and
fallback→stitched upgrade markers inline), and *did the measured kernel
time agree with the cost model* (the per-plan modeled-vs-measured table,
built from the ``exec.measured`` events the opt-in timer records).
"""

from __future__ import annotations

import argparse
import json
from collections import defaultdict
from pathlib import Path


def _fmt_ms(us: float) -> str:
    return f"{us / 1e3:10.3f}"


def _load_events(path: str) -> list[dict]:
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    return [e for e in events if e.get("ph") != "M"]


# -- trace views ---------------------------------------------------------------
COMPILE_NAMES = ("compile.graph", "compile.background", "compile.pattern_gen",
                 "compile.ilp", "compile.tune", "compile.start",
                 "compile.land", "compile.fail", "cache.hit", "cache.miss",
                 "exec.upgrade", "exec.trace")


def compile_timeline(events: list[dict]) -> list[str]:
    """Chronological compile/cache/upgrade activity, one line per event."""
    rows = [e for e in events if e.get("name") in COMPILE_NAMES]
    rows.sort(key=lambda e: e.get("ts", 0.0))
    lines = [f"{'t_ms':>10}  {'dur_ms':>10}  {'event':24}  detail",
             "-" * 78]
    for e in rows:
        args = e.get("args", {})
        detail = " ".join(
            f"{k}={args[k]}" for k in
            ("graph", "fn", "placement", "cache", "bucket", "n_kernels",
             "modeled_time_s", "status", "method", "error")
            if k in args and args[k] not in ("", None))
        dur = _fmt_ms(e["dur"]) if e.get("ph") == "X" else " " * 10
        lines.append(f"{_fmt_ms(e.get('ts', 0.0))}  {dur}  "
                     f"{e['name']:24}  {detail}")
    if len(lines) == 2:
        lines.append("(no compile/cache events in this trace — was the run "
                     "traced with --stitch?)")
    return lines


def measured_table(events: list[dict]) -> list[str]:
    """Per-(fn, path, placement) modeled-vs-measured from ``exec.measured``
    events; ratio > 1 means the kernel ran slower than the cost model
    promised."""
    groups: dict[tuple, list[dict]] = defaultdict(list)
    for e in events:
        if e.get("name") == "exec.measured":
            a = e.get("args", {})
            groups[(a.get("fn", "?"), a.get("path", "?"),
                    a.get("placement") or "")].append(a)
    if not groups:
        return ["(no exec.measured events — run with the kernel timer "
                "enabled, e.g. --trace-out on the serve/train drivers)"]
    lines = [f"{'fn':16} {'path':10} {'calls':>6} {'measured_ms':>12} "
             f"{'modeled_ms':>11} {'ratio':>7}  placement",
             "-" * 78]
    for (fn, path, placement), rows in sorted(groups.items()):
        meas = [float(r["measured_s"]) for r in rows if "measured_s" in r]
        mods = [float(r["modeled_s"]) for r in rows
                if r.get("modeled_s") is not None]
        mean_meas = sum(meas) / len(meas) if meas else 0.0
        mean_mod = sum(mods) / len(mods) if mods else None
        ratio = (f"{mean_meas / mean_mod:7.2f}"
                 if mean_mod else "      -")
        mod_str = f"{mean_mod * 1e3:11.4f}" if mean_mod else "          -"
        lines.append(f"{fn:16} {path:10} {len(rows):>6} "
                     f"{mean_meas * 1e3:12.4f} {mod_str} {ratio}  "
                     f"{placement}")
    return lines


def serve_timeline(events: list[dict]) -> list[str]:
    """Prefill activity against page-pool occupancy: every ``serve.prefill``
    span/hit-marker interleaved with the ``serve.pages`` counter samples the
    scheduler emits per step, plus an inline occupancy bar.  Answers "was
    that admission a prefix hit, and what did it do to the pool?" without
    loading Perfetto."""
    rows = [e for e in events
            if e.get("name") in ("serve.prefill", "serve.pages")]
    rows.sort(key=lambda e: e.get("ts", 0.0))
    if not rows:
        return ["(no serve.prefill/serve.pages events — paged continuous "
                "serving emits them when traced)"]
    cap = max((e["args"].get("used", 0) + e["args"].get("free", 0)
               for e in rows if e.get("name") == "serve.pages"),
              default=0)
    lines = [f"{'t_ms':>10}  {'dur_ms':>10}  {'event':14}  detail",
             "-" * 78]
    for e in rows:
        args = e.get("args", {})
        if e["name"] == "serve.pages":
            used = args.get("used", 0)
            bar = "#" * round(12 * used / cap) if cap else ""
            detail = (f"used={used}/{cap} {bar:<12}")
        else:
            detail = " ".join(
                f"{k}={args[k]}" for k in
                ("rid", "prompt_len", "bucket", "batch", "cached")
                if k in args)
        dur = _fmt_ms(e["dur"]) if e.get("ph") == "X" else " " * 10
        lines.append(f"{_fmt_ms(e.get('ts', 0.0))}  {dur}  "
                     f"{e['name']:14}  {detail}")
    hits = sum(1 for e in rows if e["name"] == "serve.prefill"
               and e.get("args", {}).get("cached"))
    total = sum(1 for e in rows if e["name"] == "serve.prefill")
    lines.append(f"{total} prefill(s), {hits} prefix-cache hit(s)")
    return lines


def trace_summary(events: list[dict]) -> list[str]:
    counts: dict[str, int] = defaultdict(int)
    for e in events:
        counts[e.get("name", "?")] += 1
    return [f"{n:28} {c:>6}" for n, c in sorted(counts.items())]


# -- cache-dir view ------------------------------------------------------------
def cache_table(directory: str) -> list[str]:
    files = sorted(Path(directory).glob("plan_*.json"))
    if not files:
        return [f"(no plan_*.json records under {directory})"]
    lines = [f"{'graph':12} {'bucket':12} {'mode':6} {'hw':8} {'nodes':>5} "
             f"{'groups':>6} {'solve_s':>8}  placement",
             "-" * 78]
    for p in files:
        try:
            with open(p) as f:
                d = json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            lines.append(f"{p.name}: unreadable ({e})")
            continue
        lines.append(
            f"{d.get('graph_key', '?')[:12]:12} "
            f"{d.get('bucket_key', '?')[:12]:12} "
            f"{d.get('mode', '?'):6} {d.get('hw', '?'):8} "
            f"{d.get('n_nodes', 0):>5} {len(d.get('groups', ())):>6} "
            f"{d.get('solve_seconds', 0.0):>8.3f}  "
            f"{d.get('placement', '')}")
    lines.append(f"{len(files)} record(s)")
    return lines


# -- metrics view --------------------------------------------------------------
def metrics_view(path: str) -> list[str]:
    with open(path) as f:
        snap = json.load(f)
    lines: list[str] = []
    for section in ("counters", "gauges"):
        for name, v in sorted(snap.get(section, {}).items()):
            lines.append(f"{name:48} {v:g}")
    for name, s in sorted(snap.get("histograms", {}).items()):
        lines.append(f"{name:48} count={s.get('count', 0):g} "
                     f"mean={s.get('mean', 0.0):g} p50={s.get('p50', 0.0):g} "
                     f"p99={s.get('p99', 0.0):g}")
    for name in sorted(snap.get("providers", {})):
        lines.append(f"provider: {name}")
    return lines or ["(empty snapshot)"]


def main(argv=None):
    import sys
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "verify":
        # `inspect verify ...` delegates to the static verifier CLI:
        # python -m repro.analysis --cache-dir DIR / --configs [...]
        from repro.analysis.__main__ import main as verify_main
        raise SystemExit(verify_main(argv[1:]))
    ap = argparse.ArgumentParser(
        description="inspect stitching observability artifacts offline")
    ap.add_argument("trace", nargs="?", default=None,
                    help="Chrome-trace JSON written by --trace-out "
                         "(or the literal 'verify' to run the "
                         "repro.analysis static verifier)")
    ap.add_argument("--cache-dir", default=None,
                    help="StitchCache directory: print the persisted "
                         "fusion-plan records")
    ap.add_argument("--metrics", default=None,
                    help="metrics snapshot written by --metrics-json")
    args = ap.parse_args(argv)
    if not (args.trace or args.cache_dir or args.metrics):
        ap.error("nothing to inspect: give a trace file, --cache-dir, "
                 "or --metrics")

    out: list[str] = []
    if args.trace:
        events = _load_events(args.trace)
        out += [f"== trace: {args.trace} ({len(events)} events) ==", ""]
        out += ["-- event counts --"] + trace_summary(events) + [""]
        out += ["-- compile timeline --"] + compile_timeline(events) + [""]
        out += ["-- serve timeline --"] + serve_timeline(events) + [""]
        out += ["-- modeled vs measured --"] + measured_table(events) + [""]
    if args.cache_dir:
        out += [f"== cache: {args.cache_dir} ==", ""]
        out += cache_table(args.cache_dir) + [""]
    if args.metrics:
        out += [f"== metrics: {args.metrics} ==", ""]
        out += metrics_view(args.metrics) + [""]
    print("\n".join(out))


if __name__ == "__main__":
    main()
