# NOTE: intentionally does not import submodules — dryrun must set XLA_FLAGS
# before jax initializes, so it is always imported/executed directly.
