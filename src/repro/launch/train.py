"""Training driver — real execution on whatever devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this container that is CPU execution of the reduced config (the e2e
example trains a ~100M-param model); on a TPU slice the same driver runs
the full config over :func:`make_production_mesh` — everything between the
CLI and the hardware is mesh-shape agnostic.

``--stitch [--cache-dir DIR]`` routes the step through the FusionStitching
pipeline (:class:`repro.train.StitchedTrainStep`): the backward pass traces
to StitchIR, the AdamW+clip update runs as one packed multi-tensor kernel,
and each step polls the cache so the run upgrades from the instant XLA
fallback to stitched plans as background compiles land.

``--stitch`` composes with ``--model-parallel`` (and any multi-device
host): the stitched step dispatches through ``shard_map`` on per-shard
graphs — batch rows split across the mesh for the backward pass, params
updated TP-shard-locally by the packed kernel — with mesh-keyed cache
entries.  ``--host-devices N`` forces N host-platform devices for CI /
laptop rehearsal (the same ``--xla_force_host_platform_device_count``
mechanism as :mod:`repro.launch.dryrun`).
"""

from __future__ import annotations

import sys

# --host-devices must take effect before the first jax import (jax locks
# the device count at first init); argparse proper still declares the flag
from repro.launch.hostenv import force_host_devices

force_host_devices(argv=sys.argv)

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_reduced
from repro.data import DataConfig, SyntheticTokens
from repro.ft import StepFailure, TrainSupervisor
from repro.launch.mesh import make_host_mesh
from repro.models import batch_pspecs, build_model, param_pspecs
from repro.optim import AdamWConfig, adamw
from repro.train import TrainState, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. to hit ~100M params)")
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--stitch", action="store_true",
                    help="run the step through the FusionStitching pipeline "
                         "(backward pass traced to StitchIR + packed AdamW "
                         "kernel), upgrading from the XLA fallback as "
                         "background compiles land")
    ap.add_argument("--cache-dir", default=None,
                    help="StitchCache directory (fusion plans persist and "
                         "replay across runs)")
    ap.add_argument("--plan-budget", type=float, default=None,
                    help="wall-clock seconds the fusion-plan ILP may spend "
                         "per graph before degrading to the greedy heuristic "
                         "(anytime solve; a huge backward graph can never "
                         "hang a background upgrade thread)")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N host-platform devices (must be first-"
                         "parsed before jax init; see module docstring)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(enables span tracing AND the measured kernel "
                         "timer; load the file at https://ui.perfetto.dev)")
    ap.add_argument("--metrics-json", default=None,
                    help="write the obs metrics-registry snapshot (plus the "
                         "stitched step report) as JSON at exit")
    args = ap.parse_args()

    from repro import obs
    if args.trace_out:
        obs.enable_tracing()
        obs.enable_timing()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    over = {}
    if args.d_model:
        over["d_model"] = args.d_model
    if args.n_layers:
        over["n_layers"] = args.n_layers
    if over:
        cfg = dataclasses.replace(cfg, **over)
    model = build_model(cfg)
    mesh = make_host_mesh(args.model_parallel)
    print(f"arch={cfg.name} params~{cfg.param_count():,} mesh={dict(mesh.shape)}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps)
    state = init_state(model, jax.random.PRNGKey(0))

    aparams = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                           state.params)
    pspecs = param_pspecs(aparams, cfg, mesh)

    stitched = None
    if args.stitch:
        # stitched training: the backward pass and packed AdamW+clip update
        # execute through compiled StitchIR artifacts, polling the cache each
        # step so the run upgrades from the XLA fallback mid-flight.  On a
        # multi-device mesh both phases dispatch through shard_map on
        # per-shard graphs (mesh-keyed cache entries).
        from repro.cache import CompilationService, StitchCache
        from repro.train import StitchedTrainStep
        svc = CompilationService(cache=StitchCache(args.cache_dir),
                                 plan_budget=args.plan_budget)
        stitched = StitchedTrainStep(model, opt_cfg,
                                     microbatches=args.microbatches,
                                     service=svc, mesh=mesh,
                                     param_specs=pspecs)

    if stitched is not None and stitched.mesh is not None:
        # packed panels update shard-local param/moment slices, so m/v must
        # stay co-located with params (no ZeRO offset on the stitched path)
        state_sh = stitched.state_shardings()
    else:
        opt_specs = adamw.opt_state_pspecs(state.opt, pspecs, mesh)
        state_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            TrainState(params=pspecs, opt=opt_specs, step=P()),
            is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, state_sh)

    data = SyntheticTokens(DataConfig(cfg.vocab, args.seq, args.batch))
    bspecs = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          batch_pspecs(data.batch(0), mesh),
                          is_leaf=lambda x: isinstance(x, P))
    if stitched is not None:
        step_fn = stitched
    else:
        step_raw = make_train_step(model, opt_cfg, microbatches=args.microbatches)
        step_fn = jax.jit(step_raw, in_shardings=(state_sh, bspecs),
                          donate_argnums=(0,))

    def data_fn(step: int):
        return jax.device_put(data.batch(step), bspecs)

    sup = TrainSupervisor(step_fn, data_fn, args.ckpt_dir,
                          ckpt_every=args.ckpt_every)
    t0 = time.time()
    last = [t0]

    orig_step = sup.train_step

    def logged(state, batch):
        out_state, metrics = orig_step(state, batch)
        s = int(jax.device_get(out_state.step))
        if s % args.log_every == 0 or s == 1:
            now = time.time()
            print(f"step {s:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"{(now - last[0]) / args.log_every:.3f}s/step", flush=True)
            last[0] = now
        return out_state, metrics

    sup.train_step = logged
    state = sup.run(state, args.steps)
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s; "
          f"final loss {sup.metrics_log[-1]['loss']:.4f}")
    if stitched is not None:
        stitched.wait(timeout=60.0)
        rep = stitched.report()
        grad_plan = rep["grad"].get("plan") or {}
        opt_plan = rep["optimizer"].get("plan") or {}
        mesh_note = (f" mesh={rep['mesh']} (shard_map per-shard graphs)"
                     if "mesh" in rep else "")
        print(f"stitch: grad {rep['grad']['status']} "
              f"({grad_plan.get('n_ops', '?')} ops -> "
              f"{grad_plan.get('n_kernels', '?')} kernels), "
              f"optimizer {rep['optimizer']['status']} "
              f"({opt_plan.get('n_ops', '?')} ops -> "
              f"{opt_plan.get('n_kernels', '?')} packed kernel(s)), "
              f"fallback_steps={rep['fallback_steps']}{mesh_note}")
        placements = rep.get("cache", {}).get("per_placement")
        if placements:
            print(f"stitch cache per-placement: {placements}")
    if args.trace_out:
        print(f"trace: {obs.save_trace(args.trace_out)} "
              f"({len(obs.tracer)} events)")
    if args.metrics_json:
        reg = obs.registry()
        if stitched is not None:
            reg.register_provider("train", stitched.report)
        reg.to_json(args.metrics_json)
        print(f"metrics: {args.metrics_json}")


if __name__ == "__main__":
    main()
