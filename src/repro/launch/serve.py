"""Serving driver: static batch or continuous batching under open-loop load.

Continuous mode (default for attention families) drives the slot scheduler
with a Poisson arrival process — requests arrive on their own clock whether
or not the server keeps up (open loop), prompt lengths and token budgets are
ragged, and the report shows throughput plus latency percentiles:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --slots 4 --requests 32 --rate 20 --prompt-len 24 --new-tokens 16

Static mode replays the legacy fixed-batch lock-step loop:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --mode static --slots 4 --prompt-len 32 --new-tokens 16

``--stitch`` builds a :class:`repro.cache.CompilationService` (persistent
when ``--cache-dir`` is given), serves through the stitched decode artifact
(miss-then-upgrade: the XLA fallback answers instantly while the stitch
pipeline compiles in the background), and prints ``Engine.stitch_report()``
at exit.

``--model-parallel`` (with real devices or ``--host-devices N``) builds the
host mesh and turns on the engine's DP-replica dispatch: the scheduler's
batched decode step spreads its slots across the data-parallel replicas via
``shard_map``, and with ``--stitch`` the decode graph is traced and solved
at shard-local shapes under a mesh-keyed cache entry.
"""

from __future__ import annotations

import sys

# --host-devices must take effect before the first jax import (jax locks
# the device count at first init); argparse proper still declares the flag
from repro.launch.hostenv import force_host_devices

force_host_devices(argv=sys.argv)

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig
from repro.serve.scheduler import RAGGED_FAMILIES


def build_engine(args, cfg, model, params):
    svc = None
    if args.stitch:
        from repro.cache import CompilationService, StitchCache
        svc = CompilationService(StitchCache(directory=args.cache_dir),
                                 plan_budget=args.plan_budget)
    # DP-replica dispatch is opt-in (--mesh, implied by --model-parallel>1):
    # a multi-device host with the default slot count must not change
    # behavior or hit the slots-divisibility check uninvited
    mesh = None
    if args.mesh or args.model_parallel != 1:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(args.model_parallel)
    eng = Engine(model, params, ServeConfig(
        batch=args.slots, max_len=args.max_len,
        max_new_tokens=args.new_tokens, eos_id=args.eos,
        stitch_execute=args.stitch,
        paged=False if args.dense else None,
        page_size=args.page_size, num_pages=args.num_pages,
        prefix_cache=args.prefix_cache), stitch_service=svc, mesh=mesh)
    if mesh is not None:
        print(f"mesh={dict(mesh.shape)} dp_replicas={eng.dp_replicas}")
    print(f"kv_layout={'paged' if eng.paged else 'dense'}"
          + (f" page_size={args.page_size}" if eng.paged else "")
          + (" prefix_cache=on" if args.prefix_cache else ""))
    return eng


def make_workload(args, cfg):
    """Ragged prompts + Poisson arrival offsets (open loop).  With
    ``--prefix-pool N`` the prompts are drawn from N distinct templates
    (shared system prompts), making the trace prefix-heavy: every repeat
    of a template is a whole-prompt prefix-cache hit."""
    rng = np.random.default_rng(args.seed)
    lo = max(1, args.prompt_len // 2)
    hi = max(lo + 1, args.prompt_len)
    news = rng.integers(max(1, args.new_tokens // 4), args.new_tokens + 1,
                        args.requests)
    if args.prefix_pool > 0:
        pool_lens = rng.integers(lo, hi + 1, args.prefix_pool)
        pool = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
                for p in pool_lens]
        prompts = [pool[i] for i in rng.integers(0, len(pool), args.requests)]
    else:
        lens = rng.integers(lo, hi + 1, args.requests)
        prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
                   for p in lens]
    if args.rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    else:
        arrivals = np.zeros(args.requests)
    return prompts, news, arrivals


def run_continuous(args, eng, prompts, news, arrivals):
    t0 = time.monotonic()
    pending = list(zip(prompts, news, arrivals))
    i = 0
    while i < len(pending) or eng.scheduler.queue or eng.scheduler.n_active:
        now = time.monotonic() - t0
        while i < len(pending) and pending[i][2] <= now:
            p, n, at = pending[i]
            eng.submit(p, max_new_tokens=int(n), arrival_time=t0 + at)
            i += 1
        if eng.scheduler.queue or eng.scheduler.n_active:
            eng.step()
        elif i < len(pending):
            time.sleep(max(0.0, pending[i][2] - (time.monotonic() - t0)))
    report = eng.serve_report()
    # tokens_per_sec above is busy-time capacity (decode seconds only);
    # wall-clock includes idle gaps between Poisson arrivals and is the
    # number comparable to static mode's report
    wall = time.monotonic() - t0
    report["wall_elapsed_s"] = wall
    report["wall_tokens_per_sec"] = report["total_tokens"] / max(wall, 1e-9)
    return report


def run_static(args, eng, prompts, news):
    """Legacy lock-step: pad every group of ``slots`` requests into one
    rectangle, decode to the group's worst-case budget."""
    total_tokens = 0
    t0 = time.monotonic()
    for g in range(0, len(prompts), args.slots):
        group = prompts[g:g + args.slots]
        while len(group) < args.slots:           # ride-along padding rows
            group = group + [group[-1]]
        lens = [len(p) for p in group]
        rect = np.zeros((args.slots, max(lens)), np.int32)
        for r, p in enumerate(group):
            rect[r, :len(p)] = p
        eng.cfg.max_new_tokens = int(max(news[g:g + args.slots]))
        toks = eng.generate(rect, prompt_lens=lens)
        total_tokens += int(sum(min(n, toks.shape[1])
                                for n in news[g:g + args.slots]))
    dt = time.monotonic() - t0
    return {"requests_finished": len(prompts), "total_tokens": total_tokens,
            "elapsed_s": dt, "tokens_per_sec": total_tokens / max(dt, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=["continuous", "static"], default=None,
                    help="default: continuous for attention families")
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=None,
                    help="KV capacity (default prompt-len + new-tokens)")
    ap.add_argument("--requests", type=int, default=16,
                    help="continuous mode: number of open-loop requests")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate req/s (0 = all at t=0)")
    ap.add_argument("--eos", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stitch", action="store_true",
                    help="serve decode AND bucketed prefills through the "
                         "stitched artifact (miss-then-upgrade)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged-KV block size in tokens (paged is the "
                         "default layout off-mesh)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="KV page-pool size (default: worst case, "
                         "slots*ceil(max_len/page_size)+1)")
    ap.add_argument("--dense", action="store_true",
                    help="force the legacy dense per-slot KV rectangles")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-hashed prompt-KV reuse (paged only): "
                         "repeated prompts skip prefill via a page-table "
                         "splice")
    ap.add_argument("--prefix-pool", type=int, default=0,
                    help="draw prompts from N distinct templates (prefix-"
                         "heavy trace; 0 = all prompts unique)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent StitchCache directory (with --stitch)")
    ap.add_argument("--plan-budget", type=float, default=None,
                    help="wall-clock seconds the fusion-plan ILP may spend "
                         "per graph before degrading to the greedy heuristic "
                         "(anytime solve; keeps background upgrades bounded)")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="model-axis size of the host mesh (must divide the "
                         "device count); >1 implies --mesh")
    ap.add_argument("--mesh", action="store_true",
                    help="enable the DP-replica decode dispatch over the "
                         "host mesh (slots must divide the DP size)")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N host-platform devices (see module "
                         "docstring)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(enables span tracing AND the measured kernel "
                         "timer; load the file at https://ui.perfetto.dev)")
    ap.add_argument("--metrics-json", default=None,
                    help="write the obs metrics-registry snapshot (counters/"
                         "histograms + serve/stitch reports) as JSON at exit")
    args = ap.parse_args()
    if args.max_len is None:
        args.max_len = args.prompt_len + args.new_tokens

    from repro import obs
    if args.trace_out:
        obs.enable_tracing()
        obs.enable_timing()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.mode is None:
        args.mode = "continuous" if cfg.family in RAGGED_FAMILIES else "static"

    if cfg.family == "audio":
        _serve_audio(args, cfg, model, params)
        return

    eng = build_engine(args, cfg, model, params)
    prompts, news, arrivals = make_workload(args, cfg)
    if args.mode == "continuous":
        report = run_continuous(args, eng, prompts, news, arrivals)
    else:
        report = run_static(args, eng, prompts, news)
    print(f"arch={cfg.name} mode={args.mode} slots={args.slots}")
    print(json.dumps(report, indent=2, default=float))
    if args.prefix_cache and eng.prefix_cache is not None:
        print("prefix_cache:")
        print(json.dumps(eng.prefix_cache.report(), indent=2, default=float))
    if args.stitch:
        print("stitch_report:")
        print(json.dumps(eng.stitch_report(), indent=2, default=str))
    if args.trace_out:
        print(f"trace: {obs.save_trace(args.trace_out)} "
              f"({len(obs.tracer)} events)")
    if args.metrics_json:
        reg = obs.registry()
        reg.register_provider("serve", eng.serve_report)
        reg.register_provider("stitch", eng.stitch_report)
        reg.register_provider("engine", eng.report)
        reg.to_json(args.metrics_json, report=report)
        print(f"metrics: {args.metrics_json}")


def _serve_audio(args, cfg, model, params):
    """Enc-dec (audio) family: cross-attn decode outside the engine."""
    import jax.numpy as jnp
    from repro.models import encdec
    rng = np.random.default_rng(args.seed)
    B, P = args.slots, args.prompt_len
    prompts = rng.integers(0, cfg.vocab, (B, P)).astype(np.int32)
    frames = np.asarray(rng.standard_normal((B, P, cfg.d_model)), np.float32)
    t0 = time.time()
    enc_out = encdec.encode(params, jnp.asarray(frames), cfg)
    logits, _ = model.prefill(params, jnp.asarray(prompts),
                              frames=jnp.asarray(frames))
    cache = model.init_cache(B, P + args.new_tokens)
    out_toks = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    dec = jax.jit(lambda p, c, t, e: model.decode_step(p, c, t, enc_out=e))
    for _ in range(args.new_tokens):
        out_toks.append(np.asarray(tok))
        logits, cache = dec(params, cache, tok, enc_out)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    toks = np.concatenate(out_toks, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name}: generated {toks.shape} in {dt:.2f}s "
          f"({B * args.new_tokens / dt:.1f} tok/s)")
    print(toks[:, :12])


if __name__ == "__main__":
    main()
