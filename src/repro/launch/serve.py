"""Serving driver: prefill a batch of synthetic prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(
        batch=args.batch, max_len=args.prompt_len + args.new_tokens,
        max_new_tokens=args.new_tokens))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = np.asarray(
            rng.standard_normal((args.batch, args.prompt_len, cfg.d_model)),
            dtype=np.float32)
        extra_decode = {"enc_out": None}
    t0 = time.time()
    if cfg.family == "audio":
        # encoder output doubles as the decode-time cross-attn input
        import jax.numpy as jnp
        from repro.models import encdec
        enc_out = encdec.encode(params, jnp.asarray(extra["frames"]), cfg)
        logits, _ = model.prefill(params, jnp.asarray(prompts), frames=jnp.asarray(extra["frames"]))
        cache = model.init_cache(args.batch, args.prompt_len + args.new_tokens)
        out_toks = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        dec = jax.jit(lambda p, c, t, e: model.decode_step(p, c, t, enc_out=e))
        for _ in range(args.new_tokens):
            out_toks.append(np.asarray(tok))
            logits, cache = dec(params, cache, tok, enc_out)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        toks = np.concatenate(out_toks, axis=1)
    else:
        toks = eng.generate(prompts)
    dt = time.time() - t0
    print(f"arch={cfg.name}: generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print(toks[:, :12])


if __name__ == "__main__":
    main()
