"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

Lowers one (arch x shape x mesh) cell with optimization-variant overrides
and prints the roofline metric deltas vs the recorded baseline, plus a
top-collectives dump (the dry-run 'profile').

    python -m repro.launch.perf --arch qwen2-moe-a2.7b --shape train_4k \
        --set moe_groups=16 shard_activations=1 \
        --baseline dryrun_results.jsonl

Variants (--set key=value, repeatable):
    moe_groups=N          grouped MoE dispatch (0=auto, 1=global baseline)
    shard_activations=1   pin activation token-dim to DP at layer boundaries
    zero1_skip=1          ZeRO-1 skips the layer-stack dim of stacked leaves
    remat=dots|none|full  activation-checkpoint policy
    param_dtype=bfloat16  parameter storage dtype
    capacity=F            MoE capacity factor
"""

import os

# must be set before jax import; respect an operator-provided device count
# but keep any unrelated pre-existing flags (e.g. --xla_dump_to) intact
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json
import re
import sys
import time

import jax

from repro.configs import get_config
from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.models import SHAPES


def apply_variants(cfg, variants: dict):
    over = {}
    moe_over = {}
    if "moe_groups" in variants and cfg.moe:
        moe_over["n_groups"] = int(variants["moe_groups"])
    if "capacity" in variants and cfg.moe:
        moe_over["capacity_factor"] = float(variants["capacity"])
    if moe_over:
        over["moe"] = dataclasses.replace(cfg.moe, **moe_over)
    if variants.get("shard_activations"):
        over["shard_activations"] = bool(int(variants["shard_activations"]))
    if "remat" in variants:
        over["remat"] = variants["remat"]
    if "param_dtype" in variants:
        over["param_dtype"] = variants["param_dtype"]
    if "loss_groups" in variants:
        over["loss_groups"] = int(variants["loss_groups"])
    return dataclasses.replace(cfg, **over) if over else cfg


def top_collectives(hlo: str, n: int = 8):
    """Largest individual collective ops in the optimized HLO."""
    rows = []
    for line in hlo.splitlines():
        s = line.strip()
        for op in dr._COLL_OPS:
            if f" {op}(" in s:
                head = s.split(f" {op}(")[0]
                b = sum(dr._shape_bytes(d, dims)
                        for d, dims in dr._SHAPE_RE.findall(head))
                rows.append((b, op, head[:90]))
                break
    rows.sort(reverse=True)
    return rows[:n]


def run(arch, shape_name, multi_pod, variants, zero1_skip=False, dump=False):
    cfg = apply_variants(get_config(arch), variants)
    mesh = make_production_mesh(multi_pod=multi_pod)

    if zero1_skip:
        from repro.optim import adamw
        orig = adamw.opt_state_pspecs
        adamw.opt_state_pspecs = (
            lambda s, p, m, skip_leading=True: orig(s, p, m, skip_leading=True))

    pin = bool(int(variants.get("pin_decode_outs", 0)))
    if variants.get("kv_shard"):
        from repro.models import sharding as _sh
        _sh.CACHE_KV_DIM = variants["kv_shard"]

    def _compile(use_cfg):
        fn, args, shardings, donate, outs = dr.build_cell(
            use_cfg, shape_name, mesh, pin_decode_outs=pin)
        with mesh:
            kw = {}
            if outs is not None:
                kw["out_shardings"] = outs
            return jax.jit(fn, in_shardings=shardings, donate_argnums=donate,
                           **kw).lower(*args).compile()

    t0 = time.time()
    p1c = _compile(dr.probe_cfg(cfg, 1))
    p2c = _compile(dr.probe_cfg(cfg, 2))

    def _metrics(compiled):
        cost = compiled.cost_analysis() or {}
        text = compiled.as_text()
        hist = dr.op_bytes_histogram(text)
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_adjusted": dr.adjusted_bytes(hist),
            "coll": dr.collective_bytes(text),
            "text": text,
        }

    p1, p2 = _metrics(p1c), _metrics(p2c)
    units = dr.depth_units(cfg)

    def extrap(a, b):
        return a + (units - 1) * max(b - a, 0.0)

    out = {
        "flops": extrap(p1["flops"], p2["flops"]),
        "bytes": extrap(p1["bytes_adjusted"], p2["bytes_adjusted"]),
        "coll": extrap(p1["coll"]["total"], p2["coll"]["total"]),
        "coll_kinds": {k: extrap(p1["coll"][k], p2["coll"][k])
                       for k in dr._COLL_OPS},
        "t": time.time() - t0,
    }
    if dump:
        out["top"] = top_collectives(p2["text"])
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[], dest="sets")
    ap.add_argument("--zero1-skip", action="store_true")
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--dump-collectives", action="store_true")
    args = ap.parse_args()

    variants = dict(kv.split("=", 1) for kv in args.sets)
    res = run(args.arch, args.shape, args.multi_pod, variants,
              zero1_skip=args.zero1_skip, dump=args.dump_collectives)

    terms = {
        "compute_s": res["flops"] / PEAK_FLOPS,
        "memory_s": res["bytes"] / HBM_BW,
        "collective_s": res["coll"] / LINK_BW,
    }
    print(f"\n== {args.arch} x {args.shape} x "
          f"{'2x16x16' if args.multi_pod else '16x16'} "
          f"variants={variants or 'NONE'} zero1_skip={args.zero1_skip}")
    print(f" flops/dev={res['flops']:.3e}  bytes/dev={res['bytes']:.3e}  "
          f"coll/dev={res['coll']:.3e}")
    print(f" terms: compute={terms['compute_s']:.3f}s "
          f"memory={terms['memory_s']:.3f}s collective={terms['collective_s']:.3f}s "
          f"(compile {res['t']:.0f}s)")
    print(" coll kinds:", {k: f"{v:.2e}" for k, v in res["coll_kinds"].items()})

    if args.baseline:
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
        arch_key = args.arch.lower().replace("-", "_").replace(".", "_")
        for line in open(args.baseline):
            r = json.loads(line)
            if (r["arch"], r["shape"], r.get("mesh")) == (arch_key, args.shape, mesh_name):
                b_c = r["flops_per_device"] / PEAK_FLOPS
                b_m = r["bytes_adjusted_per_device"] / HBM_BW
                b_l = r["collective_bytes_per_device"] / LINK_BW
                print(f" baseline: compute={b_c:.3f}s memory={b_m:.3f}s "
                      f"collective={b_l:.3f}s")
                print(f" delta:    compute x{terms['compute_s']/max(b_c,1e-12):.2f} "
                      f"memory x{terms['memory_s']/max(b_m,1e-12):.2f} "
                      f"collective x{terms['collective_s']/max(b_l,1e-12):.2f}")
                break
    if args.dump_collectives:
        print(" top collectives (probe2):")
        for b, op, head in res["top"]:
            print(f"   {b:14,d}B  {op:18s} {head}")


if __name__ == "__main__":
    main()
