"""Roofline analysis over dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:

    compute term    = FLOPs_dev / peak_FLOPs_chip
    memory term     = bytes_dev / HBM_bw          (raw HLO bytes; an
                      'adjusted' column excludes CPU-lowering phantom ops)
    collective term = coll_bytes_dev / link_bw

(the dry-run HLO is the per-device SPMD program, so per-device quantities
divided by per-chip peaks equal the brief's global/(chips x peak) formula),
plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs_global.

For SSM/hybrid cells the sequence recurrence runs as a lax.scan whose
per-trip state round-trip the probe extrapolation cannot see (trip count =
seq len, not depth); :func:`scan_state_traffic` adds that analytic term —
and its Pallas-kernel counterpart (state in VREG, no HBM round-trip) is the
quantified win reported in §Perf.

Usage:
    python -m repro.launch.roofline --in dryrun_results.jsonl --md out.md
"""

from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

# TPU v5e deployment target
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def chips(mesh: str) -> int:
    n = 1
    for d in mesh.split("x"):
        n *= int(d)
    return n


def model_flops(rec: dict, cfg=None) -> float:
    """6·N·D training / 2·N·D inference FLOPs over the *global* token count."""
    from repro.configs import get_config
    from repro.models import SHAPES
    cfg = cfg or get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0 * (2 if cfg.family == "audio" else 1)  # enc+dec both run
        return mult * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def scan_state_traffic(rec: dict) -> float:
    """Analytic HBM bytes/device of the recurrence state round-trip that the
    XLA lax.scan path incurs (read+write carry per timestep) — invisible to
    the depth probes.  Returns 0 for non-recurrent archs or decode cells."""
    from repro.configs import get_config
    from repro.models import SHAPES
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    if shape.is_decode or cfg.family not in ("ssm", "hybrid"):
        return 0.0
    n_dev = chips(rec["mesh"])
    B, L = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        s = cfg.ssm
        dm = s.expand * cfg.d_model
        per_trip = 2 * B * dm * s.d_state * 4            # carry RW, f32
        return cfg.n_layers * L * per_trip / n_dev
    h = cfg.hybrid
    drnn = h.d_rnn or cfg.d_model
    n_rec = sum(1 for i in range(cfg.n_layers)
                if h.pattern[i % len(h.pattern)] == "rec")
    per_trip = 2 * B * drnn * 4
    return n_rec * L * per_trip / n_dev


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "run" or "flops_per_device" not in rec:
        return None
    n = chips(rec["mesh"])
    extra_scan = scan_state_traffic(rec)
    compute = rec["flops_per_device"] / PEAK_FLOPS
    memory = (rec["bytes_per_device"] + extra_scan) / HBM_BW
    memory_adj = (rec.get("bytes_adjusted_per_device",
                          rec["bytes_per_device"]) + extra_scan) / HBM_BW
    coll = rec["collective_bytes_per_device"] / LINK_BW
    terms = {"compute": compute, "memory": memory_adj, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = rec["flops_per_device"] * n
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_raw_s": memory, "memory_s": memory_adj,
        "collective_s": coll, "scan_state_bytes": extra_scan,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else float("nan"),
        "step_time_s": max(terms.values()),
        "roofline_frac": (min(compute, max(terms.values())) and
                          compute / max(terms.values())),
    }


def load(path: str) -> list[dict]:
    recs = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            recs.append(json.loads(line))
    return recs


def to_markdown(rows: list[dict], skips: list[dict]) -> str:
    out = ["| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
           "| dominant | 6ND/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} |")
    if skips:
        out.append("")
        out.append("Skipped cells (documented in DESIGN.md §Arch-applicability):")
        for s in skips:
            out.append(f"- {s['arch']} x {s['shape']} x {s['mesh']}: {s['status']}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="dryrun_results.jsonl")
    ap.add_argument("--md", default=None)
    ap.add_argument("--json", dest="json_out", default=None)
    args = ap.parse_args()

    recs = load(args.inp)
    rows, skips = [], []
    for rec in recs:
        if rec.get("status") != "run":
            skips.append(rec)
            continue
        a = analyze(rec)
        if a:
            rows.append(a)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    md = to_markdown(rows, skips)
    print(md)
    if args.md:
        Path(args.md).write_text(md + "\n")
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
