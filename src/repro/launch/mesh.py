"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and only the
dry-run wants 512 placeholder devices).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; the multi-pod mesh adds an outer 2-pod DP
    axis (gradient reduction crosses DCN on 'pod', ICI on 'data')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Largest (data, model) mesh over whatever devices exist (1 on this CPU
    container, N under ``--xla_force_host_platform_device_count=N``) — used
    by the real train/serve drivers and the elastic-restart path.

    ``model_parallel`` must divide the device count: the old
    ``max(n // model_parallel, 1)`` silently built a mesh wanting
    ``data * model_parallel != n`` devices, and ``jax.make_mesh`` then
    failed with an opaque reshape error deep in the launcher."""
    n = len(jax.devices())
    if model_parallel < 1:
        raise ValueError(f"model_parallel must be >= 1, got {model_parallel}")
    if n % model_parallel != 0:
        divisors = [d for d in range(1, n + 1) if n % d == 0]
        raise ValueError(
            f"model_parallel={model_parallel} does not divide the host "
            f"device count ({n} devices); valid values are {divisors}")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
