"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and only the
dry-run wants 512 placeholder devices).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; the multi-pod mesh adds an outer 2-pod DP
    axis (gradient reduction crosses DCN on 'pod', ICI on 'data')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Largest mesh over whatever devices exist (1 on this CPU container) —
    used by the real train/serve drivers and the elastic-restart path."""
    n = len(jax.devices())
    data = max(n // model_parallel, 1)
    return jax.make_mesh((data, model_parallel), ("data", "model"))
