"""Pre-jax host-platform device forcing, shared by the launchers, the test
suite, and the benchmark harness.

jax locks the device count at first init, so this must run before the first
jax import anywhere in the process — which is why this module deliberately
imports nothing but ``os``.  An operator-provided
``--xla_force_host_platform_device_count`` already in ``XLA_FLAGS`` always
wins; other flags in the variable are preserved (the
:mod:`repro.launch.dryrun` merge idiom).
"""

from __future__ import annotations

import os

FLAG = "--host-devices"


def parse_host_devices(argv) -> int | None:
    """Extract ``--host-devices N`` / ``--host-devices=N`` from ``argv``.
    Malformed or missing values return None — argparse (which also declares
    the flag) produces the user-facing error later."""
    for i, a in enumerate(argv):
        if a == FLAG:
            if i + 1 < len(argv):
                try:
                    return int(argv[i + 1])
                except ValueError:
                    return None
        elif a.startswith(FLAG + "="):
            try:
                return int(a.split("=", 1)[1])
            except ValueError:
                return None
    return None


def force_host_devices(n: int | None = None, argv=None) -> None:
    """Force ``n`` host-platform devices (or the count named by
    ``--host-devices`` in ``argv``) by merging into ``XLA_FLAGS``.  No-op if
    neither is given or the operator already forced a count."""
    if n is None:
        n = parse_host_devices(argv if argv is not None else [])
    if n is None:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={int(n)}").strip()
