"""qwen3-1.7b [hf:Qwen/Qwen3-8B; hf]
28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, qk_norm."""
from dataclasses import replace
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab=151936, act="swiglu", norm="rms",
    qk_norm=True, head_dim=128, rope_theta=1e6,
)

def reduced() -> ModelConfig:
    return replace(CONFIG, name="qwen3-smoke", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16)
