"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
24L d_model=2048 16H (GQA kv=16) d_ff=1408/expert, 60 routed top-4 + 4 shared
(shared width 4*1408=5632), vocab 151936."""
from dataclasses import replace
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, act="swiglu", norm="rms", qkv_bias=True,
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408,
                  n_shared=4, d_shared=5632),
)

def reduced() -> ModelConfig:
    return replace(
        CONFIG, name="qwen2-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=48, vocab=256,
        moe=MoEConfig(n_experts=6, top_k=2, d_expert=48, n_shared=2, d_shared=96),
    )
