"""seamless-m4t-medium [arXiv:2308.11596; hf]
enc-dec, 12L encoder + 12L decoder, d_model=1024 16H (kv=16) d_ff=4096
vocab=256206.  Audio frontend is a stub: input_specs provides precomputed
frame embeddings."""
from dataclasses import replace
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, n_encoder_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, act="geglu", norm="ln",
)

def reduced() -> ModelConfig:
    return replace(CONFIG, name="seamless-smoke", n_layers=2,
                   n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                   d_ff=128, vocab=256)
