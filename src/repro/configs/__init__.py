"""Architecture registry: one module per assigned arch, exact public configs.

``get_config(name)`` -> full ModelConfig; ``get_reduced(name)`` -> tiny
same-family config for CPU smoke tests.  ``ARCHS`` lists all ids.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "granite_moe_1b_a400m",
    "qwen2_moe_a2_7b",
    "phi3_mini_3_8b",
    "qwen3_1_7b",
    "nemotron_4_15b",
    "qwen2_5_32b",
    "seamless_m4t_medium",
    "recurrentgemma_9b",
    "falcon_mamba_7b",
    "llava_next_mistral_7b",
]

def _module(name: str):
    # public ids use hyphens/dots (qwen2.5-32b); modules use underscores
    name = name.lower().replace("-", "_").replace(".", "_")
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _module(name).reduced()
