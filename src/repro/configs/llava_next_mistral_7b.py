"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Backbone: mistral-7b — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000.  Anyres vision frontend is a stub: input_specs provides
precomputed patch embeddings (n_patch_tokens per sample)."""
from dataclasses import replace
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, act="swiglu", norm="rms",
    n_patch_tokens=576, rope_theta=1e6,
)

def reduced() -> ModelConfig:
    return replace(CONFIG, name="llava-smoke", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                   n_patch_tokens=8)
