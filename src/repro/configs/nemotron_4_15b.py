"""nemotron-4-15b [arXiv:2402.16819; unverified]
32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000, squared-ReLU, LN."""
from dataclasses import replace
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab=256000, act="sqrelu", norm="ln",
)

def reduced() -> ModelConfig:
    return replace(CONFIG, name="nemotron-smoke", n_layers=2, d_model=96,
                   n_heads=6, n_kv_heads=2, d_ff=192, vocab=256)
