"""falcon-mamba-7b [arXiv:2410.05355; unverified]
64L d_model=4096 attention-free mamba-1, ssm_state=16, vocab=65024."""
from dataclasses import replace
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024, norm="rms",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)

def reduced() -> ModelConfig:
    return replace(CONFIG, name="falcon-mamba-smoke", n_layers=2, d_model=64,
                   vocab=256, ssm=SSMConfig(d_state=8, d_conv=4, expand=2))
