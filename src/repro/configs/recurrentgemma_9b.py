"""recurrentgemma-9b [arXiv:2402.19427; unverified]
38L d_model=4096 16H (GQA kv=1 = MQA) d_ff=12288 vocab=256000,
RG-LRU + local attention 1:2 ((rec, rec, attn) pattern), window 2048."""
from dataclasses import replace
from repro.models.config import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, act="geglu", norm="rms", head_dim=256,
    hybrid=HybridConfig(window=2048, pattern=("rec", "rec", "attn")),
)

def reduced() -> ModelConfig:
    return replace(
        CONFIG, name="recurrentgemma-smoke", n_layers=5, d_model=64,
        n_heads=4, n_kv_heads=1, d_ff=128, vocab=256, head_dim=16,
        hybrid=HybridConfig(window=16, pattern=("rec", "rec", "attn")),
    )
