"""qwen2.5-32b [hf:Qwen/Qwen2.5-0.5B (family); hf]
64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064, QKV bias."""
from dataclasses import replace
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab=152064, act="swiglu", norm="rms",
    qkv_bias=True, rope_theta=1e6,
)

def reduced() -> ModelConfig:
    return replace(CONFIG, name="qwen2.5-smoke", n_layers=2, d_model=80,
                   n_heads=5, n_kv_heads=1, d_ff=160, vocab=256)
