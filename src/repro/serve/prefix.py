"""Content-hashed prefix cache: repeated prompts reuse KV pages.

Entries are keyed on the whole-prompt chained page hash
(:func:`repro.cache.token_prefix_keys`), so a hit means the *entire* token
sequence matched — insert is then an exact replay of the original prefill
state and trivially deterministic.  Each entry owns refcounted full pages
in the shared pool (never written after registration — decode writes land
on a per-slot private tail page, so no copy-on-write is needed) plus the
partial tail page's KV held as plain arrays outside the pool.

Because the fusion-plan cache keys on graph structure + shape bucket, a
prefix hit also reuses the cached prefill plan trivially (no prefill runs
at all); misses of the same bucket still share one plan.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro import obs
from repro.cache import token_prefix_keys

from .kv import PagedKV, Prefix

__all__ = ["PrefixCache", "PrefixEntry"]


@dataclass
class PrefixEntry:
    pages: list[int]          # cache-owned full pages in the pool
    tail: tuple | None        # (k, v) partial-page KV, outside the pool
    length: int
    first_token: int


class PrefixCache:
    """LRU cache of materialized prompt KV, page-table spliced on hit."""

    def __init__(self, kv: PagedKV, max_entries: int = 64):
        self.kv = kv
        self.max_entries = max_entries
        self._entries: OrderedDict[str, PrefixEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def _key(self, tokens) -> str:
        return token_prefix_keys(tokens, self.kv.page_size)[-1]

    def lookup(self, tokens) -> Prefix | None:
        """Whole-prompt hit -> a ready-to-insert :class:`Prefix`, else None."""
        key = self._key(tokens)
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            obs.event("serve.prefix.miss", cat="serve", key=key)
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        obs.event("serve.prefix.hit", cat="serve", key=key,
                  length=entry.length)
        return Prefix(
            lengths=np.array([entry.length], np.int32),
            first_tokens=np.array([entry.first_token], np.int64),
            bucket=entry.length,
            pages=entry.pages, tail=entry.tail, cached=True)

    def register(self, tokens, kv_cache: dict, row: int, first_token: int,
                 length: int) -> None:
        """Materialize one prefill row into cache-owned pages.  Best-effort:
        pool pressure (all pages pinned by live slots) skips registration
        rather than failing the prefill."""
        key = self._key(tokens)
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        try:
            pages, tail = self.kv.materialize_prefix(kv_cache, row, length)
        except Exception:
            return
        self._entries[key] = PrefixEntry(pages=pages, tail=tail,
                                         length=length,
                                         first_token=int(first_token))
        while len(self._entries) > self.max_entries:
            self.evict_one()

    def evict_one(self) -> bool:
        """Drop the LRU entry, releasing its pool pages.  Used both for the
        entry cap and as the allocator's pressure-reclaim callback."""
        if not self._entries:
            return False
        _, entry = self._entries.popitem(last=False)
        if entry.pages:
            self.kv.release_pages(entry.pages)
        return True

    @property
    def pages_held(self) -> int:
        return sum(len(e.pages) for e in self._entries.values())

    def report(self) -> dict:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "entries": len(self._entries),
                "pages_held": self.pages_held}
