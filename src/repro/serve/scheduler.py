"""Slot-based continuous-batching scheduler (the serving tentpole).

The decode batch is a fixed array of ``slots``; each slot independently
holds one in-flight request at its own sequence position.  The KV cache is
a single batched pytree whose ``"length"`` leaf is a per-slot *vector* —
the model's decode step (``dense`` / ``moe`` / ``vlm`` families) accepts it
and writes each slot's new KV at its own offset, so one batched decode step
advances every request regardless of where each one is in its stream.

Lifecycle per :meth:`Scheduler.step`:

1. **Refill** — free slots are filled from the FIFO queue.  Admission runs
   a *bucketed* prefill: the prompt is right-padded to the next power-of-two
   length (same :class:`~repro.cache.policy.BucketPolicy` rule the
   StitchCache keys on), so a refill at a nearby prompt length replays the
   already-compiled prefill executable — and, because the decode graph's
   shapes never change, the stitched decode plan — instead of forcing a
   recompile.  Causal masking makes the pad positions inert, and logits are
   gathered at the true last position, so bucketing never changes tokens
   (dense/vlm; see the moe capacity caveat on :data:`RAGGED_FAMILIES`).
2. **Decode** — one batched step over all slots (inactive slots ride along;
   their rows are ignored, and admission's slot write resets them).
3. **Evict** — slots whose request hit EOS (``eos_id >= 0``) or its
   per-request ``max_new_tokens`` are completed and freed; the next step's
   refill reuses them immediately.

The scheduler is deliberately model-API-thin: it is handed a
``decode_fn(cache, tok) -> (logits, cache)`` (the engine injects its
stitched-or-jitted dispatch there) and drives ``model.prefill`` itself.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.cache.policy import BucketPolicy

from .metrics import ServeMetrics, StepMetrics
from .queue import FinishedRequest, Request, RequestQueue

__all__ = ["SchedulerConfig", "Scheduler", "RAGGED_FAMILIES"]

# families whose decode step supports a per-slot length vector AND whose
# prefill is pad-invariant under causal masking (SSM/hybrid state mixes pad
# tokens in, so bucketed admission would change numerics there).  Caveat:
# moe is pad-invariant only while no expert overflows its capacity —
# GShard token-choice dispatch couples rows through the shared capacity
# budget (pad/ride-along tokens can displace real ones on overflow), the
# same coupling a static moe batch already has.  dense/vlm are exact.
RAGGED_FAMILIES = ("dense", "moe", "vlm")

# the admission bucket rule, shared with Engine._generate_ragged so the
# static reference path pads exactly like the scheduler
ADMISSION_BUCKET = BucketPolicy(mode="pow2", min_dim=1)


@dataclass
class SchedulerConfig:
    slots: int
    max_len: int                        # KV capacity per slot
    max_new_tokens: int = 32            # default per-request budget
    eos_id: int = -1                    # -1: never stop early
    # pow2 admission buckets; min_dim=1 so even short prompts coalesce
    # (the cache-key default of 16 would give every short length its own
    # compile)
    bucket: BucketPolicy = field(default_factory=lambda: ADMISSION_BUCKET)


@dataclass
class _Slot:
    req: Request
    tokens: list[int]
    admit_time: float
    admit_step: int


class Scheduler:
    def __init__(self, model, params, cfg: SchedulerConfig,
                 decode_fn: Callable, status_fn: Callable | None = None,
                 clock: Callable[[], float] = time.monotonic):
        if model.cfg.family not in RAGGED_FAMILIES:
            raise NotImplementedError(
                f"continuous batching supports families {RAGGED_FAMILIES}, "
                f"got {model.cfg.family!r} (its decode state is not "
                f"pad-invariant / per-slot addressable)")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.decode_fn = decode_fn
        self.status_fn = status_fn or (lambda: None)
        self.clock = clock

        self.queue = RequestQueue()
        self.metrics = ServeMetrics()
        cache = model.init_cache(cfg.slots, cfg.max_len)
        cache = dict(cache)
        cache["length"] = jnp.zeros((cfg.slots,), jnp.int32)
        self.cache = cache
        self.tok = np.zeros((cfg.slots, 1), np.int32)
        self.slots: list[_Slot | None] = [None] * cfg.slots
        self.step_count = 0
        # one compiled prefill per (bucket length, extra-structure) — this
        # memo is what bucketed admission exists to keep small
        self._prefill_fns: dict[tuple, Callable] = {}
        self._write_fns: dict[tuple, Callable] = {}

    # -- admission -------------------------------------------------------------
    def bucket_len(self, prompt_len: int) -> int:
        return min(self.cfg.bucket.bucket_dim(prompt_len), self.cfg.max_len)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def submit(self, prompt, max_new_tokens: int | None = None,
               rid: int | None = None, arrival_time: float | None = None,
               extra: dict | None = None) -> int:
        """Enqueue one request; returns its id."""
        n_new = self.cfg.max_new_tokens if max_new_tokens is None else max_new_tokens
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) + n_new > self.cfg.max_len:
            raise ValueError(
                f"prompt_len={len(prompt)} + max_new_tokens={n_new} exceeds "
                f"max_len={self.cfg.max_len}")
        at = self.clock() if arrival_time is None else arrival_time
        return self.queue.submit(prompt, n_new, rid=rid, arrival_time=at,
                                 extra=extra)

    def _prefill_fn(self, pb: int, extra: dict) -> Callable:
        key = (pb, tuple(sorted(extra)),
               tuple((np.shape(v), str(np.asarray(v).dtype))
                     for _, v in sorted(extra.items())))
        fn = self._prefill_fns.get(key)
        if fn is None:
            fn = jax.jit(lambda p, toks, tl, **kw: self.model.prefill(
                p, toks, true_len=tl, **kw))
            self._prefill_fns[key] = fn
        return fn

    def _write_fn(self, pb: int) -> Callable:
        """Jitted slot write: splice a (·, 1, pb, ·, ·) prefill cache into
        row ``slot`` of the batched decode cache (traced index — one compile
        per bucket, not per slot)."""
        fn = self._write_fns.get(pb)
        if fn is None:
            def write(cache, pcache, slot):
                out = dict(cache)
                for k, leaf in cache.items():
                    if k == "length":
                        continue
                    upd = pcache[k].astype(leaf.dtype)
                    start = (0, slot) + (0,) * (leaf.ndim - 2)
                    out[k] = jax.lax.dynamic_update_slice(leaf, upd, start)
                out["length"] = cache["length"].at[slot].set(
                    pcache["length"][0])
                return out
            fn = jax.jit(write)
            self._write_fns[pb] = fn
        return fn

    def _finish(self, slot_state: _Slot, reason: str, step: int) -> FinishedRequest:
        req = slot_state.req
        fin = FinishedRequest(
            rid=req.rid, prompt_len=len(req.prompt),
            tokens=np.asarray(slot_state.tokens, np.int32),
            finish_reason=reason,
            arrival_time=req.arrival_time,
            admit_time=slot_state.admit_time,
            first_token_time=slot_state.admit_time,
            finish_time=self.clock(),
            admit_step=slot_state.admit_step, finish_step=step)
        self.metrics.record_finished(fin)
        obs.event("serve.evict", cat="serve", rid=req.rid, reason=reason,
                  step=step, tokens=len(slot_state.tokens))
        return fin

    def _admit(self, slot: int, req: Request) -> tuple[int, int]:
        """Bucketed prefill into ``slot``; returns (tokens_emitted, evictions)
        — a request whose budget is 1 (or whose first token is EOS) finishes
        at admission without ever occupying the slot."""
        P = len(req.prompt)
        pb = self.bucket_len(P)
        padded = np.zeros((1, pb), np.int32)
        padded[0, :P] = req.prompt
        with obs.span("serve.prefill", cat="serve", rid=req.rid,
                      prompt_len=P, bucket=pb, slot=slot):
            logits, pcache = self._prefill_fn(pb, req.extra)(
                self.params, jnp.asarray(padded),
                jnp.asarray([P], jnp.int32), **req.extra)
        first = int(jnp.argmax(logits, axis=-1)[0])
        state = _Slot(req=req, tokens=[first], admit_time=self.clock(),
                      admit_step=self.step_count)
        eos = self.cfg.eos_id >= 0 and first == self.cfg.eos_id
        if eos or req.max_new_tokens == 1:
            self._finish(state, "eos" if eos else "length", self.step_count)
            return 1, 1
        self.cache = self._write_fn(pb)(self.cache, pcache,
                                        jnp.asarray(slot, jnp.int32))
        self.tok[slot, 0] = first
        self.slots[slot] = state
        return 1, 0

    def _refill(self) -> tuple[int, int, int]:
        """Fill free slots from the queue; returns (admissions, tokens,
        evictions)."""
        admissions = tokens = evictions = 0
        for slot in range(self.cfg.slots):
            while self.slots[slot] is None and self.queue:
                req = self.queue.pop()
                t, e = self._admit(slot, req)
                admissions += 1
                tokens += t
                evictions += e
                if e == 0:
                    break               # slot now occupied
        return admissions, tokens, evictions

    def _chunk_len(self) -> int:
        """Decode steps safely runnable before the next scheduling decision.

        With EOS off, evictions are budget-exhaustions — predictable on the
        host — and after a refill either the queue is empty or every slot is
        full, so no admission can happen before the earliest budget runs
        out.  Chunking those steps keeps the decode stream on device (one
        argmax readback per chunk instead of per token).  With EOS on, every
        token is a potential eviction: chunk = 1."""
        if self.cfg.eos_id >= 0:
            return 1
        return min(s.req.max_new_tokens - len(s.tokens)
                   for s in self.slots if s is not None)

    # -- one scheduling iteration ---------------------------------------------
    def step(self) -> StepMetrics:
        t0 = self.clock()
        step = self.step_count
        ssp = obs.span("serve.step", cat="serve", step=step)
        ssp.__enter__()
        admissions, tokens, evictions = self._refill()
        active = self.n_active

        if active:
            chunk = self._chunk_len()
            cache, tok = self.cache, jnp.asarray(self.tok)
            toks_dev = []
            for _ in range(chunk):
                logits, cache = self.decode_fn(cache, tok)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
                toks_dev.append(tok)
            self.cache = cache
            # the chunk's one host sync: token ids are needed for EOS /
            # budget checks and the next iteration's input.  Free slots ride
            # along (their rows are ignored and admission's slot write
            # resets both KV and length), so nothing else syncs.
            nxt = np.asarray(jnp.concatenate(toks_dev, axis=1))   # (slots, chunk)
            for slot, state in enumerate(self.slots):
                if state is None:
                    continue
                done = None
                for tok_i in nxt[slot].tolist():
                    state.tokens.append(int(tok_i))
                    tokens += 1
                    if self.cfg.eos_id >= 0 and tok_i == self.cfg.eos_id:
                        done = "eos"
                        break
                    if len(state.tokens) >= state.req.max_new_tokens:
                        done = "length"
                        break
                if done is not None:
                    self._finish(state, done, step)
                    self.slots[slot] = None
                    evictions += 1
                    self.tok[slot, 0] = 0
                else:
                    self.tok[slot, 0] = int(nxt[slot, -1])

        m = StepMetrics(
            step=step, active=active, slots=self.cfg.slots,
            queue_depth=len(self.queue), admissions=admissions,
            evictions=evictions, tokens=tokens,
            step_seconds=self.clock() - t0, stitch_status=self.status_fn())
        self.metrics.record_step(m)
        self.step_count += 1
        ssp.set(active=active, admissions=admissions, evictions=evictions,
                tokens=tokens, queue_depth=m.queue_depth,
                stitch_status=m.stitch_status)
        ssp.__exit__(None, None, None)
        # a Perfetto counter track per series: occupancy + queue over time
        obs.counter_event("serve.slots", cat="serve", active=active,
                          free=self.cfg.slots - active,
                          queue_depth=m.queue_depth)
        return m

    def drain(self, max_steps: int | None = None) -> list[FinishedRequest]:
        """Step until queue and slots are empty; returns finished requests in
        completion order."""
        already = len(self.metrics.finished)
        budget = max_steps if max_steps is not None else (
            10 * self.cfg.max_len * (len(self.queue) + self.n_active + 1))
        for _ in range(budget):
            if not self.queue and not self.n_active:
                break
            self.step()
        if self.queue or self.n_active:
            raise RuntimeError(f"drain did not converge in {budget} steps")
        return self.metrics.finished[already:]
