"""Slot-based continuous-batching scheduler.

The scheduler is a thin orchestrator over the engine's three-stage API:
admission is :meth:`Engine.prefill` (bucketed, stitched, prefix-cached) +
:meth:`Engine.insert` (a page-table splice on paged engines), the decode is
:meth:`Engine.generate_step` (one batched chunk over all slots), and
eviction is :meth:`Engine.release` (pages return to the free list).  All
model and KV mechanics live behind the engine; the scheduler owns only the
FIFO queue, the per-slot request lifecycle, and the metrics stream.

Lifecycle per :meth:`Scheduler.step`:

1. **Refill** — free slots are filled from the FIFO queue.  Admission runs
   a *bucketed* prefill: the prompt is right-padded to the next power-of-two
   length (same :class:`~repro.cache.policy.BucketPolicy` rule the
   StitchCache keys on), so a refill at a nearby prompt length replays the
   already-compiled prefill specialization — and, because the decode
   graph's shapes never change, the stitched decode plan — instead of
   forcing a recompile.  Causal masking makes the pad positions inert, and
   logits are gathered at the true last position, so bucketing never
   changes tokens (dense/vlm; see the moe capacity caveat on
   :data:`RAGGED_FAMILIES`).
2. **Decode** — one batched chunk over all slots (inactive slots ride
   along; their rows are ignored, and insert resets them).
3. **Evict** — slots whose request hit EOS (``eos_id >= 0``) or its
   per-request ``max_new_tokens`` are completed and released; the next
   step's refill reuses them immediately.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro import obs
from repro.cache.policy import BucketPolicy

from .metrics import ServeMetrics, StepMetrics
from .queue import FinishedRequest, Request, RequestQueue

__all__ = ["SchedulerConfig", "Scheduler", "RAGGED_FAMILIES",
           "ADMISSION_BUCKET"]

# families whose decode step supports a per-slot length vector AND whose
# prefill is pad-invariant under causal masking (SSM/hybrid state mixes pad
# tokens in, so bucketed admission would change numerics there).  Caveat:
# moe is pad-invariant only while no expert overflows its capacity —
# GShard token-choice dispatch couples rows through the shared capacity
# budget (pad/ride-along tokens can displace real ones on overflow), the
# same coupling a static moe batch already has.  dense/vlm are exact.
RAGGED_FAMILIES = ("dense", "moe", "vlm")

# the admission bucket rule, shared with Engine.prefill so the static
# reference path pads exactly like the scheduler
ADMISSION_BUCKET = BucketPolicy(mode="pow2", min_dim=1)


@dataclass
class SchedulerConfig:
    slots: int
    max_len: int                        # KV capacity per slot
    max_new_tokens: int = 32            # default per-request budget
    eos_id: int = -1                    # -1: never stop early
    # pow2 admission buckets; min_dim=1 so even short prompts coalesce
    # (the cache-key default of 16 would give every short length its own
    # compile)
    bucket: BucketPolicy = field(default_factory=lambda: ADMISSION_BUCKET)


@dataclass
class _Slot:
    req: Request
    tokens: list[int]
    admit_time: float
    admit_step: int
    prefix_cached: bool = False


class Scheduler:
    def __init__(self, engine, cfg: SchedulerConfig | None = None,
                 clock: Callable[[], float] = time.monotonic):
        if engine.model.cfg.family not in RAGGED_FAMILIES:
            raise NotImplementedError(
                f"continuous batching supports families {RAGGED_FAMILIES}, "
                f"got {engine.model.cfg.family!r} (its decode state is not "
                f"pad-invariant / per-slot addressable)")
        if cfg is None:
            cfg = SchedulerConfig(
                slots=engine.cfg.batch, max_len=engine.cfg.max_len,
                max_new_tokens=engine.cfg.max_new_tokens,
                eos_id=engine.cfg.eos_id)
        self.engine = engine
        self.cfg = cfg
        self.clock = clock

        self.queue = RequestQueue()
        self.metrics = ServeMetrics()
        self.slots: list[_Slot | None] = [None] * cfg.slots
        self.step_count = 0

    # -- admission -------------------------------------------------------------
    def bucket_len(self, prompt_len: int) -> int:
        return min(self.cfg.bucket.bucket_dim(prompt_len), self.cfg.max_len)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def submit(self, prompt, max_new_tokens: int | None = None,
               rid: int | None = None, arrival_time: float | None = None,
               extra: dict | None = None) -> int:
        """Enqueue one request; returns its id."""
        n_new = self.cfg.max_new_tokens if max_new_tokens is None else max_new_tokens
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) + n_new > self.cfg.max_len:
            raise ValueError(
                f"prompt_len={len(prompt)} + max_new_tokens={n_new} exceeds "
                f"max_len={self.cfg.max_len}")
        at = self.clock() if arrival_time is None else arrival_time
        return self.queue.submit(prompt, n_new, rid=rid, arrival_time=at,
                                 extra=extra)

    def _finish(self, slot_state: _Slot, reason: str, step: int) -> FinishedRequest:
        req = slot_state.req
        fin = FinishedRequest(
            rid=req.rid, prompt_len=len(req.prompt),
            tokens=np.asarray(slot_state.tokens, np.int32),
            finish_reason=reason,
            arrival_time=req.arrival_time,
            admit_time=slot_state.admit_time,
            first_token_time=slot_state.admit_time,
            finish_time=self.clock(),
            admit_step=slot_state.admit_step, finish_step=step,
            prefix_cached=slot_state.prefix_cached)
        self.metrics.record_finished(fin)
        obs.event("serve.evict", cat="serve", rid=req.rid, reason=reason,
                  step=step, tokens=len(slot_state.tokens))
        return fin

    def _admit(self, slot: int, req: Request) -> tuple[int, int, int]:
        """Prefill + insert into ``slot``; returns (tokens_emitted,
        evictions, prefix_hits) — a request whose budget is 1 (or whose
        first token is EOS) finishes at admission without ever occupying
        the slot."""
        px = self.engine.prefill(req.prompt, extra=req.extra, rid=req.rid)
        first = int(px.first_tokens[0])
        state = _Slot(req=req, tokens=[first], admit_time=self.clock(),
                      admit_step=self.step_count, prefix_cached=px.cached)
        hits = int(px.cached)
        eos = self.cfg.eos_id >= 0 and first == self.cfg.eos_id
        if eos or req.max_new_tokens == 1:
            self._finish(state, "eos" if eos else "length", self.step_count)
            return 1, 1, hits
        self.engine.insert(px, slot)
        self.slots[slot] = state
        return 1, 0, hits

    def _refill(self) -> tuple[int, int, int, int]:
        """Fill free slots from the queue; returns (admissions, tokens,
        evictions, prefix_hits)."""
        admissions = tokens = evictions = hits = 0
        for slot in range(self.cfg.slots):
            while self.slots[slot] is None and self.queue:
                req = self.queue.pop()
                t, e, h = self._admit(slot, req)
                admissions += 1
                tokens += t
                evictions += e
                hits += h
                if e == 0:
                    break               # slot now occupied
        return admissions, tokens, evictions, hits

    def _chunk_len(self) -> int:
        """Decode steps safely runnable before the next scheduling decision.

        With EOS off, evictions are budget-exhaustions — predictable on the
        host — and after a refill either the queue is empty or every slot is
        full, so no admission can happen before the earliest budget runs
        out.  Chunking those steps keeps the decode stream on device (one
        argmax readback per chunk instead of per token).  With EOS on, every
        token is a potential eviction: chunk = 1."""
        if self.cfg.eos_id >= 0:
            return 1
        return min(s.req.max_new_tokens - len(s.tokens)
                   for s in self.slots if s is not None)

    # -- one scheduling iteration ---------------------------------------------
    def step(self) -> StepMetrics:
        t0 = self.clock()
        step = self.step_count
        ssp = obs.span("serve.step", cat="serve", step=step)
        ssp.__enter__()
        admissions, tokens, evictions, prefix_hits = self._refill()
        active = self.n_active

        if active:
            chunk = self._chunk_len()
            # the chunk's one host sync: token ids are needed for EOS /
            # budget checks and the next iteration's input.  Free slots
            # ride along inside the engine (their rows are ignored).
            nxt = self.engine.generate_step(steps=chunk)   # (slots, chunk)
            for slot, state in enumerate(self.slots):
                if state is None:
                    continue
                done = None
                for tok_i in nxt[slot].tolist():
                    state.tokens.append(int(tok_i))
                    tokens += 1
                    if self.cfg.eos_id >= 0 and tok_i == self.cfg.eos_id:
                        done = "eos"
                        break
                    if len(state.tokens) >= state.req.max_new_tokens:
                        done = "length"
                        break
                if done is not None:
                    self._finish(state, done, step)
                    self.slots[slot] = None
                    self.engine.release(slot)
                    evictions += 1

        m = StepMetrics(
            step=step, active=active, slots=self.cfg.slots,
            queue_depth=len(self.queue), admissions=admissions,
            evictions=evictions, tokens=tokens,
            step_seconds=self.clock() - t0,
            stitch_status=self.engine.stitch_status,
            prefix_hits=prefix_hits)
        self.metrics.record_step(m)
        self.step_count += 1
        ssp.set(active=active, admissions=admissions, evictions=evictions,
                tokens=tokens, queue_depth=m.queue_depth,
                stitch_status=m.stitch_status)
        ssp.__exit__(None, None, None)
        # a Perfetto counter track per series: occupancy + queue over time
        obs.counter_event("serve.slots", cat="serve", active=active,
                          free=self.cfg.slots - active,
                          queue_depth=m.queue_depth)
        if self.engine.paged and self.engine._kv is not None:
            alloc = self.engine.kv.allocator
            obs.counter_event("serve.pages", cat="serve", used=alloc.used,
                              free=alloc.free_count)
        return m

    def drain(self, max_steps: int | None = None) -> list[FinishedRequest]:
        """Step until queue and slots are empty; returns finished requests in
        completion order."""
        already = len(self.metrics.finished)
        budget = max_steps if max_steps is not None else (
            10 * self.cfg.max_len * (len(self.queue) + self.n_active + 1))
        for _ in range(budget):
            if not self.queue and not self.n_active:
                break
            self.step()
        if self.queue or self.n_active:
            raise RuntimeError(f"drain did not converge in {budget} steps")
        return self.metrics.finished[already:]
