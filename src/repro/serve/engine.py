"""Batched serving engine: prefill + greedy decode over a KV cache.

Two serving modes share this engine:

* **Static batch** (:meth:`Engine.generate`) — requests are padded into one
  fixed batch, prefilled once, then decoded lock-step.  With
  ``prompt_lens`` the batch may be ragged: prompts are padded to a pow2
  bucket, logits gathered at each row's true last position, and the decode
  runs with a per-row length vector.
* **Continuous batching** (:meth:`Engine.submit` / :meth:`Engine.step` /
  :meth:`Engine.drain`) — a slot-based
  :class:`~repro.serve.scheduler.Scheduler` admits queued requests into a
  fixed-slot decode batch, interleaves bucketed prefills with ongoing
  decode, evicts slots on EOS / max-token completion and refills them
  immediately, so one long request never stalls the batch.

Both modes decode through ONE :func:`repro.exec.stitch`-produced step.
The execution layer owns everything the engine used to hand-roll: tracing
the decode step to StitchIR on first use, compile-or-fallback through the
:class:`repro.cache.CompilationService` (a cache hit replays the stored
fusion plan instantly; a miss serves the cheap XLA-mode fallback while the
stitch pipeline runs on a background thread), per-call upgrade polling (so
a continuous request stream upgrades mid-flight), shape/structure-drift
fallback to jit, and — with ``mesh=`` — DP-replica ``shard_map`` dispatch:
the slot dimension is sharded over the mesh's data-parallel axes for both
the jitted and the stitched decode, with the stitched executable traced and
solved at *shard-local* shapes under a mesh-keyed placement.  Admission
prefills stay per-request (B=1) and unsharded.

``ServeConfig.stitch_execute`` selects the exec mode: ``True`` decodes
through the stitched artifact (``"stitch"``); ``False`` keeps the jitted
step serving while the stitched plan powers reporting and cache warmth
(``"shadow"``); no service at all is pure (sharded) jit dispatch
(``"jit"``).  A background compile that fails is surfaced once as a
``RuntimeWarning`` and in :meth:`Engine.stitch_report` — the engine never
silently serves the fallback forever.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.exec import stitch
from repro.models.api import Model


@dataclass
class ServeConfig:
    batch: int           # static batch size == continuous-batching slot count
    max_len: int
    max_new_tokens: int = 32
    eos_id: int = -1     # -1: never stop early (fixed-length benchmark mode)
    stitch_execute: bool = False   # run decode through the stitched artifact


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 stitch_service=None, mesh: Mesh | None = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.stitch_service = stitch_service
        self._scheduler = None
        self.mesh = mesh if (mesh is not None and mesh.size > 1) else None
        self._slot_axes: tuple[str, ...] | None = None
        if self.mesh is not None:
            from repro.models.sharding import batch_shard_axes
            axes = batch_shard_axes(self.mesh, cfg.batch)
            if not axes:
                raise ValueError(
                    f"slots/batch={cfg.batch} does not divide across mesh "
                    f"{dict(self.mesh.shape)}: the DP-replica dispatch needs "
                    f"the slot count to be a multiple of the DP size (or of "
                    f"the whole mesh)")
            self._slot_axes = axes
        self._exec = self._build_exec()
        self._ragged_prefill = jax.jit(
            lambda p, toks, tl, ml, **kw: model.prefill(
                p, toks, true_len=tl, max_len=ml, **kw),
            static_argnames=("ml",))

    # -- the one decode dispatch ----------------------------------------------
    def _build_exec(self):
        """The stitch()-produced decode step every serving path shares.

        ``extra`` (family-specific decode inputs, e.g. encoder outputs) is a
        real traced argument, not a closure capture, so later calls' values
        flow through the stitched graph; a *structure* change is an ordinary
        signature drift and serves through jit.  Under a mesh the partition
        specs are derived per signature from the concrete cache pytree
        (leaf-name based slot specs); signatures with a non-empty ``extra``
        resolve to ``None`` — their slot layout is family-specific and not
        worth a wrong guess — which the exec layer serves via plain jit.
        """
        model = self.model
        mode = ("jit" if self.stitch_service is None
                else "stitch" if self.cfg.stitch_execute else "shadow")

        def decode_step(params, cache, tok, extra):
            return model.decode_step(params, cache, tok, **extra)

        # eligibility covers only (cache, tok, extra): params are fixed for
        # an engine's lifetime, so the per-token drift check stays cheap
        elig = (1, 2, 3)
        if self.mesh is None:
            return stitch(decode_step, mode=mode, service=self.stitch_service,
                          eligibility_argnums=elig, name="decode_step")

        mesh, axes = self.mesh, self._slot_axes

        def in_specs(params, cache, tok, extra):
            if extra:
                return None
            from repro.models.sharding import slot_pspecs
            return (P(), slot_pspecs(cache, mesh, axes), P(axes, None), P())

        def out_specs(params, cache, tok, extra):
            from repro.models.sharding import slot_pspecs
            return (P(axes), slot_pspecs(cache, mesh, axes))

        return stitch(decode_step, mode=mode, service=self.stitch_service,
                      mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      eligibility_argnums=elig, name="decode_step")

    def _decode_dispatch(self, cache, tok, extra):
        """One decode step through the shared execution layer — stitched
        artifact when eligible, jit otherwise, polling the background
        upgrade each call (so a request stream upgrades mid-stream).  Both
        routes are DP-replica sharded when the engine has a mesh."""
        return self._exec(self.params, cache, jnp.asarray(tok), extra)

    @property
    def dp_replicas(self) -> int:
        """Replica count the decode batch is spread over (1 when unsharded)."""
        if self._slot_axes is None:
            return 1
        n = 1
        for a in self._slot_axes:
            n *= self.mesh.shape[a]
        return n

    # -- observability ---------------------------------------------------------
    @property
    def stitch_status(self) -> str | None:
        """None before the first decode (or without a service), else the
        exec layer's status: hit | miss | pending | failed | error."""
        if self.stitch_service is None:
            return None
        return self._exec.status

    @property
    def _stitch(self) -> dict | None:
        """Test/debug view of the active stitched specialization."""
        sp = self._exec._active
        if sp is None:
            return None
        if sp.graph is None:
            return {}
        return {"graph": sp.graph, "compiled": sp.compiled,
                "placement": sp.placement, "sharded": sp.sharded,
                "executable": sp.executable}

    def stitch_report(self) -> dict:
        """Upgrade status, plan stats, call counts, cache hit rates, and
        every background-compile failure — the unified
        :data:`repro.obs.EXEC_REPORT_SCHEMA` dict, also in pure-jit mode
        (where ``cache``/``errors`` are empty)."""
        return self._exec.report()

    # -- continuous batching ---------------------------------------------------
    @property
    def scheduler(self):
        """Lazy slot scheduler over this engine's decode dispatch."""
        if self._scheduler is None:
            from .scheduler import Scheduler, SchedulerConfig
            cfg = SchedulerConfig(
                slots=self.cfg.batch, max_len=self.cfg.max_len,
                max_new_tokens=self.cfg.max_new_tokens, eos_id=self.cfg.eos_id)
            self._scheduler = Scheduler(
                self.model, self.params, cfg,
                decode_fn=lambda cache, tok: self._decode_dispatch(cache, tok, {}),
                status_fn=lambda: self.stitch_status)
        return self._scheduler

    def submit(self, prompt, max_new_tokens: int | None = None, **kw) -> int:
        """Enqueue one request (1-D prompt); returns its request id."""
        return self.scheduler.submit(prompt, max_new_tokens=max_new_tokens, **kw)

    def step(self):
        """Run one scheduler step (refill -> batched decode -> evict);
        returns its :class:`~repro.serve.metrics.StepMetrics`."""
        return self.scheduler.step()

    def drain(self, max_steps: int | None = None):
        """Step until all submitted requests finish; returns the
        :class:`~repro.serve.queue.FinishedRequest` list in completion order."""
        return self.scheduler.drain(max_steps=max_steps)

    def serve_report(self) -> dict:
        """Aggregate scheduler metrics (empty if continuous mode unused)."""
        if self._scheduler is None:
            return {}
        return self._scheduler.metrics.summary()

    # -- static serving loop ---------------------------------------------------
    def generate(self, prompts: np.ndarray, prompt_lens=None, **extra) -> np.ndarray:
        """prompts: (batch, prompt_len) int32 -> (batch, max_new_tokens).

        ``prompt_lens`` (per-row true lengths) switches to the ragged static
        path: prompts are padded to the same pow2 bucket the continuous
        scheduler admits at, logits come from each row's true last position,
        and the decode runs with a per-row length vector — the per-request
        reference the scheduler is tested token-for-token against."""
        B, P = prompts.shape
        assert B == self.cfg.batch
        if prompt_lens is not None:
            return self._generate_ragged(prompts, prompt_lens, extra)
        logits, cache = self.model.prefill(
            self.params, jnp.asarray(prompts, jnp.int32), **extra)
        # decode cache from prefill may be shorter than max_len; re-home it
        if "k" in cache and cache["k"].ndim == 5 and cache["k"].shape[2] < self.cfg.max_len:
            pad = self.cfg.max_len - cache["k"].shape[2]
            cache = dict(cache)
            cache["k"] = jnp.pad(cache["k"], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
            cache["v"] = jnp.pad(cache["v"], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])

        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return self._decode_loop(cache, tok, extra)

    def _decode_loop(self, cache, tok, extra) -> np.ndarray:
        """Lock-step greedy decode for ``max_new_tokens`` steps through the
        shared dispatch (the exec layer re-checks eligibility and polls the
        upgrade per step — numerics are identical across an upgrade, so a
        mid-loop artifact swap is invisible in the tokens)."""
        out = []
        for _ in range(self.cfg.max_new_tokens):
            out.append(np.asarray(tok))
            logits, cache = self._decode_dispatch(cache, tok, extra)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return np.concatenate(out, axis=1)

    def _generate_ragged(self, prompts: np.ndarray, prompt_lens, extra) -> np.ndarray:
        from .scheduler import ADMISSION_BUCKET, RAGGED_FAMILIES
        if self.model.cfg.family not in RAGGED_FAMILIES:
            raise NotImplementedError(
                f"ragged generate (prompt_lens) supports families "
                f"{RAGGED_FAMILIES}, got {self.model.cfg.family!r}")
        B, P = prompts.shape
        lens = np.asarray(prompt_lens, np.int32).reshape(-1)
        assert lens.shape == (B,) and int(lens.max()) <= P
        # pad to the scheduler's admission bucket so a batch=1 ragged run is
        # the scheduler's bitwise reference
        pb = min(ADMISSION_BUCKET.bucket_dim(P), self.cfg.max_len)
        padded = np.zeros((B, pb), np.int32)
        padded[:, :P] = prompts
        logits, cache = self._ragged_prefill(
            self.params, jnp.asarray(padded), jnp.asarray(lens),
            ml=self.cfg.max_len, **extra)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return self._decode_loop(cache, tok, extra)
