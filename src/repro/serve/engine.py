"""Serving engine: three explicit stages over paged KV.

The public API is the disaggregated serving triple:

* :meth:`Engine.prefill` — run one (possibly ragged) prompt batch through a
  pow2-bucketed prefill and get a :class:`~repro.serve.kv.Prefix`: true
  lengths, the greedy first token per row, and the bucketed KV.  Prefills
  dispatch through :func:`repro.exec.stitch` with ``respecialize``: each
  bucket is its own specialization with its own placement-keyed fusion
  plan, so miss-then-upgrade, plan caching, and :meth:`Engine.report` cover
  prefill exactly like decode.  With ``ServeConfig.prefix_cache`` a
  repeated prompt (content-hashed at page granularity) skips the forward
  pass entirely and returns a page-table reference into cached KV.
* :meth:`Engine.insert` — bind one prefix row to a decode slot.  Paged
  engines splice page tables (shared full pages are refcounted; only the
  partial tail page is copied); dense engines splice the slot rectangle.
* :meth:`Engine.generate_step` — advance every occupied slot ``steps``
  greedy tokens through the one stitched decode step (one host readback
  per chunk).  :meth:`Engine.release` frees a finished slot's pages.

KV lives in :class:`~repro.serve.kv.PagedKV` (fixed-size pages + free-list
allocator; the default off-mesh) or :class:`~repro.serve.kv.DenseKV` (the
legacy per-slot rectangle; required under a mesh, where the decode batch is
``shard_map``-sharded over DP replicas and a shared page pool is not
slot-partitionable).  The paged decode gathers each slot's pages into a
dense view and slices it back to ``max_len``, so paged and dense serving
are token-for-token identical.

Legacy surfaces remain as thin shims: ``submit``/``step``/``drain``
delegate to the :class:`~repro.serve.scheduler.Scheduler` (which itself
drives prefill → insert → generate_step), ``generate(prompts,
prompt_lens=...)`` stages a whole batch through the same three calls, and
``generate(prompts)`` without lengths keeps the old rectangular
cache-splice path behind a one-per-process ``DeprecationWarning``.
"""

from __future__ import annotations

import warnings
from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.exec import stitch
from repro.models.api import Model

from .kv import DenseKV, PagedKV, Prefix

_LEGACY_RECT_WARNED = False


@dataclass
class ServeConfig:
    batch: int           # static batch size == continuous-batching slot count
    max_len: int
    max_new_tokens: int = 32
    eos_id: int = -1     # -1: never stop early (fixed-length benchmark mode)
    stitch_execute: bool = False   # run decode through the stitched artifact
    # -- KV layout -------------------------------------------------------------
    # None resolves to paged off-mesh (when the family has a paged layout)
    # and dense under a mesh; True forces paged (errors with a mesh), False
    # forces the legacy dense rectangles.
    paged: bool | None = None
    page_size: int = 16
    # pool size; default slots*ceil(max_len/ps)+1 (doubled when the prefix
    # cache is on, so cached pages aren't evicted by slot-demand pressure)
    num_pages: int | None = None
    # -- prefix cache ----------------------------------------------------------
    prefix_cache: bool = False     # content-hashed prompt KV reuse (paged only)
    prefix_cache_entries: int = 64
    # -- prefill dispatch ------------------------------------------------------
    # live prefill specializations (pow2 buckets x extra-structures), LRU
    prefill_cache_size: int = 8
    # -- debug -----------------------------------------------------------------
    # run the repro.analysis KV/refcount audit after every release() and
    # drain() (paged engines); a leaked or double-owned page raises
    # VerificationError at the call that created it instead of surfacing
    # later as pool exhaustion.  Off by default: the audit walks the whole
    # page pool.
    debug_kv: bool = False


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 stitch_service=None, mesh: Mesh | None = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.stitch_service = stitch_service
        self._scheduler = None
        self.mesh = mesh if (mesh is not None and mesh.size > 1) else None
        self._slot_axes: tuple[str, ...] | None = None
        if self.mesh is not None:
            from repro.models.sharding import batch_shard_axes
            axes = batch_shard_axes(self.mesh, cfg.batch)
            if not axes:
                raise ValueError(
                    f"slots/batch={cfg.batch} does not divide across mesh "
                    f"{dict(self.mesh.shape)}: the DP-replica dispatch needs "
                    f"the slot count to be a multiple of the DP size (or of "
                    f"the whole mesh)")
            self._slot_axes = axes
        if cfg.paged and self.mesh is not None:
            raise ValueError(
                "paged KV is not supported under a mesh: the shared page "
                "pool is not slot-partitionable across DP replicas (use "
                "paged=False / the dense layout)")
        self.paged = (cfg.paged if cfg.paged is not None
                      else self.mesh is None
                      and model.init_paged_cache is not None)
        if cfg.prefix_cache and not self.paged:
            raise ValueError("prefix_cache requires the paged KV layout")
        # slot state (host-authoritative): last emitted token per slot and
        # which slots hold a live request
        self._tok = np.zeros((cfg.batch, 1), np.int32)
        self._occupied: set[int] = set()
        self._kv: PagedKV | DenseKV | None = None
        self._prefix = None
        self._exec = self._build_exec()
        self._prefill_exec = self._build_prefill_exec()

    # -- the one decode dispatch ----------------------------------------------
    def _build_exec(self):
        """The stitch()-produced decode step every serving path shares.

        ``extra`` (family-specific decode inputs, e.g. encoder outputs) is a
        real traced argument, not a closure capture, so later calls' values
        flow through the stitched graph; a *structure* change is an ordinary
        signature drift and serves through jit.  Under a mesh the partition
        specs are derived per signature from the concrete cache pytree
        (leaf-name based slot specs); signatures with a non-empty ``extra``
        resolve to ``None`` — their slot layout is family-specific and not
        worth a wrong guess — which the exec layer serves via plain jit.
        """
        model = self.model
        mode = ("jit" if self.stitch_service is None
                else "stitch" if self.cfg.stitch_execute else "shadow")
        # python int closure constant: the paged decode slices its gathered
        # per-slot KV view back to max_len so the attention reduction shape
        # matches the dense layout bitwise (ignored by non-paged caches)
        kvl = self.cfg.max_len if self.paged else None

        def decode_step(params, cache, tok, extra):
            return model.decode_step(params, cache, tok, kv_limit=kvl, **extra)

        # eligibility covers only (cache, tok, extra): params are fixed for
        # an engine's lifetime, so the per-token drift check stays cheap
        elig = (1, 2, 3)
        if self.mesh is None:
            return stitch(decode_step, mode=mode, service=self.stitch_service,
                          eligibility_argnums=elig, name="decode_step")

        mesh, axes = self.mesh, self._slot_axes

        def in_specs(params, cache, tok, extra):
            if extra:
                return None
            from repro.models.sharding import slot_pspecs
            return (P(), slot_pspecs(cache, mesh, axes), P(axes, None), P())

        def out_specs(params, cache, tok, extra):
            from repro.models.sharding import slot_pspecs
            return (P(axes), slot_pspecs(cache, mesh, axes))

        return stitch(decode_step, mode=mode, service=self.stitch_service,
                      mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      eligibility_argnums=elig, name="decode_step")

    def _build_prefill_exec(self):
        """The stitched prefill dispatch: one StitchedFunction whose
        ``respecialize`` cap holds the live (bucket, extra-structure)
        specializations — each pow2 bucket traces its own graph under its
        own placement-keyed plan, LRU-bounded so a long-lived server with
        drifting prompt lengths cannot accumulate compiles without bound
        (the old per-bucket jit memo never evicted).  Admission prefills are
        per-request and unsharded even on mesh engines, so this path never
        takes the mesh."""
        model = self.model
        mode = ("jit" if self.stitch_service is None
                else "stitch" if self.cfg.stitch_execute else "shadow")

        def prefill_step(params, tokens, true_len, extra):
            return model.prefill(params, tokens, true_len=true_len, **extra)

        return stitch(prefill_step, mode=mode, service=self.stitch_service,
                      eligibility_argnums=(1, 2, 3),
                      respecialize=self.cfg.prefill_cache_size,
                      name="prefill")

    def _decode_dispatch(self, cache, tok, extra):
        """One decode step through the shared execution layer — stitched
        artifact when eligible, jit otherwise, polling the background
        upgrade each call (so a request stream upgrades mid-stream).  Both
        routes are DP-replica sharded when the engine has a mesh."""
        return self._exec(self.params, cache, jnp.asarray(tok), extra)

    @property
    def dp_replicas(self) -> int:
        """Replica count the decode batch is spread over (1 when unsharded)."""
        if self._slot_axes is None:
            return 1
        n = 1
        for a in self._slot_axes:
            n *= self.mesh.shape[a]
        return n

    # -- KV state --------------------------------------------------------------
    @property
    def kv(self) -> PagedKV | DenseKV:
        """Slot KV, built lazily (rect-only engines never allocate it)."""
        if self._kv is None:
            if self.paged:
                num_pages = self.cfg.num_pages
                if num_pages is None and self.cfg.prefix_cache:
                    # double the worst-case slot demand: without headroom
                    # every insert's pool pressure would immediately evict
                    # the entry the preceding prefill just registered
                    import math
                    pps = math.ceil(self.cfg.max_len / self.cfg.page_size)
                    num_pages = 2 * self.cfg.batch * pps + 1
                self._kv = PagedKV(self.model, self.cfg.batch,
                                   self.cfg.max_len, self.cfg.page_size,
                                   num_pages=num_pages)
                if self.cfg.prefix_cache:
                    from .prefix import PrefixCache
                    self._prefix = PrefixCache(
                        self._kv, max_entries=self.cfg.prefix_cache_entries)
                    # pool pressure evicts cold prefix entries before failing
                    self._kv.reclaim = self._prefix.evict_one
            else:
                self._kv = DenseKV(self.model, self.cfg.batch,
                                   self.cfg.max_len)
        return self._kv

    @property
    def prefix_cache(self):
        """The content-hashed prompt-KV cache, or None when disabled."""
        if self.cfg.prefix_cache and self._prefix is None:
            _ = self.kv                     # builds the cache alongside KV
        return self._prefix

    # -- stage 1: prefill ------------------------------------------------------
    def prefill(self, tokens, prompt_lens=None, extra=None,
                rid: int | None = None) -> Prefix:
        """Run a prompt batch (2-D, or a single 1-D prompt) through the
        bucketed prefill; returns the :class:`Prefix` that ``insert`` binds
        to a slot.  Single-row prompts first consult the prefix cache."""
        from .scheduler import ADMISSION_BUCKET
        extra = dict(extra or {})
        toks = np.asarray(tokens, np.int32)
        if toks.ndim == 1:
            toks = toks[None]
        B, Pn = toks.shape
        if Pn == 0:
            raise ValueError("prefill: empty prompt")
        lens = (np.full((B,), Pn, np.int32) if prompt_lens is None
                else np.asarray(prompt_lens, np.int32).reshape(-1))
        if lens.shape != (B,) or int(lens.max()) > Pn or int(lens.min()) < 1:
            raise ValueError(f"prompt_lens {lens!r} inconsistent with "
                             f"prompts of shape {toks.shape}")
        cacheable = (self.cfg.prefix_cache and B == 1 and not extra)
        if cacheable:
            hit = self.prefix_cache.lookup(toks[0, :int(lens[0])])
            if hit is not None:
                obs.event("serve.prefill", cat="serve",
                          rid=-1 if rid is None else rid,
                          prompt_len=int(lens[0]), cached=True)
                return hit
        pb = min(ADMISSION_BUCKET.bucket_dim(Pn), self.cfg.max_len)
        padded = np.zeros((B, pb), np.int32)
        padded[:, :Pn] = toks
        with obs.span("serve.prefill", cat="serve",
                      rid=-1 if rid is None else rid,
                      prompt_len=int(lens.max()), bucket=pb, batch=B,
                      cached=False):
            logits, cache = self._prefill_exec(
                self.params, jnp.asarray(padded), jnp.asarray(lens), extra)
        first = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int64)
        px = Prefix(lengths=lens, first_tokens=first, bucket=pb, kv=cache)
        if cacheable:
            self.prefix_cache.register(toks[0, :int(lens[0])], cache,
                                       row=0, first_token=int(first[0]),
                                       length=int(lens[0]))
        return px

    # -- stage 2: insert -------------------------------------------------------
    def insert(self, prefix: Prefix, slot: int, row: int = 0) -> None:
        """Bind row ``row`` of a prefix to decode slot ``slot``: a
        page-table splice (shared pages refcounted, tail copied) for a
        cached prefix, a KV splice otherwise."""
        if not 0 <= slot < self.cfg.batch:
            raise IndexError(f"slot {slot} out of range 0..{self.cfg.batch-1}")
        if slot in self._occupied:
            raise RuntimeError(f"slot {slot} already holds a request "
                               f"(release it first)")
        true_len = int(prefix.lengths[row])
        if prefix.pages is not None:
            self.kv.insert_shared(prefix.pages, prefix.tail, true_len, slot)
        else:
            self.kv.insert_kv(prefix.kv, row, true_len, slot)
        self._tok[slot, 0] = int(prefix.first_tokens[row])
        self._occupied.add(slot)

    # -- stage 3: generate -----------------------------------------------------
    def generate_step(self, steps: int = 1, extra: dict | None = None
                      ) -> np.ndarray:
        """Advance every occupied slot ``steps`` greedy tokens; returns the
        (slots, steps) token matrix (free slots' rows are ride-along noise).
        One host readback per call regardless of ``steps``."""
        if not self._occupied:
            raise RuntimeError("generate_step: no occupied slots "
                               "(insert a prefix first)")
        extra = dict(extra or {})
        occ = sorted(self._occupied)
        if self.paged:
            for s in occ:
                self.kv.ensure(s, steps)
        cache = self.kv.decode_cache()
        # copy: jnp.asarray may alias the numpy buffer, which is mutated
        # below while the chunk is still in flight on some backends
        tok = jnp.asarray(self._tok.copy())
        toks_dev = []
        for _ in range(steps):
            logits, cache = self._decode_dispatch(cache, tok, extra)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            toks_dev.append(tok)
        self.kv.absorb(cache)
        self.kv.advance(occ, steps)
        out = np.asarray(jnp.concatenate(toks_dev, axis=1))
        for s in occ:
            self._tok[s, 0] = int(out[s, -1])
        return out

    def release(self, slot: int) -> None:
        """Free a finished slot: paged engines return its pages to the
        allocator (decref for prefix-shared ones) immediately."""
        self.kv.free(slot)
        self._tok[slot, 0] = 0
        self._occupied.discard(slot)
        self._debug_audit(f"release(slot={slot})")

    # -- KV conservation audit (repro.analysis pass 4) -------------------------
    def audit_kv(self) -> list:
        """Snapshot the page allocator + page table + prefix cache and run
        the static conservation audit; returns the ``Finding`` list (empty
        for a healthy pool, or on dense/unbuilt KV where there is nothing
        to audit)."""
        if not self.paged or self._kv is None:
            return []
        from repro.analysis import audit_kv, snapshot
        return audit_kv(snapshot(kv=self._kv, prefix=self._prefix))

    def _debug_audit(self, what: str) -> None:
        if not self.cfg.debug_kv:
            return
        from repro.analysis import VerificationError, errors
        bad = errors(self.audit_kv())
        if bad:
            raise VerificationError(f"KV audit after {what}", bad)

    @property
    def occupied(self) -> frozenset[int]:
        return frozenset(self._occupied)

    # -- observability ---------------------------------------------------------
    @property
    def stitch_status(self) -> str | None:
        """None before the first decode (or without a service), else the
        exec layer's status: hit | miss | pending | failed | error."""
        if self.stitch_service is None:
            return None
        return self._exec.status

    @property
    def _stitch(self) -> dict | None:
        """Test/debug view of the active stitched decode specialization."""
        sp = self._exec._active
        if sp is None:
            return None
        if sp.graph is None:
            return {}
        return {"graph": sp.graph, "compiled": sp.compiled,
                "placement": sp.placement, "sharded": sp.sharded,
                "executable": sp.executable}

    def stitch_report(self) -> dict:
        """The decode step's :data:`repro.obs.EXEC_REPORT_SCHEMA` dict —
        upgrade status, plan stats, call counts, cache hit rates, and every
        background-compile failure (also in pure-jit mode)."""
        return self._exec.report()

    def land_plans(self, timeout: float | None = None) -> int:
        """Join background compiles for decode AND every live prefill
        specialization; returns how many still lack a stitched plan
        (benches use this before reading kernel counts)."""
        return (self._exec.land_plans(timeout)
                + self._prefill_exec.land_plans(timeout))

    def report(self) -> dict:
        """Engine-wide report: decode + prefill exec reports (the prefill
        one carries per-bucket placement-keyed plans), KV/page-pool state,
        prefix-cache hit rates, and the bounded prefill-memo size."""
        prefill = self._prefill_exec.report()
        entries = (prefill["specializations"] or
                   prefill.get("jit_specializations", 0))
        return {
            "decode": self._exec.report(),
            "prefill": prefill,
            "kv": self._kv.report() if self._kv is not None else None,
            "prefix_cache": (self._prefix.report()
                             if self._prefix is not None else None),
            "cache": {"prefill_entries": entries,
                      "prefill_cap": self.cfg.prefill_cache_size},
        }

    # -- continuous batching (shim over the three-stage API) -------------------
    @property
    def scheduler(self):
        """Lazy slot scheduler driving prefill → insert → generate_step."""
        if self._scheduler is None:
            from .scheduler import Scheduler, SchedulerConfig
            cfg = SchedulerConfig(
                slots=self.cfg.batch, max_len=self.cfg.max_len,
                max_new_tokens=self.cfg.max_new_tokens, eos_id=self.cfg.eos_id)
            self._scheduler = Scheduler(self, cfg)
        return self._scheduler

    def submit(self, prompt, max_new_tokens: int | None = None, **kw) -> int:
        """Enqueue one request (1-D prompt); returns its request id."""
        return self.scheduler.submit(prompt, max_new_tokens=max_new_tokens, **kw)

    def step(self):
        """Run one scheduler step (refill -> batched decode -> evict);
        returns its :class:`~repro.serve.metrics.StepMetrics`."""
        return self.scheduler.step()

    def drain(self, max_steps: int | None = None):
        """Step until all submitted requests finish; returns the
        :class:`~repro.serve.queue.FinishedRequest` list in completion order."""
        out = self.scheduler.drain(max_steps=max_steps)
        self._debug_audit("drain()")
        return out

    def serve_report(self) -> dict:
        """Aggregate scheduler metrics (empty if continuous mode unused)."""
        if self._scheduler is None:
            return {}
        return self._scheduler.metrics.summary()

    # -- static serving (shims) ------------------------------------------------
    def generate(self, prompts: np.ndarray, prompt_lens=None, **extra) -> np.ndarray:
        """prompts: (batch, prompt_len) int32 -> (batch, max_new_tokens).

        With ``prompt_lens`` (per-row true lengths) the batch stages through
        the three-stage API: one bucketed prefill, per-row slot inserts, a
        chunked generate, then release — the per-request reference the
        scheduler is tested token-for-token against.  Without it, the
        legacy rectangular cache-splice path still serves (deprecated; it
        bypasses paged KV, the prefix cache, and the stitched prefill)."""
        B, Pn = prompts.shape
        assert B == self.cfg.batch
        if prompt_lens is not None:
            return self._generate_staged(prompts, prompt_lens, extra)
        global _LEGACY_RECT_WARNED
        if not _LEGACY_RECT_WARNED:
            _LEGACY_RECT_WARNED = True
            warnings.warn(
                "Engine.generate(prompts) without prompt_lens uses the "
                "legacy rectangular cache-splice path; migrate to "
                "prefill()/insert()/generate_step() (or pass prompt_lens) — "
                "see the README 'Serving' section", DeprecationWarning,
                stacklevel=2)
        logits, cache = self.model.prefill(
            self.params, jnp.asarray(prompts, jnp.int32), **extra)
        # decode cache from prefill may be shorter than max_len; re-home it
        if "k" in cache and cache["k"].ndim == 5 and cache["k"].shape[2] < self.cfg.max_len:
            pad = self.cfg.max_len - cache["k"].shape[2]
            cache = dict(cache)
            cache["k"] = jnp.pad(cache["k"], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
            cache["v"] = jnp.pad(cache["v"], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])

        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out = []
        for _ in range(self.cfg.max_new_tokens):
            out.append(np.asarray(tok))
            logits, cache = self._decode_dispatch(cache, tok, extra)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return np.concatenate(out, axis=1)

    def _generate_staged(self, prompts: np.ndarray, prompt_lens, extra) -> np.ndarray:
        from .scheduler import RAGGED_FAMILIES
        if self.model.cfg.family not in RAGGED_FAMILIES:
            raise NotImplementedError(
                f"ragged generate (prompt_lens) supports families "
                f"{RAGGED_FAMILIES}, got {self.model.cfg.family!r}")
        if self._occupied:
            raise RuntimeError(
                "generate(prompt_lens=...) needs an idle engine; "
                f"slots {sorted(self._occupied)} hold live requests")
        B, _ = prompts.shape
        px = self.prefill(prompts, prompt_lens=prompt_lens, extra=extra)
        for row in range(B):
            self.insert(px, slot=row, row=row)
        out = [px.first_tokens.astype(np.int32)[:, None]]
        if self.cfg.max_new_tokens > 1:
            out.append(self.generate_step(steps=self.cfg.max_new_tokens - 1,
                                          extra=extra))
        for row in range(B):
            self.release(row)
        return np.concatenate(out, axis=1)
