"""Batched serving engine: prefill + greedy decode over a KV cache.

Two serving modes share this engine:

* **Static batch** (:meth:`Engine.generate`) — requests are padded into one
  fixed batch, prefilled once, then decoded lock-step.  With
  ``prompt_lens`` the batch may be ragged: prompts are padded to a pow2
  bucket, logits gathered at each row's true last position, and the decode
  runs with a per-row length vector.
* **Continuous batching** (:meth:`Engine.submit` / :meth:`Engine.step` /
  :meth:`Engine.drain`) — a slot-based
  :class:`~repro.serve.scheduler.Scheduler` admits queued requests into a
  fixed-slot decode batch, interleaves bucketed prefills with ongoing
  decode, evicts slots on EOS / max-token completion and refills them
  immediately, so one long request never stalls the batch.

Fusion-stitching integration (miss-then-upgrade): when constructed with a
:class:`repro.cache.CompilationService`, the engine traces the decode step
to StitchIR on first use and asks the service for an executable.  A cache
hit replays the stored fusion plan instantly; a miss returns the cheap
XLA-mode fallback *immediately* while the full stitch pipeline (pattern
generation, ILP, tuning) runs on a background thread and populates the
cache — the engine upgrades to the stitched plan on a later ``generate``
call, so no request ever waits on the tuner.  Decoding executes through the
stitched artifact only when ``ServeConfig.stitch_execute`` is set (the
interpret-mode reference path); otherwise the jitted step keeps serving and
the stitched plan powers kernel-count/step-time reporting and cache warmth.

DP-replica dispatch (``mesh=``): the slot dimension of the batched decode
step is sharded over the mesh's data-parallel axes (the whole mesh when the
slot count divides it), so the continuous-batching scheduler's one batched
step per iteration spreads its slots across replicas — each replica decodes
its slice of the slots against its slice of the KV cache, with the params
gathered in-body (they may live TP-sharded at rest).  Both the jitted and
the stitched decode route through ``shard_map``; the stitched executable is
traced and solved at *shard-local* shapes and cached under a mesh-keyed
placement.  Admission prefills stay per-request (B=1) and unsharded.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.api import Model


def _avals(tree) -> tuple:
    """(shape, dtype) per leaf — Python scalars get a scalar stand-in."""
    return tuple(
        (getattr(x, "shape", ()), str(getattr(x, "dtype", type(x).__name__)))
        for x in jax.tree_util.tree_leaves(tree))


@dataclass
class ServeConfig:
    batch: int           # static batch size == continuous-batching slot count
    max_len: int
    max_new_tokens: int = 32
    eos_id: int = -1     # -1: never stop early (fixed-length benchmark mode)
    stitch_execute: bool = False   # run decode through the stitched artifact


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 stitch_service=None, mesh: Mesh | None = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step)
        self.stitch_service = stitch_service
        self.stitch_status: str | None = None   # None|hit|miss|pending|error
        self._stitch: dict | None = None
        self._scheduler = None
        self.mesh = mesh if (mesh is not None and mesh.size > 1) else None
        self._slot_axes: tuple[str, ...] | None = None
        self._sharded_decode: dict = {}   # cache avals -> jitted shard_map step
        if self.mesh is not None:
            from repro.models.sharding import batch_shard_axes
            axes = batch_shard_axes(self.mesh, cfg.batch)
            if not axes:
                raise ValueError(
                    f"slots/batch={cfg.batch} does not divide across mesh "
                    f"{dict(self.mesh.shape)}: the DP-replica dispatch needs "
                    f"the slot count to be a multiple of the DP size (or of "
                    f"the whole mesh)")
            self._slot_axes = axes
        self._ragged_prefill = jax.jit(
            lambda p, toks, tl, ml, **kw: model.prefill(
                p, toks, true_len=tl, max_len=ml, **kw),
            static_argnames=("ml",))

    @property
    def dp_replicas(self) -> int:
        """Replica count the decode batch is spread over (1 when unsharded)."""
        if self._slot_axes is None:
            return 1
        n = 1
        for a in self._slot_axes:
            n *= self.mesh.shape[a]
        return n

    # -- DP-replica jitted decode ---------------------------------------------
    def _sharded_decode_fn(self, cache):
        """Jitted ``shard_map`` decode with the slot dim split over the DP
        replicas; built once per cache structure (the body gathers params,
        so TP-at-rest storage still works — no in-model collectives)."""
        from repro.models.sharding import slot_pspecs
        # keyed on avals, not just treedef: the static path's cache carries a
        # scalar "length" while the scheduler's is a per-slot vector — same
        # structure, different slot specs.  A dict (not a single slot) so an
        # engine alternating generate() and step() keeps both compiles warm.
        key = (jax.tree_util.tree_structure(cache), _avals(cache))
        fn = self._sharded_decode.get(key)
        if fn is None:
            cspecs = slot_pspecs(cache, self.mesh, self._slot_axes)
            tspec = P(self._slot_axes, None)
            fn = jax.jit(shard_map(
                lambda p, c, t: self.model.decode_step(p, c, t),
                mesh=self.mesh, in_specs=(P(), cspecs, tspec),
                out_specs=(P(self._slot_axes), cspecs), check_rep=False))
            self._sharded_decode[key] = fn
        return fn

    def _jit_decode(self, cache, tok, extra):
        """One jitted decode step — DP-replica sharded when a mesh is set
        (extra inputs force the unsharded path: their slot layout is
        family-specific and not worth a wrong guess)."""
        if self.mesh is not None and not extra:
            return self._sharded_decode_fn(cache)(self.params, cache, tok)
        return self._decode(self.params, cache, tok, **extra)

    # -- fusion-stitching plumbing -------------------------------------------
    def _prepare_stitch(self, cache, tok, extra) -> None:
        from repro.cache.signature import compute_signature, placement_key
        from repro.core.trace import trace_to_graph

        # extra is traced as a real input (not baked into the closure) so
        # later calls' values — e.g. per-request encoder outputs — flow
        # through the stitched graph; only a *structure* change forces the
        # jitted fallback (checked per call in generate()).
        def step(params, cache, tok, extra):
            return self.model.decode_step(params, cache, tok, **extra)

        # under a mesh the decode graph is traced at SHARD-LOCAL shapes: the
        # executable runs inside shard_map with the slot dim split over the
        # DP replicas, and its cache key carries the mesh+spec placement
        sharded = self.mesh is not None and not extra
        placement, cspecs, tspec = "", None, None
        trace_cache, trace_tok = cache, tok
        if sharded:
            from repro.models.sharding import local_avals, slot_pspecs
            cspecs = slot_pspecs(cache, self.mesh, self._slot_axes)
            tspec = P(self._slot_axes, None)
            trace_cache = local_avals(cache, cspecs, self.mesh)
            trace_tok = local_avals(jnp.asarray(tok), tspec, self.mesh)
            placement = placement_key(self.mesh, (P(), cspecs, tspec))
        try:
            g, names = trace_to_graph(step, self.params, trace_cache,
                                      trace_tok, extra, name="decode_step")
            compiled, status = self.stitch_service.compile_or_fallback(
                g, placement=placement)
            out_tree = jax.tree_util.tree_structure(
                jax.eval_shape(step, self.params, trace_cache, trace_tok,
                               extra))
        except Exception:
            self.stitch_status = "error"
            self._stitch = {}
            return
        executable = out_tree.num_leaves == len(g.outputs)
        # eligibility keys cover only (cache, tok, extra): params are fixed
        # for an engine's lifetime, so the per-step check stays cheap.
        # in_avals stay GLOBAL — the shard_map boundary does the slicing.
        self._stitch = {"graph": g, "names": names, "out_tree": out_tree,
                        "compiled": compiled, "executable": executable,
                        "in_tree": jax.tree_util.tree_structure(
                            (cache, tok, extra)),
                        "in_avals": _avals((cache, tok, extra)),
                        "sig": compute_signature(g),
                        "sharded": sharded, "cspecs": cspecs, "tspec": tspec,
                        "placement": placement,
                        "compiler": self.stitch_service.compiler(
                            "stitch", placement)}
        self.stitch_status = status

    def _refresh_stitch(self) -> None:
        """Upgrade the fallback executable once the background compile of the
        stitched plan has landed in the cache.  The signature and compiler
        are memoized from trace time, so a still-pending poll costs a dict
        probe, not a graph hash."""
        if not self._stitch:
            return
        svc = self.stitch_service
        hit = svc.cache.lookup(self._stitch["graph"], self._stitch["compiler"],
                               sig=self._stitch["sig"], count=False)
        if hit is not None:
            self._stitch["compiled"] = hit
            self.stitch_status = "hit"
        else:
            # re-kick if our background compile was deferred (worker cap) or
            # died — otherwise this engine would serve the fallback forever
            svc.ensure_compiling(self._stitch["graph"], sig=self._stitch["sig"],
                                 placement=self._stitch.get("placement", ""))

    def _stitch_exec(self, params, cache, tok, extra):
        st = self._stitch
        leaves = jax.tree_util.tree_leaves((params, cache, tok, extra))
        env = dict(zip(st["names"], leaves))
        outs = st["compiled"](env)
        flat = [outs[o] for o in st["graph"].outputs]
        return jax.tree_util.tree_unflatten(st["out_tree"], flat)

    def _stitch_decode(self, cache, tok, extra):
        st = self._stitch
        if st.get("sharded"):
            # per-shard stitched execution: the executable was compiled at
            # shard-local shapes; the shard_map boundary slices the slots.
            # The jitted wrapper is memoized per executable — rebuilt only
            # when an upgrade swaps st["compiled"] — so steady-state decode
            # is a jit-cache hit per token, not a retrace.
            if st.get("_sm_for") is not st["compiled"]:
                st["_sm_fn"] = jax.jit(shard_map(
                    lambda p, c, t: self._stitch_exec(p, c, t, {}),
                    mesh=self.mesh, in_specs=(P(), st["cspecs"], st["tspec"]),
                    out_specs=(P(self._slot_axes), st["cspecs"]),
                    check_rep=False))
                st["_sm_for"] = st["compiled"]
            return st["_sm_fn"](self.params, cache, jnp.asarray(tok))
        return self._stitch_exec(self.params, cache, tok, extra)

    def stitch_report(self) -> dict:
        """Observability: upgrade status, plan stats, cache hit rates."""
        out: dict[str, Any] = {"status": self.stitch_status}
        if self._stitch and self._stitch.get("compiled") is not None:
            s = self._stitch["compiled"].stats
            out["plan"] = {
                "mode": s.mode, "n_kernels": s.n_kernels, "n_ops": s.n_ops,
                "pallas_groups": s.pallas_groups,
                "modeled_time": s.modeled_time,
                "cache_status": s.cache_status,
            }
        if self.stitch_service is not None:
            out["cache"] = self.stitch_service.cache.report()
            out["service_error"] = self.stitch_service.last_error
        return out

    def _poll_stitch(self, cache, tok, extra) -> None:
        """Trace-on-first-use, then poll the background upgrade while the
        fallback is still serving."""
        if self.stitch_service is None:
            return
        if self._stitch is None:
            self._prepare_stitch(cache, tok, extra)
        elif self.stitch_status in ("miss", "pending"):
            self._refresh_stitch()

    def _use_stitched(self, cache, tok, extra) -> bool:
        # the stitched executable is shape-specialized at trace time; any
        # structure OR leaf-shape drift (e.g. per-request encoder outputs of
        # a new length) falls back to the jitted step for this call
        if not (self.cfg.stitch_execute
                and self._stitch
                and self._stitch.get("executable")
                and self._stitch.get("compiled") is not None):
            return False
        inputs = (cache, tok, extra)
        return (jax.tree_util.tree_structure(inputs) == self._stitch["in_tree"]
                and _avals(inputs) == self._stitch["in_avals"])

    def _decode_dispatch(self, cache, tok, extra):
        """One decode step through the stitched artifact when eligible,
        else the jitted step — polling the upgrade each call (the scheduler
        path, so a request stream upgrades mid-stream).  Both routes are
        DP-replica sharded when the engine has a mesh."""
        if self.stitch_service is None:
            return self._jit_decode(cache, tok, extra)
        self._poll_stitch(cache, tok, extra)
        if self._use_stitched(cache, tok, extra):
            return self._stitch_decode(cache, tok, extra)
        return self._jit_decode(cache, tok, extra)

    # -- continuous batching ---------------------------------------------------
    @property
    def scheduler(self):
        """Lazy slot scheduler over this engine's decode dispatch."""
        if self._scheduler is None:
            from .scheduler import Scheduler, SchedulerConfig
            cfg = SchedulerConfig(
                slots=self.cfg.batch, max_len=self.cfg.max_len,
                max_new_tokens=self.cfg.max_new_tokens, eos_id=self.cfg.eos_id)
            self._scheduler = Scheduler(
                self.model, self.params, cfg,
                decode_fn=lambda cache, tok: self._decode_dispatch(cache, tok, {}),
                status_fn=lambda: self.stitch_status)
        return self._scheduler

    def submit(self, prompt, max_new_tokens: int | None = None, **kw) -> int:
        """Enqueue one request (1-D prompt); returns its request id."""
        return self.scheduler.submit(prompt, max_new_tokens=max_new_tokens, **kw)

    def step(self):
        """Run one scheduler step (refill -> batched decode -> evict);
        returns its :class:`~repro.serve.metrics.StepMetrics`."""
        return self.scheduler.step()

    def drain(self, max_steps: int | None = None):
        """Step until all submitted requests finish; returns the
        :class:`~repro.serve.queue.FinishedRequest` list in completion order."""
        return self.scheduler.drain(max_steps=max_steps)

    def serve_report(self) -> dict:
        """Aggregate scheduler metrics (empty if continuous mode unused)."""
        if self._scheduler is None:
            return {}
        return self._scheduler.metrics.summary()

    # -- static serving loop ---------------------------------------------------
    def generate(self, prompts: np.ndarray, prompt_lens=None, **extra) -> np.ndarray:
        """prompts: (batch, prompt_len) int32 -> (batch, max_new_tokens).

        ``prompt_lens`` (per-row true lengths) switches to the ragged static
        path: prompts are padded to the same pow2 bucket the continuous
        scheduler admits at, logits come from each row's true last position,
        and the decode runs with a per-row length vector — the per-request
        reference the scheduler is tested token-for-token against."""
        B, P = prompts.shape
        assert B == self.cfg.batch
        if prompt_lens is not None:
            return self._generate_ragged(prompts, prompt_lens, extra)
        logits, cache = self.model.prefill(
            self.params, jnp.asarray(prompts, jnp.int32), **extra)
        # decode cache from prefill may be shorter than max_len; re-home it
        if "k" in cache and cache["k"].ndim == 5 and cache["k"].shape[2] < self.cfg.max_len:
            pad = self.cfg.max_len - cache["k"].shape[2]
            cache = dict(cache)
            cache["k"] = jnp.pad(cache["k"], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
            cache["v"] = jnp.pad(cache["v"], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])

        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return self._decode_loop(cache, tok, extra)

    def _decode_loop(self, cache, tok, extra) -> np.ndarray:
        """Lock-step greedy decode for ``max_new_tokens`` steps; the stitch
        eligibility decision is made once per call (shapes are loop-
        invariant)."""
        self._poll_stitch(cache, tok, extra)
        use_stitched = self._use_stitched(cache, tok, extra)
        out = []
        for _ in range(self.cfg.max_new_tokens):
            out.append(np.asarray(tok))
            if use_stitched:
                logits, cache = self._stitch_decode(cache, tok, extra)
            else:
                logits, cache = self._jit_decode(cache, tok, extra)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return np.concatenate(out, axis=1)

    def _generate_ragged(self, prompts: np.ndarray, prompt_lens, extra) -> np.ndarray:
        from .scheduler import ADMISSION_BUCKET, RAGGED_FAMILIES
        if self.model.cfg.family not in RAGGED_FAMILIES:
            raise NotImplementedError(
                f"ragged generate (prompt_lens) supports families "
                f"{RAGGED_FAMILIES}, got {self.model.cfg.family!r}")
        B, P = prompts.shape
        lens = np.asarray(prompt_lens, np.int32).reshape(-1)
        assert lens.shape == (B,) and int(lens.max()) <= P
        # pad to the scheduler's admission bucket so a batch=1 ragged run is
        # the scheduler's bitwise reference
        pb = min(ADMISSION_BUCKET.bucket_dim(P), self.cfg.max_len)
        padded = np.zeros((B, pb), np.int32)
        padded[:, :P] = prompts
        logits, cache = self._ragged_prefill(
            self.params, jnp.asarray(padded), jnp.asarray(lens),
            ml=self.cfg.max_len, **extra)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return self._decode_loop(cache, tok, extra)
