"""Batched serving engine: prefill + greedy decode over a KV cache.

Single-host reference implementation of the serving loop the decode cells
lower: requests are padded into a fixed batch, prefilled once, then decoded
token-by-token with the jitted ``decode_step``.

Fusion-stitching integration (miss-then-upgrade): when constructed with a
:class:`repro.cache.CompilationService`, the engine traces the decode step
to StitchIR on first use and asks the service for an executable.  A cache
hit replays the stored fusion plan instantly; a miss returns the cheap
XLA-mode fallback *immediately* while the full stitch pipeline (pattern
generation, ILP, tuning) runs on a background thread and populates the
cache — the engine upgrades to the stitched plan on a later ``generate``
call, so no request ever waits on the tuner.  Decoding executes through the
stitched artifact only when ``ServeConfig.stitch_execute`` is set (the
interpret-mode reference path); otherwise the jitted step keeps serving and
the stitched plan powers kernel-count/step-time reporting and cache warmth.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


def _avals(tree) -> tuple:
    """(shape, dtype) per leaf — Python scalars get a scalar stand-in."""
    return tuple(
        (getattr(x, "shape", ()), str(getattr(x, "dtype", type(x).__name__)))
        for x in jax.tree_util.tree_leaves(tree))


@dataclass
class ServeConfig:
    batch: int
    max_len: int
    max_new_tokens: int = 32
    eos_id: int = -1     # -1: never stop early (fixed-length benchmark mode)
    stitch_execute: bool = False   # run decode through the stitched artifact


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 stitch_service=None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step)
        self.stitch_service = stitch_service
        self.stitch_status: str | None = None   # None|hit|miss|pending|error
        self._stitch: dict | None = None

    # -- fusion-stitching plumbing -------------------------------------------
    def _prepare_stitch(self, cache, tok, extra) -> None:
        from repro.cache.signature import compute_signature
        from repro.core.trace import trace_to_graph

        # extra is traced as a real input (not baked into the closure) so
        # later calls' values — e.g. per-request encoder outputs — flow
        # through the stitched graph; only a *structure* change forces the
        # jitted fallback (checked per call in generate()).
        def step(params, cache, tok, extra):
            return self.model.decode_step(params, cache, tok, **extra)

        try:
            g, names = trace_to_graph(step, self.params, cache, tok, extra,
                                      name="decode_step")
            compiled, status = self.stitch_service.compile_or_fallback(g)
            out_tree = jax.tree_util.tree_structure(
                jax.eval_shape(step, self.params, cache, tok, extra))
        except Exception:
            self.stitch_status = "error"
            self._stitch = {}
            return
        executable = out_tree.num_leaves == len(g.outputs)
        self._stitch = {"graph": g, "names": names, "out_tree": out_tree,
                        "compiled": compiled, "executable": executable,
                        "in_tree": jax.tree_util.tree_structure(
                            (self.params, cache, tok, extra)),
                        "in_avals": _avals((self.params, cache, tok, extra)),
                        "sig": compute_signature(g),
                        "compiler": self.stitch_service.compiler("stitch")}
        self.stitch_status = status

    def _refresh_stitch(self) -> None:
        """Upgrade the fallback executable once the background compile of the
        stitched plan has landed in the cache.  The signature and compiler
        are memoized from trace time, so a still-pending poll costs a dict
        probe, not a graph hash."""
        if not self._stitch:
            return
        svc = self.stitch_service
        hit = svc.cache.lookup(self._stitch["graph"], self._stitch["compiler"],
                               sig=self._stitch["sig"], count=False)
        if hit is not None:
            self._stitch["compiled"] = hit
            self.stitch_status = "hit"
        else:
            # re-kick if our background compile was deferred (worker cap) or
            # died — otherwise this engine would serve the fallback forever
            svc.ensure_compiling(self._stitch["graph"], sig=self._stitch["sig"])

    def _stitch_decode(self, cache, tok, extra):
        st = self._stitch
        leaves = jax.tree_util.tree_leaves((self.params, cache, tok, extra))
        env = dict(zip(st["names"], leaves))
        outs = st["compiled"](env)
        flat = [outs[o] for o in st["graph"].outputs]
        return jax.tree_util.tree_unflatten(st["out_tree"], flat)

    def stitch_report(self) -> dict:
        """Observability: upgrade status, plan stats, cache hit rates."""
        out: dict[str, Any] = {"status": self.stitch_status}
        if self._stitch and self._stitch.get("compiled") is not None:
            s = self._stitch["compiled"].stats
            out["plan"] = {
                "mode": s.mode, "n_kernels": s.n_kernels, "n_ops": s.n_ops,
                "pallas_groups": s.pallas_groups,
                "modeled_time": s.modeled_time,
                "cache_status": s.cache_status,
            }
        if self.stitch_service is not None:
            out["cache"] = self.stitch_service.cache.report()
            out["service_error"] = self.stitch_service.last_error
        return out

    # -- serving loop ---------------------------------------------------------
    def generate(self, prompts: np.ndarray, **extra) -> np.ndarray:
        """prompts: (batch, prompt_len) int32 -> (batch, max_new_tokens)."""
        B, P = prompts.shape
        assert B == self.cfg.batch
        logits, cache = self.model.prefill(
            self.params, jnp.asarray(prompts, jnp.int32), **extra)
        # decode cache from prefill may be shorter than max_len; re-home it
        if "k" in cache and cache["k"].ndim == 5 and cache["k"].shape[2] < self.cfg.max_len:
            pad = self.cfg.max_len - cache["k"].shape[2]
            cache = dict(cache)
            cache["k"] = jnp.pad(cache["k"], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
            cache["v"] = jnp.pad(cache["v"], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])

        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if self.stitch_service is not None:
            if self._stitch is None:
                self._prepare_stitch(cache, tok, extra)
            elif self.stitch_status in ("miss", "pending"):
                self._refresh_stitch()
        # the stitched executable is shape-specialized at trace time; any
        # structure OR leaf-shape drift (e.g. per-request encoder outputs of
        # a new length) falls back to the jitted step for this call
        inputs = (self.params, cache, tok, extra)
        use_stitched = (
            self.cfg.stitch_execute
            and self._stitch
            and self._stitch.get("executable")
            and self._stitch.get("compiled") is not None
            and jax.tree_util.tree_structure(inputs) == self._stitch["in_tree"]
            and _avals(inputs) == self._stitch["in_avals"]
        )

        out = []
        for _ in range(self.cfg.max_new_tokens):
            out.append(np.asarray(tok))
            if use_stitched:
                logits, cache = self._stitch_decode(cache, tok, extra)
            else:
                logits, cache = self._decode(self.params, cache, tok, **extra)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return np.concatenate(out, axis=1)
