"""Batched serving engine: prefill + greedy decode over a KV cache.

Single-host reference implementation of the serving loop the decode cells
lower: requests are padded into a fixed batch, prefilled once, then decoded
token-by-token with the jitted ``decode_step``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclass
class ServeConfig:
    batch: int
    max_len: int
    max_new_tokens: int = 32
    eos_id: int = -1     # -1: never stop early (fixed-length benchmark mode)


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step)

    def generate(self, prompts: np.ndarray, **extra) -> np.ndarray:
        """prompts: (batch, prompt_len) int32 -> (batch, max_new_tokens)."""
        B, P = prompts.shape
        assert B == self.cfg.batch
        logits, cache = self.model.prefill(
            self.params, jnp.asarray(prompts, jnp.int32), **extra)
        # decode cache from prefill may be shorter than max_len; re-home it
        if "k" in cache and cache["k"].ndim == 5 and cache["k"].shape[2] < self.cfg.max_len:
            pad = self.cfg.max_len - cache["k"].shape[2]
            cache = dict(cache)
            cache["k"] = jnp.pad(cache["k"], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
            cache["v"] = jnp.pad(cache["v"], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])

        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        for _ in range(self.cfg.max_new_tokens):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok, **extra)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return np.concatenate(out, axis=1)
