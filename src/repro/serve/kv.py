"""KV state behind the serving engine's ``prefill -> insert -> generate``
stages: fixed-size paged blocks with a free-list allocator, plus the legacy
dense per-slot rectangle behind the same interface.

Paged layout
------------
One shared pool of ``num_pages`` pages per layer (page 0 is a reserved
*sink*: unallocated page-table entries point there, so ride-along writes
from free slots can never corrupt a live page).  Each slot owns an ordered
page list; the host-side page table (slots, pages_per_slot) int32 maps
logical page -> pool page and ships to the device before every decode
chunk.  Eviction frees pages back to the free list instead of abandoning a
``max_len`` rectangle, and :meth:`PagedKV.insert_shared` makes a prefix-
cache hit a page-table splice — shared full pages are refcounted, only the
partial tail page (where decode writes land) is copied per slot.

Numerics: the decode step gathers each slot's pages into a dense view and
slices it back to ``max_len`` (see ``kv_limit`` in the model layer), so
paged serving is token-for-token — bitwise — equal to the dense layout.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PageAllocator", "PageExhausted", "PagedKV", "DenseKV", "Prefix"]


class PageExhausted(RuntimeError):
    """The page pool has fewer free pages than an allocation needs."""


@dataclass
class Prefix:
    """Result of :meth:`Engine.prefill` — everything ``insert`` needs.

    Exactly one of ``kv`` (bucketed dense prefill cache, possibly B rows)
    or ``pages``/``tail`` (prefix-cache materialization, B=1: shared full
    pages + the partial-tail page KV) is set.
    """
    lengths: np.ndarray                  # (B,) true prompt lengths
    first_tokens: np.ndarray             # (B,) greedy token at each last position
    bucket: int                          # padded prefill length (pow2 bucket)
    kv: dict | None = None               # {"k": (L,B,pb,H,dh), "v": ..., "length"}
    pages: list[int] | None = None       # shared full pages (prefix cache)
    tail: tuple | None = None            # (k, v): (L, page_size, H, dh) device
    cached: bool = False                 # True when served from the PrefixCache

    @property
    def batch(self) -> int:
        return int(len(self.lengths))


class PageAllocator:
    """Free-list page allocator with refcounts for shared (prefix) pages.

    Page 0 is never handed out — it is the sink page free slots' tables
    point at.  ``alloc`` is all-or-nothing; ``free`` decrefs and returns a
    page to the free list when its last owner lets go, so the list reuses
    recently-freed pages first (LIFO).
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is the sink)")
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))   # pop() -> page 1 first
        self._refs: dict[int, int] = {}
        self.peak_used = 0

    @property
    def used(self) -> int:
        return len(self._refs)

    @property
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        if n > len(self._free):
            raise PageExhausted(
                f"need {n} page(s), {len(self._free)} free of "
                f"{self.num_pages - 1} usable")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        self.peak_used = max(self.peak_used, self.used)
        return pages

    def incref(self, pages) -> None:
        for p in pages:
            self._refs[p] += 1

    def free(self, pages) -> None:
        for p in pages:
            r = self._refs[p] - 1
            if r:
                self._refs[p] = r
            else:
                del self._refs[p]
                self._free.append(p)

    def report(self) -> dict:
        return {"num_pages": self.num_pages - 1, "used": self.used,
                "free": self.free_count, "peak_used": self.peak_used}


class PagedKV:
    """Paged slot KV: page pool on device, page tables on the host.

    ``reclaim`` (set by the engine when a prefix cache is attached) is
    called on pool pressure; it should release at least one page and return
    True, or False when nothing can be evicted.
    """

    def __init__(self, model, slots: int, max_len: int, page_size: int,
                 num_pages: int | None = None,
                 reclaim: Callable[[], bool] | None = None):
        if model.init_paged_cache is None:
            raise NotImplementedError(
                f"family {model.cfg.family!r} has no paged KV layout")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_slot = math.ceil(max_len / page_size)
        # worst case (every slot filled to max_len) + the sink page, unless
        # the caller over/under-subscribes explicitly
        self.num_pages = (num_pages if num_pages is not None
                          else slots * self.pages_per_slot + 1)
        self.allocator = PageAllocator(self.num_pages)
        self.reclaim = reclaim
        self.pool = model.init_paged_cache(self.num_pages, page_size)
        self.table = np.zeros((slots, self.pages_per_slot), np.int32)
        self.lengths = np.zeros((slots,), np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(slots)]
        self._splice_fns: dict[tuple, Callable] = {}
        self._tail_fn: Callable | None = None

    # -- allocation ------------------------------------------------------------
    def _alloc(self, n: int) -> list[int]:
        while True:
            try:
                return self.allocator.alloc(n)
            except PageExhausted:
                if self.reclaim is None or not self.reclaim():
                    raise

    # -- decode plumbing -------------------------------------------------------
    def decode_cache(self) -> dict:
        """The decode-step cache pytree; page table and lengths are pushed
        fresh from the host so evictions take effect before the next chunk.
        Host arrays are COPIED at the boundary: ``jnp.asarray`` may
        zero-copy-alias a numpy buffer (CPU backend), and the host mutates
        ``table``/``lengths`` while the async decode chunk is in flight."""
        return {"k": self.pool["k"], "v": self.pool["v"],
                "page_table": jnp.asarray(self.table.copy()),
                "length": jnp.asarray(self.lengths.copy())}

    def absorb(self, new_cache: dict) -> None:
        """Take the pool back from a decode chunk's output cache."""
        self.pool = {"k": new_cache["k"], "v": new_cache["v"]}

    def advance(self, slots, steps: int) -> None:
        """Host-side length bookkeeping after a decode chunk."""
        for s in slots:
            self.lengths[s] += steps

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow ``slot``'s page list to cover ``n_tokens`` more positions."""
        need = min(math.ceil((int(self.lengths[slot]) + n_tokens)
                             / self.page_size), self.pages_per_slot)
        owned = self._slot_pages[slot]
        while len(owned) < need:
            page = self._alloc(1)[0]
            self.table[slot, len(owned)] = page
            owned.append(page)

    # -- insert / free ---------------------------------------------------------
    def _splice_fn(self, pb: int, n: int) -> Callable:
        """Jitted pool write: row ``row`` of a (L, B, pb, H, dh) prefill
        cache -> ``n`` pool pages (one compile per (bucket, page count))."""
        fn = self._splice_fns.get((pb, n))
        if fn is None:
            ps = self.page_size

            def splice(pool_k, pool_v, pk, pv, row, pages):
                need = n * ps
                out = []
                for pool, src in ((pool_k, pk), (pool_v, pv)):
                    sel = jax.lax.dynamic_index_in_dim(
                        src, row, axis=1, keepdims=False)     # (L, pb, H, dh)
                    if need > pb:
                        sel = jnp.pad(sel, [(0, 0), (0, need - pb),
                                            (0, 0), (0, 0)])
                    else:
                        sel = sel[:, :need]
                    L, _, H, dh = sel.shape
                    pg = sel.reshape(L, n, ps, H, dh).astype(pool.dtype)
                    out.append(pool.at[:, pages].set(pg))
                return out[0], out[1]

            fn = jax.jit(splice)
            self._splice_fns[(pb, n)] = fn
        return fn

    def insert_kv(self, kv: dict, row: int, true_len: int, slot: int) -> None:
        """Private-page insert: splice one prefill row into freshly
        allocated pages (the page-table splice that replaces the dense
        cache re-home)."""
        n = max(1, math.ceil(true_len / self.page_size))
        pages = self._alloc(n)
        pb = kv["k"].shape[2]
        self.pool["k"], self.pool["v"] = self._splice_fn(pb, n)(
            self.pool["k"], self.pool["v"], kv["k"], kv["v"],
            jnp.asarray(row, jnp.int32), jnp.asarray(pages, jnp.int32))
        self._set_slot(slot, pages, true_len)

    def insert_shared(self, pages: list[int], tail, true_len: int,
                      slot: int) -> None:
        """Prefix-cache insert: point the slot's table at the shared full
        pages (refcounted — never written again) and copy only the partial
        tail page, where this request's decode writes will land."""
        self.allocator.incref(pages)
        owned = list(pages)
        if tail is not None:
            tp = self._alloc(1)[0]
            if self._tail_fn is None:
                self._tail_fn = jax.jit(
                    lambda pool_k, pool_v, tk, tv, p: (
                        pool_k.at[:, p].set(tk.astype(pool_k.dtype)),
                        pool_v.at[:, p].set(tv.astype(pool_v.dtype))))
            self.pool["k"], self.pool["v"] = self._tail_fn(
                self.pool["k"], self.pool["v"], tail[0], tail[1],
                jnp.asarray(tp, jnp.int32))
            owned.append(tp)
        self._set_slot(slot, owned, true_len)

    def _set_slot(self, slot: int, pages: list[int], true_len: int) -> None:
        if self._slot_pages[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        self.table[slot] = 0
        self.table[slot, :len(pages)] = pages
        self.lengths[slot] = true_len
        self._slot_pages[slot] = pages

    def free(self, slot: int) -> None:
        """Eviction frees pages (decref for shared ones), not a rectangle."""
        if self._slot_pages[slot]:
            self.allocator.free(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self.table[slot] = 0
        self.lengths[slot] = 0

    # -- prefix-cache materialization ------------------------------------------
    def materialize_prefix(self, kv: dict, row: int, true_len: int):
        """Copy a prefill row into cache-owned pool pages: returns
        (full_pages, tail) where ``full_pages`` covers the ``true_len //
        page_size`` complete pages and ``tail`` is the partial page's KV as
        plain device arrays (no pool page held)."""
        ps = self.page_size
        n_full = true_len // ps
        pages: list[int] = []
        if n_full:
            pages = self._alloc(n_full)
            pb = kv["k"].shape[2]
            self.pool["k"], self.pool["v"] = self._splice_fn(pb, n_full)(
                self.pool["k"], self.pool["v"], kv["k"], kv["v"],
                jnp.asarray(row, jnp.int32), jnp.asarray(pages, jnp.int32))
        tail = None
        if true_len % ps:
            lo = n_full * ps
            pb = kv["k"].shape[2]
            pad = max(0, lo + ps - pb)
            tk = kv["k"][:, row, lo:lo + ps]
            tv = kv["v"][:, row, lo:lo + ps]
            if pad:
                tk = jnp.pad(tk, [(0, 0), (0, pad), (0, 0), (0, 0)])
                tv = jnp.pad(tv, [(0, 0), (0, pad), (0, 0), (0, 0)])
            tail = (tk, tv)
        return pages, tail

    def release_pages(self, pages: list[int]) -> None:
        self.allocator.free(pages)

    def report(self) -> dict:
        rep = self.allocator.report()
        rep.update(page_size=self.page_size,
                   pages_per_slot=self.pages_per_slot,
                   occupancy=rep["used"] / max(rep["num_pages"], 1),
                   slot_pages=[len(p) for p in self._slot_pages])
        return rep


class DenseKV:
    """The legacy dense slot cache — (L, slots, max_len, H, dh) rectangles —
    behind the same insert/ensure/free surface, kept for mesh-sharded
    engines (the page pool is not slot-partitionable) and as the equality
    reference for the paged path."""

    def __init__(self, model, slots: int, max_len: int):
        self.model = model
        self.slots = slots
        self.max_len = max_len
        cache = dict(model.init_cache(slots, max_len))
        cache["length"] = jnp.zeros((slots,), jnp.int32)
        self.cache = cache
        self._write_fns: dict[int, Callable] = {}

    def decode_cache(self) -> dict:
        return self.cache

    def absorb(self, new_cache: dict) -> None:
        self.cache = new_cache

    def advance(self, slots, steps: int) -> None:
        pass                      # device-side length is authoritative

    def ensure(self, slot: int, n_tokens: int) -> None:
        pass                      # every slot owns its max_len rectangle

    def _write_fn(self, pb: int) -> Callable:
        """Jitted slot write: splice row ``row`` of a (·, B, pb, ·, ·)
        prefill cache into row ``slot`` of the batched decode cache (traced
        indices — one compile per bucket, not per slot)."""
        fn = self._write_fns.get(pb)
        if fn is None:
            def write(cache, pcache, row, slot):
                out = dict(cache)
                for k, leaf in cache.items():
                    if k == "length":
                        continue
                    upd = jax.lax.dynamic_index_in_dim(
                        pcache[k], row, axis=1, keepdims=True).astype(leaf.dtype)
                    start = (0, slot) + (0,) * (leaf.ndim - 2)
                    out[k] = jax.lax.dynamic_update_slice(leaf, upd, start)
                out["length"] = cache["length"].at[slot].set(
                    pcache["length"][row])
                return out
            fn = jax.jit(write)
            self._write_fns[pb] = fn
        return fn

    def insert_kv(self, kv: dict, row: int, true_len: int, slot: int) -> None:
        self.cache = self._write_fn(kv["k"].shape[2])(
            self.cache, kv, jnp.asarray(row, jnp.int32),
            jnp.asarray(slot, jnp.int32))

    def free(self, slot: int) -> None:
        pass                      # admission's slot write resets KV + length

    def report(self) -> dict:
        return {"layout": "dense", "slots": self.slots,
                "max_len": self.max_len}
