from .engine import Engine, ServeConfig
from .kv import DenseKV, PageAllocator, PagedKV, PageExhausted, Prefix
from .metrics import ServeMetrics, StepMetrics, percentiles
from .prefix import PrefixCache
from .queue import FinishedRequest, Request, RequestQueue
from .scheduler import (ADMISSION_BUCKET, RAGGED_FAMILIES, Scheduler,
                        SchedulerConfig)

__all__ = [
    "Engine", "ServeConfig", "Prefix",
    "PagedKV", "DenseKV", "PageAllocator", "PageExhausted", "PrefixCache",
    "Scheduler", "SchedulerConfig", "RAGGED_FAMILIES", "ADMISSION_BUCKET",
    "Request", "FinishedRequest", "RequestQueue",
    "ServeMetrics", "StepMetrics", "percentiles",
]
