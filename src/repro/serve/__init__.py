from .engine import Engine, ServeConfig
from .metrics import ServeMetrics, StepMetrics, percentiles
from .queue import FinishedRequest, Request, RequestQueue
from .scheduler import RAGGED_FAMILIES, Scheduler, SchedulerConfig

__all__ = [
    "Engine", "ServeConfig",
    "Scheduler", "SchedulerConfig", "RAGGED_FAMILIES",
    "Request", "FinishedRequest", "RequestQueue",
    "ServeMetrics", "StepMetrics", "percentiles",
]
