"""Request queue for the continuous-batching serving tier.

A :class:`Request` is one generation job: a 1-D prompt plus a per-request
token budget.  :class:`RequestQueue` is the FIFO the scheduler admits from —
deliberately simple (no priorities, no preemption): the scheduling smarts
live in the slot manager, the queue just buffers the open-loop arrival
process and tracks depth statistics for the metrics report.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any

import numpy as np

__all__ = ["Request", "FinishedRequest", "RequestQueue"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (P,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0             # caller clock; metrics only
    extra: dict[str, Any] = field(default_factory=dict)   # per-request prefill kwargs

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")


@dataclass
class FinishedRequest:
    """A completed request plus the timestamps the latency report needs."""

    rid: int
    prompt_len: int
    tokens: np.ndarray                    # (n,) generated ids, EOS included if hit
    finish_reason: str                    # "eos" | "length"
    arrival_time: float
    admit_time: float                     # admission (bucketed prefill) instant
    first_token_time: float               # == admit_time: prefill emits token 0
    finish_time: float
    admit_step: int
    finish_step: int
    prefix_cached: bool = False           # admission KV came from the prefix cache

    @property
    def e2e_latency(self) -> float:
        return self.finish_time - self.arrival_time

    @property
    def ttft(self) -> float:
        return self.first_token_time - self.arrival_time

    @property
    def queue_latency(self) -> float:
        return self.admit_time - self.arrival_time


class RequestQueue:
    """FIFO of pending requests with depth accounting."""

    def __init__(self):
        self._q: deque[Request] = deque()
        self._next_rid = 0
        self.total_submitted = 0
        self.peak_depth = 0

    def submit(self, prompt, max_new_tokens: int, rid: int | None = None,
               arrival_time: float = 0.0, extra: dict | None = None) -> int:
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        req = Request(rid=rid, prompt=np.asarray(prompt),
                      max_new_tokens=max_new_tokens,
                      arrival_time=arrival_time, extra=dict(extra or {}))
        self._q.append(req)
        self.total_submitted += 1
        self.peak_depth = max(self.peak_depth, len(self._q))
        return rid

    def pop(self) -> Request | None:
        return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
