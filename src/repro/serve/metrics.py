"""Serving metrics: per-step scheduler telemetry + request-latency summary.

The scheduler emits one :class:`StepMetrics` per decode step; the
:class:`ServeMetrics` aggregator folds them with the stream of
:class:`~repro.serve.queue.FinishedRequest` records into the numbers an
operator actually watches: occupancy, queue depth, useful tokens/sec, and
end-to-end / time-to-first-token latency percentiles.

The percentile math lives in :func:`repro.obs.metrics.percentiles` (the one
shared implementation repo-wide); it is re-exported here for the existing
callers.  Latency summaries are always present in :meth:`ServeMetrics.
summary` — an empty run reports all-zero percentiles rather than missing
keys, so downstream schema checks never special-case short runs.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.obs.metrics import percentiles

from .queue import FinishedRequest

__all__ = ["StepMetrics", "ServeMetrics", "percentiles"]


@dataclass
class StepMetrics:
    """One scheduler iteration (admissions happen before the decode).  With
    EOS off the scheduler chunks predictable decode steps, so ``tokens``
    may cover several tokens per active slot in one iteration."""

    step: int
    active: int                 # occupied slots during the decode
    slots: int
    queue_depth: int            # after admissions
    admissions: int
    evictions: int
    tokens: int                 # useful tokens emitted this step
    step_seconds: float
    stitch_status: str | None = None   # None|hit|miss|pending|error
    prefix_hits: int = 0        # admissions served from the prefix cache

    @property
    def occupancy(self) -> float:
        return self.active / self.slots if self.slots else 0.0


@dataclass
class ServeMetrics:
    steps: list[StepMetrics] = field(default_factory=list)
    finished: list[FinishedRequest] = field(default_factory=list)

    def record_step(self, m: StepMetrics) -> None:
        self.steps.append(m)

    def record_finished(self, f: FinishedRequest) -> None:
        self.finished.append(f)

    # -- aggregates -----------------------------------------------------------
    @property
    def total_tokens(self) -> int:
        return sum(m.tokens for m in self.steps)

    @property
    def elapsed(self) -> float:
        return sum(m.step_seconds for m in self.steps)

    def summary(self) -> dict:
        steps = self.steps
        active_steps = [m for m in steps if m.active]
        out = {
            "steps": len(steps),
            "requests_finished": len(self.finished),
            "total_tokens": self.total_tokens,
            "elapsed_s": self.elapsed,
            "tokens_per_sec": self.total_tokens / max(self.elapsed, 1e-9),
            "mean_occupancy": (float(np.mean([m.occupancy for m in active_steps]))
                               if active_steps else 0.0),
            "peak_queue_depth": max((m.queue_depth for m in steps), default=0),
            "admissions": sum(m.admissions for m in steps),
            "evictions": sum(m.evictions for m in steps),
            "prefix_hits": sum(m.prefix_hits for m in steps),
        }
        # always present (all-zero for an empty run): downstream schema
        # checks must not have to special-case short runs
        out["e2e_latency_s"] = percentiles([f.e2e_latency for f in self.finished])
        out["ttft_s"] = percentiles([f.ttft for f in self.finished])
        out["queue_latency_s"] = percentiles(
            [f.queue_latency for f in self.finished])
        out["finish_reasons"] = {
            r: sum(1 for f in self.finished if f.finish_reason == r)
            for r in sorted({f.finish_reason for f in self.finished})
        }
        return out
