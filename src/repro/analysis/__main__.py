"""Offline static-verification CLI.

::

    # audit every persisted plan record in a StitchCache directory
    PYTHONPATH=src python -m repro.analysis --cache-dir /tmp/stitch

    # trace bundled model configs and audit their compiled plans
    PYTHONPATH=src python -m repro.analysis --configs qwen3_1_7b phi3_mini_3_8b
    PYTHONPATH=src python -m repro.analysis --configs          # all of them

Exit code 1 when any ERROR finding is emitted, 0 otherwise (WARNs don't
fail the run) — CI gates on this.  The cache-dir audit is zero-jax: it
checks record *structure* (readable JSON, known group kinds, in-range
canonical indices, disjoint members, well-formed pack provenance —
disjoint, covering, >= 2 member subgraphs) without a live graph; the full
graph-vs-record check (including the RA061 pack-dependence pass) runs
online at replay (:meth:`StitchCache.lookup`).
The config audit imports jax: it traces each reduced config's train
forward, compiles it, and runs :func:`verify_compiled`.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .findings import Finding, errors, format_findings, summarize
from .plan import _RECORD_KINDS


def audit_cache_dir(directory: str) -> dict[str, list[Finding]]:
    """Structural audit of every ``plan_*.json`` under ``directory``;
    returns findings keyed by file name (files with none are included, so
    the caller can report coverage)."""
    from repro.cache.store import PlanRecord

    out: dict[str, list[Finding]] = {}
    for path in sorted(Path(directory).glob("plan_*.json")):
        findings: list[Finding] = []
        rec = None
        try:
            with open(path) as f:
                rec = PlanRecord.from_json(json.load(f))
        except Exception as err:
            findings.append(Finding(
                "RA050", f"unreadable plan record: "
                         f"{type(err).__name__}: {err}"))
        if rec is not None:
            findings += _audit_record_structure(rec)
        # rec is None with no findings == stale record version: a silent
        # miss at runtime, not corruption
        out[path.name] = findings
    return out


def _audit_record_structure(rec) -> list[Finding]:
    findings: list[Finding] = []
    owner: dict[int, int] = {}
    for i, gr in enumerate(rec.groups):
        if gr.kind not in _RECORD_KINDS:
            findings.append(Finding(
                "RA028", f"group kind {gr.kind!r} not one of "
                         f"{_RECORD_KINDS}", group=i))
        bad = [j for j in list(gr.members) + list(gr.scratch or ())
               if not isinstance(j, int) or not 0 <= j < rec.n_nodes]
        if bad:
            findings.append(Finding(
                "RA020", f"canonical indices {bad[:6]} out of range "
                         f"[0, {rec.n_nodes})", group=i))
        for j in gr.members:
            if isinstance(j, int) and j in owner:
                findings.append(Finding(
                    "RA021", f"canonical node {j} owned by groups "
                             f"{owner[j]} and {i}", group=i))
            elif isinstance(j, int):
                owner[j] = i
        findings += _audit_record_pack(gr, i, rec.n_nodes)
    return findings


def _audit_record_pack(gr, i: int, n_nodes: int) -> list[Finding]:
    """Graph-free pack-provenance checks on one group record: canonical
    indices in range (RA020), member subgraphs disjoint and covering the
    group with >= 2 subgraphs (RA060).  The cross-subgraph dependence
    check (RA061) needs the live graph and runs at replay."""
    pack = getattr(gr, "pack", None)
    if not pack:
        return []
    findings: list[Finding] = []
    flat = [j for gset in pack for j in gset]
    bad = [j for j in flat if not isinstance(j, int) or not 0 <= j < n_nodes]
    if bad:
        return [Finding("RA020", f"pack canonical indices {bad[:6]} out of "
                                 f"range [0, {n_nodes})", group=i)]
    if len(pack) < 2:
        findings.append(Finding(
            "RA060", f"pack has {len(pack)} member subgraph(s); needs >= 2",
            group=i))
    if len(set(flat)) != len(flat):
        findings.append(Finding(
            "RA060", "pack member subgraphs overlap", group=i))
    if set(flat) != {j for j in gr.members if isinstance(j, int)}:
        findings.append(Finding(
            "RA060", f"pack member subgraphs do not cover the group "
                     f"({len(set(flat))} packed vs {len(gr.members)} "
                     f"members)", group=i))
    return findings


def audit_configs(names: list[str]) -> dict[str, list[Finding]]:
    """Trace each bundled config's train forward, compile it, and run the
    full IR + plan audit.  Imports jax (slow path)."""
    import numpy as np

    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.core.compiler import StitchCompiler
    from repro.core.trace import trace_to_graph
    from repro.models import build_model

    from .plan import verify_compiled

    out: dict[str, list[Finding]] = {}
    for name in names:
        cfg = get_reduced(name)
        model = build_model(cfg)
        import jax
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (2, cfg.n_patch_tokens, cfg.d_model), cfg.dtype)
        if cfg.family == "audio":
            batch["frames"] = jnp.asarray(
                rng.standard_normal((2, 32, cfg.d_model)), cfg.dtype)
        # trace the FULL (loss, metrics) output: tracing only the loss
        # leaves the metrics' nodes dead in the graph, and every one would
        # (correctly) surface as an RA005 warning
        g, _ = trace_to_graph(lambda p: model.train_forward(p, batch),
                              params, name=name)
        # use_pallas=False: the audit cares about plan legality, not kernel
        # build time; verify="off" here because verify_compiled below runs
        # the superset (IR pass + cover + pattern-class recount)
        compiler = StitchCompiler(use_pallas=False, verify="off")
        cg = compiler.compile(g)
        budget = compiler.gen_cfg.scratch_budget
        if budget is None:
            budget = compiler.hw.onchip_budget
        out[name] = verify_compiled(cg, scratch_budget=budget,
                                    cost=compiler.cost)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static verification of stitching artifacts (offline)")
    ap.add_argument("--cache-dir", default=None,
                    help="StitchCache directory: structural audit of every "
                         "persisted plan record")
    ap.add_argument("--configs", nargs="*", default=None,
                    help="bundled model configs to trace+compile+audit "
                         "(no names = all)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output")
    args = ap.parse_args(argv)
    if args.cache_dir is None and args.configs is None:
        ap.error("nothing to audit: give --cache-dir and/or --configs")

    sections: dict[str, dict[str, list[Finding]]] = {}
    if args.cache_dir is not None:
        sections["cache"] = audit_cache_dir(args.cache_dir)
    if args.configs is not None:
        names = args.configs
        if not names:
            from repro.configs import ARCHS
            names = list(ARCHS)
        sections["configs"] = audit_configs(names)

    all_findings = [f for sec in sections.values()
                    for fs in sec.values() for f in fs]
    if args.json:
        print(json.dumps({
            "summary": summarize(all_findings),
            "sections": {
                sec: {k: [f.as_dict() for f in fs] for k, fs in items.items()}
                for sec, items in sections.items()
            },
        }, indent=2))
    else:
        for sec, items in sections.items():
            print(f"== {sec}: {len(items)} artifact(s) audited ==")
            for k, fs in items.items():
                if fs:
                    print(f"-- {k} --")
                    print(format_findings(fs))
            clean = sum(1 for fs in items.values() if not fs)
            print(f"   {clean}/{len(items)} clean")
        s = summarize(all_findings)
        print(f"total: {s['errors']} error(s), {s['warnings']} warning(s)")
    return 1 if errors(all_findings) else 0


if __name__ == "__main__":
    sys.exit(main())
