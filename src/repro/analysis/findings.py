"""Structured findings for the static verifier.

Every analysis pass (:mod:`repro.analysis.verify`, ``plan``, ``alias``,
``kvaudit``) reports problems as :class:`Finding` records instead of
raising mid-pipeline: a stable machine-checkable code (``RA0xx``), a
severity, a human message, and provenance (node name / group index /
page id).  Callers decide what to do with them — the compiler refuses
ERROR plans, cache replay demotes to a miss, the CLI exits nonzero.

Code registry (stable — tests pin these; never renumber):

========  =======================================================
code      meaning
========  =======================================================
RA001     operand references an undefined node (use-before-def)
RA002     graph contains a cycle
RA003     declared output missing from the graph
RA004     invalid / unparseable dtype
RA005     dead compute node (unreachable from any output) [WARN]
RA010     elementwise operand shapes not broadcast-compatible
RA011     invalid broadcast dims
RA012     reshape element-count mismatch
RA013     invalid transpose permutation
RA014     invalid reduction axes / output shape
RA015     dot contraction or batch dimension mismatch
RA016     slice bounds invalid
RA017     gather output shape mismatch
RA020     plan group member not in graph
RA021     overlapping groups (node owned by more than one group)
RA022     compute node not covered by any group
RA023     induced group DAG has a cycle
RA024     group scratch request exceeds on-chip budget
RA025     unregistered custom kernel inside a fused group
RA026     recorded pattern-class stats inconsistent [WARN]
RA027     non-compute node (param/const/tuple) inside a group
RA028     invalid group kind in a plan record
RA030     donated input aliases a graph output (passthrough)
RA031     donated input read by a group after the donating group
RA032     donated name is not a graph parameter / unused [WARN]
RA040     page neither free nor allocated (lost)
RA041     page both free and allocated
RA042     page refcount disagrees with owner count
RA043     page refcounted but owned by nobody (leaked)
RA044     page owned more often than its refcount (double-owned)
RA045     allocator structure corrupt (free-list dup / page 0 / range)
RA046     page owned but not allocated (use-after-free)
RA047     page-table row disagrees with slot ownership
RA050     plan record file unreadable / structurally invalid
RA060     pack member subgraphs overlap / don't cover the group
RA061     pack data dependence crosses member subgraphs
RA062     pack register pressure exceeds budget
========  =======================================================
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["Finding", "VerificationError", "ERROR", "WARN", "CODES",
           "errors", "warnings_", "summarize", "format_findings"]

ERROR = "error"
WARN = "warning"

CODES: dict[str, str] = {
    "RA001": "undefined operand",
    "RA002": "graph cycle",
    "RA003": "missing output",
    "RA004": "invalid dtype",
    "RA005": "dead node",
    "RA010": "elementwise shape mismatch",
    "RA011": "invalid broadcast dims",
    "RA012": "reshape element-count mismatch",
    "RA013": "invalid transpose permutation",
    "RA014": "invalid reduction axes",
    "RA015": "dot dimension mismatch",
    "RA016": "slice bounds invalid",
    "RA017": "gather shape mismatch",
    "RA020": "group member not in graph",
    "RA021": "overlapping groups",
    "RA022": "uncovered compute node",
    "RA023": "induced group cycle",
    "RA024": "scratch over budget",
    "RA025": "unregistered custom in fused group",
    "RA026": "pattern-class stats inconsistent",
    "RA027": "non-compute node in group",
    "RA028": "invalid group kind",
    "RA030": "donated input aliases output",
    "RA031": "donated input read after donating group",
    "RA032": "donated name unused",
    "RA040": "lost page",
    "RA041": "page both free and allocated",
    "RA042": "page refcount mismatch",
    "RA043": "leaked page",
    "RA044": "double-owned page",
    "RA045": "allocator structure corrupt",
    "RA046": "page owned but not allocated",
    "RA047": "page-table row inconsistent",
    "RA050": "unreadable plan record",
    "RA060": "pack member subgraphs malformed",
    "RA061": "pack dependence crosses member subgraphs",
    "RA062": "pack register pressure over budget",
}

_WARN_CODES = frozenset({"RA005", "RA026", "RA032"})


@dataclass(frozen=True)
class Finding:
    """One defect found by a static pass.

    ``node`` is a graph node name (IR/alias passes), ``group`` a group
    index (plan pass), ``page`` a page id (KV pass); unused provenance
    fields stay None.
    """

    code: str
    message: str
    severity: str = ""          # derived from code when empty
    node: str | None = None
    group: int | None = None
    page: int | None = None

    def __post_init__(self) -> None:
        if not self.severity:
            object.__setattr__(
                self, "severity",
                WARN if self.code in _WARN_CODES else ERROR)

    @property
    def title(self) -> str:
        return CODES.get(self.code, "unknown code")

    def as_dict(self) -> dict:
        d: dict = {"code": self.code, "severity": self.severity,
                   "title": self.title, "message": self.message}
        if self.node is not None:
            d["node"] = self.node
        if self.group is not None:
            d["group"] = self.group
        if self.page is not None:
            d["page"] = self.page
        return d

    def __str__(self) -> str:
        where = ""
        if self.node is not None:
            where = f" node={self.node}"
        if self.group is not None:
            where += f" group={self.group}"
        if self.page is not None:
            where += f" page={self.page}"
        return (f"{self.code} [{self.severity.upper()}] {self.title}:"
                f"{where} {self.message}")


def errors(findings: list[Finding]) -> list[Finding]:
    return [f for f in findings if f.severity == ERROR]


def warnings_(findings: list[Finding]) -> list[Finding]:
    return [f for f in findings if f.severity == WARN]


def summarize(findings: list[Finding]) -> dict:
    """Compact dict for stats / bench records: counts + distinct codes."""
    return {
        "errors": len(errors(findings)),
        "warnings": len(warnings_(findings)),
        "codes": sorted({f.code for f in findings}),
    }


def format_findings(findings: list[Finding], limit: int = 20) -> str:
    lines = [str(f) for f in findings[:limit]]
    if len(findings) > limit:
        lines.append(f"... and {len(findings) - limit} more")
    return "\n".join(lines)


class VerificationError(Exception):
    """Raised by callers that refuse artifacts with ERROR findings (the
    compiler's ``verify=`` gate, the engine's debug KV audit).  Carries
    the full finding list so the failure is explainable."""

    def __init__(self, what: str, findings: list[Finding]):
        self.findings = findings
        super().__init__(
            f"{what}: {len(errors(findings))} error finding(s)\n"
            + format_findings(findings))

    @property
    def codes(self) -> set[str]:
        return {f.code for f in self.findings}
