"""repro.analysis — static verification of StitchIR artifacts.

Zero-jax: every pass checks artifacts (graphs, fusion plans, disk plan
records, KV allocator snapshots) *without executing them*, emitting
structured :class:`Finding` records with stable ``RA0xx`` codes instead
of raising mid-pipeline.  See :mod:`repro.analysis.findings` for the
code registry.

Passes:
  * :func:`verify_graph`   — IR legality (SSA, shapes, dtypes, dead code)
  * :func:`verify_plan`    — fusion-plan legality (cover, cycles, scratch,
    registry membership); :func:`verify_record` for disk records,
    :func:`verify_compiled` for compiled artifacts
  * :func:`check_donation` — donation/aliasing hazards
  * :func:`audit_kv`       — paged-KV refcount conservation over a
    :func:`snapshot`

Wired in at: ``StitchCompiler(verify=...)`` (refuses ERROR plans),
``repro.cache`` replay (demotes bad records to a miss),
``Engine(debug_kv=True)`` (asserts clean audits on release/drain), and
``python -m repro.analysis`` / ``launch/inspect.py verify`` offline.
"""

from .alias import check_donation
from .findings import (CODES, ERROR, WARN, Finding, VerificationError,
                       errors, format_findings, summarize, warnings_)
from .kvaudit import KVSnapshot, audit_kv, snapshot
from .plan import GroupView, verify_compiled, verify_plan, verify_record
from .verify import verify_graph

__all__ = [
    "Finding", "VerificationError", "CODES", "ERROR", "WARN",
    "errors", "warnings_", "summarize", "format_findings",
    "verify_graph",
    "GroupView", "verify_plan", "verify_record", "verify_compiled",
    "check_donation",
    "KVSnapshot", "snapshot", "audit_kv",
]
