"""Pass 2 — plan verifier: is a fusion plan legal for a given graph?

A *plan* here is any grouping of graph nodes into kernels: the patterns
the ILP chose (pre-tuning), the ``_Group`` list of a compiled artifact,
or a ``PlanRecord`` replayed from disk.  ``verify_plan`` checks the §4
invariants statically:

* every member exists and is a compute node (RA020 / RA027),
* groups are disjoint (RA021) and — when asked — cover every compute
  node (RA022),
* the induced group DAG is acyclic (RA023) — the global form of the
  ``induced_reaches`` cycle rule, checked over the *whole* plan rather
  than one contraction at a time,
* multi-member groups fit the on-chip scratch budget (RA024),
* fused groups only contain CUSTOM kernels the registry knows (RA025),
* recorded pattern-class stats match a recount (RA026, WARN),
* horizontal packs are well-formed: member subgraphs disjoint and covering
  (RA060), no data dependence crosses two packed subgraphs (RA061), and
  the pack fits the register/live-value budget (RA062).

``verify_record`` adapts a disk ``PlanRecord`` (canonical indices) onto
the live graph and runs the same checks — the cache-replay gate.
"""

from __future__ import annotations

from typing import Iterable, Sequence

from repro.core.cost import CostModel
from repro.core.ir import Graph, OpKind
from repro.core.pattern import FusionPattern
from repro.kernels.registry import lookup as _registry_lookup

from .findings import Finding

__all__ = ["GroupView", "verify_plan", "verify_record", "verify_compiled"]

_RECORD_KINDS = ("pallas", "jnp", "op")


class GroupView:
    """Minimal adapter one plan group: a member set, an execution kind
    (``pallas``/``jnp``/``op`` from artifacts and records, ``pattern`` for
    not-yet-tuned ILP choices), and — for horizontal packs — the packed
    member subgraphs (``pack``, None for ordinary groups)."""

    __slots__ = ("members", "kind", "index", "pack")

    def __init__(self, members: Iterable[str], kind: str = "pattern",
                 index: int = 0,
                 pack: "tuple[frozenset[str], ...] | None" = None):
        self.members = frozenset(members)
        self.kind = kind
        self.index = index
        self.pack = pack


def _as_views(groups: Sequence) -> list[GroupView]:
    views = []
    for i, grp in enumerate(groups):
        if isinstance(grp, GroupView):
            grp.index = i
            views.append(grp)
        elif isinstance(grp, (frozenset, set, list, tuple)):
            views.append(GroupView(grp, "pattern", i))
        else:  # duck-typed _Group / FusionPattern / PackPattern
            kind = getattr(grp, "kind", "pattern")
            pack = (getattr(grp, "pack", None)
                    or getattr(grp, "member_groups", None))
            if pack:
                pack = tuple(frozenset(gset) for gset in pack)
            views.append(GroupView(grp.members, kind, i, pack or None))
    return views


def verify_plan(
    g: Graph,
    groups: Sequence,
    *,
    require_cover: bool = False,
    scratch_budget: int | None = None,
    cost: CostModel | None = None,
    reg_budget: int | None = None,
    pattern_classes: dict[str, int] | None = None,
) -> list[Finding]:
    """Check plan legality; ``groups`` accepts member sets, patterns,
    ``_Group``-likes or :class:`GroupView` s.  ``scratch_budget`` (with a
    ``cost`` model) enables the RA024 budget check for fusable groups;
    ``reg_budget`` (with ``cost``) enables the RA062 register-pressure
    check for packed groups; ``require_cover`` additionally demands a full
    disjoint cover of the graph's compute nodes (records / compiled
    artifacts — the compiler's pre-tune call leaves uncovered nodes to
    implicit singletons)."""
    findings: list[Finding] = []
    views = _as_views(groups)
    compute = {n.name for n in g.compute_nodes()}

    # -- membership + disjointness ----------------------------------------
    owner: dict[str, int] = {}
    sane: list[GroupView] = []
    for v in views:
        ok = True
        for m in sorted(v.members):
            if m not in g.nodes:
                findings.append(Finding(
                    "RA020", f"member {m!r} not in graph", node=m,
                    group=v.index))
                ok = False
                continue
            if m not in compute:
                findings.append(Finding(
                    "RA027", f"member {m!r} is {g[m].kind.value}, not a "
                             f"compute node", node=m, group=v.index))
                ok = False
            if m in owner:
                findings.append(Finding(
                    "RA021", f"node {m!r} owned by groups {owner[m]} and "
                             f"{v.index}", node=m, group=v.index))
                ok = False
            else:
                owner[m] = v.index
        if ok:
            sane.append(v)

    uncovered = compute - set(owner)
    if require_cover:
        for m in sorted(uncovered):
            findings.append(Finding(
                "RA022", f"compute node {m!r} not covered by any group",
                node=m))

    # -- induced group DAG must schedule (global cycle rule) ---------------
    # Uncovered compute nodes execute as implicit singleton kernels, so they
    # participate in the schedule exactly as CompiledGraph._schedule treats
    # them — a cycle routed through one is just as unschedulable.
    full_owner = dict(owner)
    n_groups = len(views)
    for m in sorted(uncovered):
        full_owner[m] = n_groups
        n_groups += 1
    indeg = [0] * n_groups
    succs: list[set[int]] = [set() for _ in range(n_groups)]
    for name, gid in full_owner.items():
        for o in g.nodes[name].operands:
            src = full_owner.get(o)
            if src is not None and src != gid and gid not in succs[src]:
                succs[src].add(gid)
                indeg[gid] += 1
    ready = [i for i in range(n_groups) if indeg[i] == 0]
    seen = 0
    while ready:
        cur = ready.pop()
        seen += 1
        for s in succs[cur]:
            indeg[s] -= 1
            if indeg[s] == 0:
                ready.append(s)
    if seen != n_groups:
        stuck = sorted(i for i in range(n_groups) if indeg[i] > 0)
        real = [i for i in stuck if i < len(views)]
        findings.append(Finding(
            "RA023", f"induced group DAG has a cycle through groups "
                     f"{real[:6] if real else stuck[:6]}",
            group=real[0] if real else None))

    # -- per-group kernel legality (fused groups only) ---------------------
    for v in sane:
        if len(v.members) < 2 or v.kind == "op":
            continue
        if any(m not in g.nodes for m in v.members):
            continue
        # registered-custom membership: an unregistered CUSTOM cannot live
        # inside a fused kernel — there is no body to stitch (registry rule;
        # kernels/stitched.py would only discover this mid-tuning)
        for m in sorted(v.members):
            node = g[m]
            if node.kind is OpKind.CUSTOM and _registry_lookup(node) is None:
                findings.append(Finding(
                    "RA025", f"custom kernel "
                             f"{node.attrs.get('kernel', '?')!r} not in "
                             f"registry", node=m, group=v.index))
        if scratch_budget is not None and cost is not None \
                and v.kind in ("pattern", "pallas"):
            p = FusionPattern(g, v.members)
            req = sum(cost.scratch_request(p).values()) + cost.custom_scratch(p)
            if req > scratch_budget:
                findings.append(Finding(
                    "RA024", f"scratch request {req} B exceeds budget "
                             f"{scratch_budget} B", group=v.index))

    # -- horizontal packs: provenance well-formed + truly independent ------
    for v in sane:
        if not v.pack:
            continue
        seen_pack: set[str] = set()
        union: set[str] = set()
        bad_pack = False
        for grp in v.pack:
            if grp & seen_pack:
                findings.append(Finding(
                    "RA060", f"pack member subgraphs overlap on "
                             f"{sorted(grp & seen_pack)[:4]}", group=v.index))
                bad_pack = True
            seen_pack |= grp
            union |= grp
        if union != v.members:
            findings.append(Finding(
                "RA060", "pack member subgraphs do not cover the group "
                         f"({len(union)} packed vs {len(v.members)} members)",
                group=v.index))
            bad_pack = True
        if bad_pack:
            continue
        owner_grp: dict[str, int] = {}
        for gi, grp in enumerate(v.pack):
            for m in grp:
                owner_grp[m] = gi
        for m in sorted(v.members):
            if m not in g.nodes:
                continue
            for o in g.nodes[m].operands:
                if o in owner_grp and owner_grp[o] != owner_grp[m]:
                    findings.append(Finding(
                        "RA061", f"pack dependence crosses member subgraphs: "
                                 f"{o!r} (subgraph {owner_grp[o]}) feeds "
                                 f"{m!r} (subgraph {owner_grp[m]})",
                        node=m, group=v.index))
        if reg_budget is not None and cost is not None \
                and all(m in g.nodes for m in v.members):
            # pack-aware pressure: independent subgraphs serialise inside a
            # block, so the widest member subgraph sets the working set
            # (mirrors CostModel.register_pressure on a PackPattern)
            reg = max(cost.register_pressure(FusionPattern(g, grp))
                      for grp in v.pack)
            if reg > reg_budget:
                findings.append(Finding(
                    "RA062", f"pack register pressure {reg} B exceeds "
                             f"budget {reg_budget} B", group=v.index))

    # -- recorded pattern-class stats vs a recount (WARN) ------------------
    if pattern_classes is not None:
        recount: dict[str, int] = {}
        for v in sane:
            if len(v.members) < 2 or v.kind == "op" \
                    or any(m not in g.nodes for m in v.members):
                continue
            cls = FusionPattern(g, v.members).pattern_class
            recount[cls] = recount.get(cls, 0) + 1
        recorded = {k: c for k, c in pattern_classes.items() if c}
        if recount != recorded:
            findings.append(Finding(
                "RA026", f"recorded pattern classes {recorded} != recount "
                         f"{recount}"))

    return findings


def verify_record(
    g: Graph,
    canon_order: Sequence[str],
    rec,
    *,
    scratch_budget: int | None = None,
    cost: CostModel | None = None,
    reg_budget: int | None = None,
) -> list[Finding]:
    """Verify a disk ``PlanRecord`` against the *live* graph it is about
    to replay onto.  ``canon_order`` maps the record's canonical node
    indices back to this graph's node names (``sig.canon_order``)."""
    findings: list[Finding] = []
    n = len(canon_order)
    if getattr(rec, "n_nodes", n) != n:
        findings.append(Finding(
            "RA050", f"record describes {rec.n_nodes} nodes, live graph has "
                     f"{n}"))
        return findings
    views: list[GroupView] = []
    for i, gr in enumerate(rec.groups):
        if gr.kind not in _RECORD_KINDS:
            findings.append(Finding(
                "RA028", f"group kind {gr.kind!r} not one of "
                         f"{_RECORD_KINDS}", group=i))
            continue
        pack_idx = [list(gset) for gset in (getattr(gr, "pack", ()) or ())]
        flat_pack = [j for gset in pack_idx for j in gset]
        bad = [j for j in list(gr.members) + list(gr.scratch or []) + flat_pack
               if not isinstance(j, int) or not 0 <= j < n]
        if bad:
            findings.append(Finding(
                "RA020", f"canonical indices {bad[:6]} out of range "
                         f"[0, {n})", group=i))
            continue
        pack = (tuple(frozenset(canon_order[j] for j in gset)
                      for gset in pack_idx) or None)
        views.append(GroupView((canon_order[j] for j in gr.members),
                               gr.kind, i, pack))
    if not any(f.severity == "error" for f in findings):
        findings += verify_plan(g, views, require_cover=True,
                                scratch_budget=scratch_budget, cost=cost,
                                reg_budget=reg_budget)
    return findings


def verify_compiled(cg, *, scratch_budget: int | None = None,
                    cost: CostModel | None = None,
                    reg_budget: int | None = None) -> list[Finding]:
    """Full audit of a compiled artifact: IR pass + plan pass + recorded
    pattern-class consistency.  Offline/CLI entry point."""
    from .verify import verify_graph

    findings = verify_graph(cg.graph)
    findings += verify_plan(
        cg.graph, cg.groups, require_cover=True,
        scratch_budget=scratch_budget, cost=cost, reg_budget=reg_budget,
        pattern_classes=getattr(cg.stats, "pattern_classes", None))
    return findings
