"""Pass 3 — aliasing/donation sanitizer.

``repro.exec.stitch(donate_argnums=...)`` lets callers donate input
buffers.  Donation is only safe when the donated value is (a) not itself
returned (an output passthrough aliases the dead buffer) and (b) fully
consumed by the time its first reader finishes — under a group schedule,
read by exactly one group, or only by groups no later than the donating
one.  PR 5 fixed this bug class *dynamically* (``_donate`` keeps leaves
whose id reappears in the outputs); this pass detects both hazards
statically from the graph + plan, so they surface at compile/report time
with provenance instead of as a mysterious runtime keep.
"""

from __future__ import annotations

from typing import Iterable, Sequence

from repro.core.ir import Graph

from .findings import Finding

__all__ = ["check_donation"]


def _group_schedule(g: Graph, groups: Sequence[frozenset[str]] | None
                    ) -> list[frozenset[str]]:
    """Execution-ordered groups; defaults to one group per compute node in
    topo order (the mode="off" schedule)."""
    if groups is None:
        return [frozenset([n.name]) for n in g.compute_nodes()
                if n.name in set(g.topo_order())]
    owner: dict[str, int] = {}
    for i, members in enumerate(groups):
        for m in members:
            owner[m] = i
    # Kahn over the induced DAG (same edges as CompiledGraph._schedule);
    # on a cyclic plan (RA023 elsewhere) fall back to given order.
    n = len(groups)
    indeg = [0] * n
    succs: list[set[int]] = [set() for _ in range(n)]
    for name, gid in owner.items():
        if name not in g.nodes:
            continue
        for o in g.nodes[name].operands:
            src = owner.get(o)
            if src is not None and src != gid and gid not in succs[src]:
                succs[src].add(gid)
                indeg[gid] += 1
    ready = [i for i in range(n) if indeg[i] == 0]
    order: list[int] = []
    while ready:
        cur = ready.pop(0)
        order.append(cur)
        for s in sorted(succs[cur]):
            indeg[s] -= 1
            if indeg[s] == 0:
                ready.append(s)
    if len(order) != n:
        order = list(range(n))
    return [frozenset(groups[i]) for i in order]


def check_donation(
    g: Graph,
    donated: Iterable[str],
    groups: Sequence[frozenset[str]] | None = None,
) -> list[Finding]:
    """Statically audit donated parameter names against graph + plan.

    RA030 (ERROR): a donated input is itself a graph output — the
    passthrough aliases a buffer the runtime considers dead.
    RA031 (ERROR): a donated input is read by a group scheduled *after*
    the donating (first-reader) group — the second read would observe a
    reused buffer.
    RA032 (WARN): the donated name is not a parameter of this graph (or
    nothing reads it) — the donation is a no-op and likely a caller bug.
    """
    findings: list[Finding] = []
    schedule = _group_schedule(g, groups)
    for name in sorted(set(donated)):
        node = g.nodes.get(name)
        if node is None or not node.is_source():
            findings.append(Finding(
                "RA032", f"donated name {name!r} is not a graph input",
                node=name))
            continue
        if name in g.outputs:
            findings.append(Finding(
                "RA030", f"donated input {name!r} is passed through as a "
                         f"graph output", node=name))
        readers = [i for i, members in enumerate(schedule)
                   if any(name in g.nodes[m].operands for m in members
                          if m in g.nodes)]
        if not readers:
            if name not in g.outputs:
                findings.append(Finding(
                    "RA032", f"donated input {name!r} is never read",
                    node=name))
            continue
        first = readers[0]
        late = [i for i in readers[1:] if i != first]
        if late:
            findings.append(Finding(
                "RA031", f"donated input {name!r} read by group(s) {late} "
                         f"after donating group {first}", node=name,
                group=late[0]))
    return findings
