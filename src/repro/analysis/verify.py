"""Pass 1 — IR verifier: structural + per-OpKind shape/dtype legality.

``verify_graph`` statically checks a :class:`repro.core.Graph` without
executing it and without importing jax: every operand resolves (RA001),
the def-use relation is acyclic (RA002), declared outputs exist (RA003),
dtypes parse (RA004), and each node's shape is consistent with what the
executors (:mod:`repro.core.codegen` / the Pallas emitters) would
actually produce — dot contraction dims, broadcast dims, reduce axes,
reshape element counts, transpose perms, slice bounds, gather shapes.
Dead compute nodes are reported as warnings (RA005).

This is the real replacement for the thin ``Graph.validate()``:
``GraphBuilder``/``Graph.add`` enforce some of this at construction
time, but graphs arriving from disk records, hand mutation, or future
frontends do not get that protection.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.ir import Graph, OpKind, OpNode

from .findings import Finding

__all__ = ["verify_graph"]


def _broadcast_shapes(shapes: list[tuple[int, ...]]) -> tuple[int, ...] | None:
    """numpy-style broadcast result, or None when incompatible."""
    rank = max((len(s) for s in shapes), default=0)
    out = []
    for i in range(1, rank + 1):
        dim = 1
        for s in shapes:
            if i <= len(s):
                d = s[-i]
                if d == 1:
                    continue
                if dim not in (1, d):
                    return None
                dim = d
        out.append(dim)
    return tuple(reversed(out))


def _check_elementwise(g: Graph, n: OpNode) -> Finding | None:
    shapes = [g[o].shape for o in n.operands]
    if n.attrs.get("op") == "iota":
        return None                       # generator: no operand constraint
    bc = _broadcast_shapes(shapes)
    if bc is None:
        return Finding("RA010", f"operand shapes {shapes} do not broadcast",
                       node=n.name)
    if bc != n.shape:
        return Finding("RA010", f"operands {shapes} broadcast to {bc}, "
                                f"node declares {n.shape}", node=n.name)
    return None


def _check_broadcast(g: Graph, n: OpNode) -> Finding | None:
    src = g[n.operands[0]].shape
    dims = n.attrs.get("bcast_dims")
    if dims is None:
        return Finding("RA011", "missing bcast_dims attr", node=n.name)
    dims = tuple(dims)
    if len(dims) != len(src):
        return Finding("RA011", f"bcast_dims {dims} must have one entry per "
                                f"operand dim (operand shape {src})",
                       node=n.name)
    if any(not 0 <= d < len(n.shape) for d in dims) or \
            any(a >= b for a, b in zip(dims, dims[1:])):
        return Finding("RA011", f"bcast_dims {dims} not strictly increasing "
                                f"within output rank {len(n.shape)}",
                       node=n.name)
    for i, d in enumerate(dims):
        if src[i] not in (1, n.shape[d]):
            return Finding("RA011", f"operand dim {i} (={src[i]}) cannot map "
                                    f"to output dim {d} (={n.shape[d]})",
                           node=n.name)
    return None


def _check_reshape(g: Graph, n: OpNode) -> Finding | None:
    src = g[n.operands[0]].shape
    if math.prod(src) != math.prod(n.shape):
        return Finding("RA012", f"reshape {src} -> {n.shape} changes element "
                                f"count {math.prod(src)} -> "
                                f"{math.prod(n.shape)}", node=n.name)
    return None


def _check_transpose(g: Graph, n: OpNode) -> Finding | None:
    src = g[n.operands[0]].shape
    perm = n.attrs.get("perm")
    if perm is None or sorted(perm) != list(range(len(src))):
        return Finding("RA013", f"perm {perm} is not a permutation of "
                                f"rank-{len(src)} axes", node=n.name)
    want = tuple(src[p] for p in perm)
    if want != n.shape:
        return Finding("RA013", f"transpose of {src} by {tuple(perm)} gives "
                                f"{want}, node declares {n.shape}",
                       node=n.name)
    return None


def _check_reduction(g: Graph, n: OpNode) -> Finding | None:
    src = g[n.operands[0]].shape
    axes = n.attrs.get("axes")
    if axes is None:
        return Finding("RA014", "missing axes attr", node=n.name)
    axes = tuple(axes)
    if len(set(axes)) != len(axes) or \
            any(not 0 <= a < len(src) for a in axes):
        return Finding("RA014", f"axes {axes} invalid for operand rank "
                                f"{len(src)}", node=n.name)
    if n.attrs.get("keepdims", False):
        want = tuple(1 if i in axes else d for i, d in enumerate(src))
    else:
        want = tuple(d for i, d in enumerate(src) if i not in axes)
    if want != n.shape:
        return Finding("RA014", f"reduce of {src} over {axes} gives {want}, "
                                f"node declares {n.shape}", node=n.name)
    return None


def _check_dot(g: Graph, n: OpNode) -> Finding | None:
    if len(n.operands) != 2:
        return Finding("RA015", f"dot takes 2 operands, got "
                                f"{len(n.operands)}", node=n.name)
    lhs, rhs = g[n.operands[0]].shape, g[n.operands[1]].shape
    try:
        lc, rc = (tuple(d) for d in n.attrs["contract"])
        lb, rb = (tuple(d) for d in n.attrs.get("batch", ((), ())))
    except (KeyError, TypeError, ValueError):
        return Finding("RA015", f"malformed contract/batch attrs "
                                f"{n.attrs.get('contract')!r}", node=n.name)
    for dims, shape, side in ((lc, lhs, "lhs"), (rc, rhs, "rhs"),
                              (lb, lhs, "lhs"), (rb, rhs, "rhs")):
        if any(not 0 <= d < len(shape) for d in dims):
            return Finding("RA015", f"{side} dims {dims} out of range for "
                                    f"shape {shape}", node=n.name)
    if len(lc) != len(rc) or any(lhs[a] != rhs[b] for a, b in zip(lc, rc)):
        return Finding("RA015", f"contracted extents differ: lhs{lhs}@{lc} "
                                f"vs rhs{rhs}@{rc}", node=n.name)
    if len(lb) != len(rb) or any(lhs[a] != rhs[b] for a, b in zip(lb, rb)):
        return Finding("RA015", f"batch extents differ: lhs{lhs}@{lb} vs "
                                f"rhs{rhs}@{rb}", node=n.name)
    want = tuple(lhs[d] for d in lb) \
        + tuple(d for i, d in enumerate(lhs) if i not in lc and i not in lb) \
        + tuple(d for i, d in enumerate(rhs) if i not in rc and i not in rb)
    if want != n.shape:
        return Finding("RA015", f"dot_general({lhs}, {rhs}) gives {want}, "
                                f"node declares {n.shape}", node=n.name)
    return None


def _check_slice(g: Graph, n: OpNode) -> Finding | None:
    src = g[n.operands[0]].shape
    starts = n.attrs.get("starts")
    limits = n.attrs.get("limits")
    strides = n.attrs.get("strides") or (1,) * len(src)
    if starts is None or limits is None or \
            not len(starts) == len(limits) == len(strides) == len(src):
        return Finding("RA016", f"starts/limits {starts}/{limits} do not "
                                f"match operand rank {len(src)}", node=n.name)
    for s, l, d in zip(starts, limits, src):
        if not 0 <= s <= l <= d:
            return Finding("RA016", f"slice [{starts}:{limits}] out of "
                                    f"bounds for shape {src}", node=n.name)
    want = tuple(-(-(l - s) // st)
                 for s, l, st in zip(starts, limits, strides))
    if want != n.shape:
        return Finding("RA016", f"slice of {src} gives {want}, node "
                                f"declares {n.shape}", node=n.name)
    return None


def _check_gather(g: Graph, n: OpNode) -> Finding | None:
    if len(n.operands) != 2:
        return Finding("RA017", f"gather takes 2 operands, got "
                                f"{len(n.operands)}", node=n.name)
    table, idx = g[n.operands[0]].shape, g[n.operands[1]].shape
    want = idx + table[1:]
    if want != n.shape:
        return Finding("RA017", f"take(table{table}, idx{idx}) gives {want}, "
                                f"node declares {n.shape}", node=n.name)
    return None


_KIND_CHECKS = {
    OpKind.ELEMENTWISE: _check_elementwise,
    OpKind.BROADCAST: _check_broadcast,
    OpKind.RESHAPE: _check_reshape,
    OpKind.TRANSPOSE: _check_transpose,
    OpKind.REDUCTION: _check_reduction,
    OpKind.GEMM: _check_dot,
    OpKind.BATCHED_GEMM: _check_dot,
    OpKind.SLICE: _check_slice,
    OpKind.GATHER: _check_gather,
    # CUSTOM / SCATTER / TUPLE: opaque or shape-free carriers — only the
    # structural checks (operands, cycles, dtype) apply
}


def verify_graph(g: Graph) -> list[Finding]:
    """Run every IR check; returns all findings (empty = clean)."""
    findings: list[Finding] = []

    # -- structural: operands resolve, outputs exist -----------------------
    resolved: set[str] = set()
    for n in g.nodes.values():
        missing = [o for o in n.operands if o not in g.nodes]
        if missing:
            findings.append(Finding(
                "RA001", f"operand(s) {missing} undefined", node=n.name))
        else:
            resolved.add(n.name)
    for out in g.outputs:
        if out not in g.nodes:
            findings.append(Finding(
                "RA003", f"declared output {out!r} not in graph", node=out))

    # -- cycles (Kahn over edges whose endpoints both exist) ---------------
    indeg = {name: 0 for name in g.nodes}
    users: dict[str, list[str]] = {name: [] for name in g.nodes}
    for n in g.nodes.values():
        for o in n.operands:
            if o in g.nodes:
                indeg[n.name] += 1
                users[o].append(n.name)
    ready = [name for name, d in indeg.items() if d == 0]
    seen = 0
    while ready:
        cur = ready.pop()
        seen += 1
        for u in users[cur]:
            indeg[u] -= 1
            if indeg[u] == 0:
                ready.append(u)
    if seen != len(g.nodes):
        stuck = sorted(name for name, d in indeg.items() if d > 0)
        findings.append(Finding(
            "RA002", f"def-use cycle through {stuck[:6]}"
                     + ("..." if len(stuck) > 6 else ""),
            node=stuck[0] if stuck else None))

    # -- dtypes ------------------------------------------------------------
    for n in g.nodes.values():
        try:
            np.dtype(n.dtype)
        except (TypeError, ValueError):
            findings.append(Finding(
                "RA004", f"dtype {n.dtype!r} is not a numpy dtype",
                node=n.name))

    # -- per-kind shape rules (only on nodes whose operands resolve, so a
    #    single missing node does not cascade into shape noise) ------------
    for n in g.nodes.values():
        if n.name not in resolved:
            continue
        check = _KIND_CHECKS.get(n.kind)
        if check is None:
            continue
        if n.kind is not OpKind.ELEMENTWISE and not n.operands:
            continue                        # structurally hopeless; RA001-ish
        f = check(g, n)
        if f is not None:
            findings.append(f)

    # -- dead compute nodes (reverse reachability from outputs) ------------
    live: set[str] = set()
    stack = [o for o in g.outputs if o in g.nodes]
    while stack:
        cur = stack.pop()
        if cur in live:
            continue
        live.add(cur)
        stack.extend(o for o in g.nodes[cur].operands if o in g.nodes)
    for n in g.nodes.values():
        if n.kind in (OpKind.PARAMETER, OpKind.CONSTANT):
            continue
        if n.name not in live:
            # an unused projection of a live multi-output custom base is
            # structural, not dead compute: the kernel produces it whether
            # or not anyone reads it (e.g. a scan's final-state output)
            if ("project" in n.attrs and n.operands
                    and n.operands[0] in live):
                continue
            findings.append(Finding(
                "RA005", "compute node feeds no graph output", node=n.name))

    return findings
