"""Pass 4 — KV/refcount auditor: conservation over a paged-KV snapshot.

The paged serving stack shares pages three ways: slot page tables
(``PagedKV._slot_pages``), the content-hashed prefix cache
(``PrefixCache`` entries), and the refcounted free-list allocator
(``PageAllocator``).  The conservation law: every usable page (1..N-1;
page 0 is the sink) is either on the free list or refcounted, never
both, and its refcount equals the number of owners holding it (slot
lists + prefix entries).  Leaked pages (refcounted, no owner) and
double-owned pages (more owners than refs) are the two bug classes that
silently shrink or corrupt the pool under load — both are ERRORs here.

``snapshot`` reads the live objects duck-typed (plain ints/lists only,
no jax arrays cross the boundary), so ``audit_kv`` stays executable in a
jax-free process and on serialized snapshots.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .findings import Finding

__all__ = ["KVSnapshot", "snapshot", "audit_kv"]


@dataclass
class KVSnapshot:
    """Pure-data view of allocator + page tables + prefix cache."""

    num_pages: int                       # including the page-0 sink
    free: list[int] = field(default_factory=list)
    refs: dict[int, int] = field(default_factory=dict)
    slot_pages: list[list[int]] = field(default_factory=list)
    prefix_pages: dict[str, list[int]] = field(default_factory=dict)
    # optional: the device-facing table rows (slots x pages_per_slot);
    # unowned tail entries must point at the page-0 sink
    table: list[list[int]] | None = None
    slot_lengths: list[int] | None = None
    page_size: int | None = None
    # False when captured from a bare allocator: free/refcount conservation
    # still holds, but "who owns this page" is unknowable, so the
    # leak/double-own checks are skipped
    owners_known: bool = True


def snapshot(kv=None, allocator=None, prefix=None) -> KVSnapshot:
    """Duck-typed capture of a live ``PagedKV`` (or bare ``PageAllocator``)
    plus an optional ``PrefixCache``."""
    if allocator is None:
        if kv is None:
            raise ValueError("need a PagedKV or a PageAllocator")
        allocator = kv.allocator
    snap = KVSnapshot(
        num_pages=int(allocator.num_pages),
        free=[int(p) for p in allocator._free],
        refs={int(p): int(r) for p, r in allocator._refs.items()},
        owners_known=kv is not None or prefix is not None,
    )
    if kv is not None:
        snap.slot_pages = [[int(p) for p in pages]
                           for pages in kv._slot_pages]
        snap.table = [[int(p) for p in row] for row in kv.table]
        snap.slot_lengths = [int(x) for x in kv.lengths]
        snap.page_size = int(kv.page_size)
    if prefix is not None:
        snap.prefix_pages = {key: [int(p) for p in e.pages]
                             for key, e in prefix._entries.items()}
    return snap


def audit_kv(snap: KVSnapshot) -> list[Finding]:
    """Run the conservation checks; returns all findings (empty = clean)."""
    findings: list[Finding] = []
    usable = range(1, snap.num_pages)
    free_set = set(snap.free)

    # -- free-list structure -----------------------------------------------
    if len(free_set) != len(snap.free):
        dups = sorted({p for p in snap.free if snap.free.count(p) > 1})
        findings.append(Finding(
            "RA045", f"free list contains duplicates {dups[:6]}",
            page=dups[0]))
    for p in sorted(free_set):
        if not 1 <= p < snap.num_pages:
            findings.append(Finding(
                "RA045", f"free list holds out-of-range/sink page {p} "
                         f"(usable: 1..{snap.num_pages - 1})", page=p))

    # -- owners: slot tables + prefix entries ------------------------------
    owners: dict[int, list[str]] = {}
    for slot, pages in enumerate(snap.slot_pages):
        for p in pages:
            owners.setdefault(p, []).append(f"slot{slot}")
    for key, pages in snap.prefix_pages.items():
        for p in pages:
            owners.setdefault(p, []).append(f"prefix:{key[:8]}")
    for p in sorted(owners):
        if not 1 <= p < snap.num_pages:
            findings.append(Finding(
                "RA045", f"owned page {p} out of usable range "
                         f"(owners: {owners[p]})", page=p))

    # -- conservation ------------------------------------------------------
    for p in usable:
        on_free = p in free_set
        refs = snap.refs.get(p)
        own = owners.get(p, [])
        if on_free and refs is not None:
            findings.append(Finding(
                "RA041", f"page {p} is on the free list with refcount "
                         f"{refs}", page=p))
            continue
        if not on_free and refs is None:
            findings.append(Finding(
                "RA040", f"page {p} is neither free nor allocated"
                         + (f" (owners: {own})" if own else ""), page=p))
            continue
        if on_free:
            if own:
                findings.append(Finding(
                    "RA046", f"free page {p} still owned by {own}", page=p))
            continue
        # allocated: refcount must match owner count
        if refs is not None and refs < 1:
            findings.append(Finding(
                "RA042", f"page {p} has non-positive refcount {refs}",
                page=p))
        elif not snap.owners_known:
            pass                      # bare allocator: no ownership to check
        elif not own:
            findings.append(Finding(
                "RA043", f"page {p} refcounted ({refs}) but owned by "
                         f"nobody — leaked", page=p))
        elif len(own) > refs:
            findings.append(Finding(
                "RA044", f"page {p} owned {len(own)}x ({own}) but "
                         f"refcount is {refs} — double-owned", page=p))
        elif len(own) < refs:
            findings.append(Finding(
                "RA042", f"page {p} refcount {refs} != owner count "
                         f"{len(own)} ({own})", page=p))
    for p, r in sorted(snap.refs.items()):
        if not 1 <= p < snap.num_pages:
            findings.append(Finding(
                "RA045", f"refcount table holds out-of-range/sink page {p} "
                         f"(refs={r})", page=p))

    # -- device table rows vs host ownership -------------------------------
    if snap.table is not None:
        for slot, row in enumerate(snap.table):
            owned = snap.slot_pages[slot] if slot < len(snap.slot_pages) \
                else []
            want = owned + [0] * (len(row) - len(owned))
            if list(row) != want:
                findings.append(Finding(
                    "RA047", f"slot {slot} table row {list(row)} != owned "
                             f"pages {owned} + sink padding", group=slot))
        if snap.slot_lengths is not None and snap.page_size:
            for slot, ln in enumerate(snap.slot_lengths):
                owned = len(snap.slot_pages[slot]) \
                    if slot < len(snap.slot_pages) else 0
                need = -(-ln // snap.page_size)       # ceil
                if ln and owned < need:
                    findings.append(Finding(
                        "RA047", f"slot {slot} length {ln} needs {need} "
                                 f"page(s), owns {owned}", group=slot))
    return findings
