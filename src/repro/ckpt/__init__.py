from . import store
from .store import AsyncCheckpointer, latest_step, restore, save
