"""Sharded, mesh-agnostic checkpointing with an async writer.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json        # step, leaf paths, shapes, dtypes
        leaf_<i>.npy         # one file per pytree leaf (full array)
    <dir>/LATEST             # atomic pointer (written last)

Design points for the 1000-node posture:
* **mesh-agnostic**: leaves are stored as full logical arrays; on restore
  they are re-sharded to whatever mesh is alive (elastic scaling).  On a
  real multi-host cluster the .npy write becomes a per-shard write keyed by
  ``(leaf, shard_index)`` — the manifest format already carries everything
  needed; this container has one host so leaves are whole.
* **crash-safe**: data is written to ``step_XXX.tmp`` then renamed; LATEST
  is updated only after the rename, so a torn write can never be LATEST.
* **async**: ``save_async`` snapshots to host memory (device_get) and hands
  the serialization to a writer thread so the step loop is not blocked.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        out.append((jax.tree_util.keystr(path), leaf))
    return out, treedef


def save(tree, directory: str | os.PathLike, step: int) -> Path:
    """Synchronous checkpoint write. Returns the final step directory."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    # LATEST pointer last -> crash safety
    latest_tmp = directory / "LATEST.tmp"
    latest_tmp.write_text(final.name)
    os.replace(latest_tmp, directory / "LATEST")
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread (cheap device_get), serialize on a
    background thread; ``wait()`` joins the in-flight write."""

    def __init__(self, directory: str | os.PathLike):
        self.directory = Path(directory)
        self._thread: threading.Thread | None = None
        self.last_path: Path | None = None

    def save_async(self, tree, step: int) -> None:
        self.wait()
        snapshot = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def _write():
            self.last_path = save(snapshot, self.directory, step)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str | os.PathLike) -> int | None:
    latest = Path(directory) / "LATEST"
    if not latest.exists():
        return None
    name = latest.read_text().strip()
    return int(name.split("_")[-1])


def restore(directory: str | os.PathLike, like, step: int | None = None,
            shardings=None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  With `shardings`, leaves are device_put to the new
    mesh — this is the elastic re-shard path."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    cdir = directory / f"step_{step:08d}"
    with open(cdir / "manifest.json") as f:
        manifest = json.load(f)
    by_path = {l["path"]: l for l in manifest["leaves"]}

    leaves, treedef = _flatten(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _flatten(shardings)[0]]
    out = []
    for i, (path, leaf) in enumerate(leaves):
        meta = by_path.get(path)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = np.load(cdir / meta["file"])
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{path}: ckpt shape {arr.shape} != {want_shape}")
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
