"""Cache policies: shape bucketing + eviction.

Shape bucketing (the dynamic-shape story): serving traffic produces a spread
of sequence lengths; compiling a fresh plan for every length would defeat
the cache.  ``BucketPolicy`` coarsens each shape before it enters the cache
key, so a plan compiled at one length serves nearby lengths.  The default
rule rounds every dimension ``>= min_dim`` up to the next power of two —
symmetric across dims, so derived shapes (reduction outputs, broadcasts)
bucket consistently with their parents and the per-node shape tuple of two
nearby-length traces digests identically.

Replay at a different concrete shape inside the bucket is always *valid*
(plans are structural); per-kernel row blocks are re-clamped to the new
row count when the stitched callable is instantiated.

Eviction: the in-memory tier is a plain LRU bounded by entry count — plan
records are tiny (KBs); the bound exists to keep a long-lived serving
process from accumulating one entry per (model x bucket) forever.  The disk
tier is unbounded by default (one small JSON per entry) with an optional
``max_entries`` pruned oldest-first on insert.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["BucketPolicy", "EvictionPolicy", "BucketStats"]


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length() if n > 0 else 0


@dataclass(frozen=True)
class BucketPolicy:
    """Pad-to-bucket rules applied to every node shape before keying."""

    mode: str = "pow2"        # "pow2" | "exact"
    min_dim: int = 16         # dims below this stay exact (heads, ranks, ...)

    def bucket_dim(self, d: int) -> int:
        if self.mode == "exact" or d < self.min_dim:
            return d
        return _next_pow2(d)

    def bucket_shape(self, shape: tuple[int, ...]) -> tuple[int, ...]:
        return tuple(self.bucket_dim(int(d)) for d in shape)


@dataclass(frozen=True)
class EvictionPolicy:
    memory_entries: int = 128       # in-memory LRU capacity
    disk_entries: int | None = None  # None = unbounded


@dataclass
class BucketStats:
    """Per-bucket hit/miss accounting (observability for the serving tier).

    Lookups also aggregate per *placement* (the mesh+PartitionSpec key of a
    sharded compile, ``""`` for single-device) so a multi-mesh deployment
    can see which mesh is cold — a miss storm isolated to one placement
    means that mesh has never been compiled, not that the cache is broken.
    """

    hits: dict[str, int] = field(default_factory=dict)
    misses: dict[str, int] = field(default_factory=dict)
    placement_hits: dict[str, int] = field(default_factory=dict)
    placement_misses: dict[str, int] = field(default_factory=dict)
    # unreadable record files per bucket (disk JSON corruption read as a miss)
    corrupt: dict[str, int] = field(default_factory=dict)
    # records demoted to a miss by replay verification (repro.analysis)
    demoted: dict[str, int] = field(default_factory=dict)

    def record(self, bucket: str, hit: bool, placement: str = "") -> None:
        d = self.hits if hit else self.misses
        d[bucket] = d.get(bucket, 0) + 1
        p = self.placement_hits if hit else self.placement_misses
        label = placement or "single-device"
        p[label] = p.get(label, 0) + 1

    def record_corrupt(self, bucket: str) -> None:
        self.corrupt[bucket] = self.corrupt.get(bucket, 0) + 1

    def record_demoted(self, bucket: str) -> None:
        self.demoted[bucket] = self.demoted.get(bucket, 0) + 1

    @property
    def total_hits(self) -> int:
        return sum(self.hits.values())

    @property
    def total_misses(self) -> int:
        return sum(self.misses.values())

    def hit_rate(self, bucket: str | None = None) -> float:
        if bucket is None:
            h, m = self.total_hits, self.total_misses
        else:
            h, m = self.hits.get(bucket, 0), self.misses.get(bucket, 0)
        return h / (h + m) if (h + m) else 0.0

    def as_dict(self) -> dict:
        return {
            "total_hits": self.total_hits,
            "total_misses": self.total_misses,
            "total_corrupt": sum(self.corrupt.values()),
            "total_demoted": sum(self.demoted.values()),
            "corrupt": dict(self.corrupt),
            "demoted": dict(self.demoted),
            "per_bucket": {
                b: {"hits": self.hits.get(b, 0), "misses": self.misses.get(b, 0)}
                for b in sorted(set(self.hits) | set(self.misses))
            },
            "per_placement": {
                p: {"hits": self.placement_hits.get(p, 0),
                    "misses": self.placement_misses.get(p, 0)}
                for p in sorted(set(self.placement_hits)
                                | set(self.placement_misses))
            },
        }
