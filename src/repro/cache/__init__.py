"""StitchCache — persistent fusion-plan cache with shape bucketing.

The optimize-once/run-many amortization layer: canonical graph signatures
(:mod:`.signature`), pad-to-bucket shape rules + LRU eviction
(:mod:`.policy`), a two-tier memory+disk store (:mod:`.store`), and the
cache facade / miss-then-upgrade compilation service (:mod:`.service`).
"""

from .policy import BucketPolicy, BucketStats, EvictionPolicy
from .signature import (GraphSignature, compute_signature, node_struct_hashes,
                        placement_key, token_prefix_keys)
from .store import DiskStore, GroupRecord, MemoryStore, PlanRecord, TwoTierStore
from .service import CompilationService, StitchCache, extract_record, replay_record

__all__ = [
    "BucketPolicy", "BucketStats", "EvictionPolicy",
    "GraphSignature", "compute_signature", "node_struct_hashes", "placement_key",
    "token_prefix_keys",
    "DiskStore", "GroupRecord", "MemoryStore", "PlanRecord", "TwoTierStore",
    "CompilationService", "StitchCache", "extract_record", "replay_record",
]
