"""StitchCache — persistent fusion-plan cache with shape bucketing.

The optimize-once/run-many amortization layer: canonical graph signatures
(:mod:`.signature`), pad-to-bucket shape rules + LRU eviction
(:mod:`.policy`), a two-tier memory+disk store (:mod:`.store`), and the
cache facade / miss-then-upgrade compilation service (:mod:`.service`).
"""

from .policy import BucketPolicy, BucketStats, EvictionPolicy
from .signature import (GraphSignature, compute_signature, node_struct_hashes,
                        placement_key, token_prefix_keys)
from .store import DiskStore, GroupRecord, MemoryStore, PlanRecord, TwoTierStore

# service.py reaches into repro.core.compiler (jax); the record/policy/
# signature layers above are pure Python.  Lazy-loading the service keeps
# the on-disk record format — what the repro.analysis offline cache audit
# reads — importable in a jax-free process.
_LAZY = {"CompilationService": ".service", "StitchCache": ".service",
         "extract_record": ".service", "replay_record": ".service"}


def __getattr__(name):
    submodule = _LAZY.get(name)
    if submodule is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    from importlib import import_module
    return getattr(import_module(submodule, __name__), name)

__all__ = [
    "BucketPolicy", "BucketStats", "EvictionPolicy",
    "GraphSignature", "compute_signature", "node_struct_hashes", "placement_key",
    "token_prefix_keys",
    "DiskStore", "GroupRecord", "MemoryStore", "PlanRecord", "TwoTierStore",
    "CompilationService", "StitchCache", "extract_record", "replay_record",
]
