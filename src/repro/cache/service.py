"""StitchCache facade + compilation service (miss-then-upgrade).

:class:`StitchCache` binds the three lower pieces together — signatures
(:mod:`.signature`), bucketing/eviction (:mod:`.policy`), and the two-tier
store (:mod:`.store`) — behind two operations:

* ``lookup(g, compiler)``  — signature the graph, probe the store, and on a
  hit *replay* the record: rebuild executable groups on the new graph
  (canonical indices -> this graph's node names), re-instantiating stitched
  Pallas callables from the recorded ``(row_block, scratch)`` choice.  The
  expensive head of compilation — pattern generation, ILP solving, template
  enumeration — is skipped entirely.
* ``insert(g, compiled)``  — extract a :class:`PlanRecord` in canonical
  coordinates from a freshly compiled graph and write it through both tiers.

:class:`CompilationService` is the serving-path wrapper: ``compile_or_
fallback`` answers *immediately* — with the replayed stitched executable on
a hit, or with a cheap unfused/XLA-mode executable on a miss — while a
background thread runs the full stitch pipeline and populates the cache, so
the *next* request for the same (graph, bucket) upgrades to stitched
kernels.  Tail latency never pays the tuner's cost.
"""

from __future__ import annotations

import copy
import dataclasses
import threading
import time

from repro import obs
from repro.core.compiler import CompiledGraph, FusionStats, StitchCompiler, _Group
from repro.core.cost import HardwareModel, TPU_V5E
from repro.core.ir import Graph
from repro.core.pattern import FusionPattern, PackPattern
from repro.core.tuner import grid_row_block

from .policy import BucketPolicy, BucketStats, EvictionPolicy
from .signature import GraphSignature, compute_signature, config_key
from .store import DiskStore, GroupRecord, MemoryStore, PlanRecord, TwoTierStore

__all__ = ["StitchCache", "CompilationService", "extract_record", "replay_record"]


def extract_record(
    g: Graph,
    sig: GraphSignature,
    compiled: CompiledGraph,
    bucket_key: str,
    hw: str,
    solve_seconds: float = 0.0,
    placement: str = "",
    config: str = "",
) -> PlanRecord:
    """Freeze a compiled plan into canonical coordinates."""
    idx = sig.node_to_index
    groups = []
    for grp in compiled.groups:
        row_block = None
        scratch: tuple[int, ...] = ()
        if grp.tuned is not None:
            row_block = grid_row_block(grp.tuned.template)
            scratch = tuple(sorted(idx[n] for n in grp.tuned.template.scratch_ops))
        pack: tuple[tuple[int, ...], ...] = ()
        if getattr(grp, "pack", None):
            pack = tuple(sorted(
                tuple(sorted(idx[m] for m in gset)) for gset in grp.pack
            ))
        groups.append(
            GroupRecord(
                members=tuple(sorted(idx[m] for m in grp.members)),
                kind=grp.kind,
                row_block=row_block,
                scratch=scratch,
                pack=pack,
            )
        )
    ilp = compiled.stats.ilp
    return PlanRecord(
        graph_key=sig.graph_key,
        bucket_key=bucket_key,
        shape_key=sig.shape_key,
        mode=compiled.stats.mode,
        hw=hw,
        n_nodes=len(sig.canon_order),
        groups=tuple(groups),
        objective=ilp.objective if ilp else 0.0,
        ilp_iterations=ilp.iterations if ilp else 0,
        solve_seconds=solve_seconds,
        placement=placement,
        config=config,
    )


def replay_record(
    g: Graph, sig: GraphSignature, rec: PlanRecord, compiler: StitchCompiler
) -> CompiledGraph | None:
    """Rebuild an executable from a record, skipping search/solve/tune.

    Returns None when the record cannot apply (node-count mismatch from a
    hash collision) — the caller falls back to a cold compile.  Pallas
    groups that fail to re-instantiate at this graph's concrete shapes
    (bucketed hit at a new length outside the kernel's feasible blocks)
    degrade to fused-jnp groups; numerics are unaffected.
    """
    if rec.n_nodes != len(sig.canon_order):
        return None
    names = sig.canon_order
    n = len(names)
    for gr in rec.groups:          # corrupt/hand-edited records: treat as miss
        flat_pack = tuple(i for gset in getattr(gr, "pack", ()) for i in gset)
        if any(not 0 <= i < n for i in gr.members + gr.scratch + flat_pack):
            return None
    stats = FusionStats(
        mode=compiler.mode,
        n_ops=len(g.compute_nodes()),
        n_kernels=0,
        cache_status="hit",
    )
    groups: list[_Group] = []
    covered: set[str] = set()
    diag_start = len(compiler.tuner.diagnostics)
    for gr in rec.groups:
        members = frozenset(names[i] for i in gr.members)
        covered |= members
        if gr.kind == "op" or len(members) == 1 and gr.kind != "pallas":
            groups.append(_Group(members, "op"))
            continue
        pack = tuple(
            frozenset(names[i] for i in gset)
            for gset in getattr(gr, "pack", ())
        ) or None
        if pack:
            try:
                p: FusionPattern = PackPattern(g, members, "cache",
                                               member_groups=pack)
            except ValueError:
                return None        # malformed pack provenance: treat as miss
            stats.packs += 1
            stats.packed_subgraphs += len(pack)
        else:
            p = FusionPattern(g, members, "cache")
        stats.pattern_classes[p.pattern_class] = (
            stats.pattern_classes.get(p.pattern_class, 0) + 1
        )
        tuned = None
        if gr.kind == "pallas" and compiler.use_pallas:
            tuned = compiler.tuner.instantiate(
                p,
                row_block=gr.row_block,
                scratch_names=[names[i] for i in gr.scratch],
            )
        if tuned is not None:
            groups.append(_Group(members, "pallas", tuned, pack))
            stats.pallas_groups += 1
            stats.scratch_requested += sum(compiler.cost.scratch_request(p).values())
            stats.scratch_allocated += tuned.scratch_plan.allocated
            if tuned.scratch_plan.allocated:
                stats.patterns_with_scratch += 1
        else:
            groups.append(_Group(members, "jnp", None, pack))
    # a record always covers every compute node of an isomorphic graph, but
    # degrade gracefully if it somehow doesn't
    for node in g.compute_nodes():
        if node.name not in covered:
            groups.append(_Group(frozenset([node.name]), "op"))
    stats.n_kernels = len(groups)
    stats.diagnostics = list(compiler.tuner.diagnostics[diag_start:])
    stats.modeled_time = compiler.modeled_time(g, [grp.members for grp in groups])
    return CompiledGraph(g, groups, stats)


class StitchCache:
    """Thread-safe two-tier fusion-plan cache with shape bucketing."""

    def __init__(
        self,
        directory: str | None = None,
        bucket_policy: BucketPolicy | None = None,
        eviction: EvictionPolicy | None = None,
    ):
        eviction = eviction or EvictionPolicy()
        self.bucket_policy = bucket_policy or BucketPolicy()
        disk = (
            DiskStore(directory, max_entries=eviction.disk_entries,
                      on_corrupt=self._note_corrupt)
            if directory is not None
            else None
        )
        self.store = TwoTierStore(MemoryStore(eviction.memory_entries), disk)
        self.stats = BucketStats()
        self._lock = threading.RLock()
        # keys whose replayed record failed static verification (warn once)
        self._verify_warned: set[tuple] = set()
        # Live-artifact memo: (id(graph), mode, hw, use_pallas) -> (graph,
        # artifact, bucket, node count at memo time).  Replay on a record rebuilds
        # Pallas callables (cheap but not free); recompiling the *same*
        # unmutated Graph object can skip even that.  The value holds a
        # strong ref to the graph so the id key cannot be recycled.
        self._live: "dict[tuple, tuple[Graph, CompiledGraph, str, int]]" = {}
        self._live_capacity = eviction.memory_entries

    # -- keys -----------------------------------------------------------------
    def key_for(self, sig: GraphSignature, mode: str = "stitch",
                hw: str = "", placement: str = "", config: str = "") -> tuple:
        # hw is part of the durable key: a plan tuned for one chip's launch
        # latency / on-chip budget must not shadow the other chip's optimum.
        # placement (mesh + PartitionSpecs, see signature.placement_key) is
        # too: a plan solved at one mesh's shard-local shapes never replays
        # at another mesh or at the single-device ("") placement.  config is
        # the GenConfig digest (signature.config_key): different
        # pattern-generation knobs legitimately produce different plans.
        return (sig.graph_key, sig.bucket_key(self.bucket_policy), mode, hw,
                placement, config)

    def signature_of(self, g: Graph) -> GraphSignature:
        return compute_signature(g)

    # -- operations -----------------------------------------------------------
    def lookup(
        self,
        g: Graph,
        compiler: StitchCompiler,
        sig: GraphSignature | None = None,
        count: bool = True,
    ) -> CompiledGraph | None:
        placement = getattr(compiler, "placement", "")
        cfg_key = config_key(getattr(compiler, "gen_cfg", None))
        live_key = (id(g), compiler.mode, compiler.hw.name,
                    compiler.use_pallas, placement, cfg_key)
        with self._lock:
            live = self._live.get(live_key)
        if live is not None and live[0] is g and live[3] == len(g.nodes):
            if count:
                with self._lock:
                    self.stats.record(live[2], hit=True, placement=placement)
            art = copy.copy(live[1])   # fresh stats: don't rewrite the miss's
            art.stats = dataclasses.replace(live[1].stats, cache_status="hit")
            return art
        sig = sig or compute_signature(g)
        key = self.key_for(sig, compiler.mode, compiler.hw.name, placement,
                           cfg_key)
        with self._lock:
            rec = self.store.get(key)
        if rec is not None and getattr(compiler, "verify", "plans") != "off":
            # static plan verification against the *live* graph: a stale,
            # corrupt, or hand-edited record is demoted to a miss here —
            # never instantiated — and the recompile overwrites it
            rec = self._verified(g, sig, rec, compiler, key)
        compiled = None
        if rec is not None:
            try:
                compiled = replay_record(g, sig, rec, compiler)
            except Exception:
                compiled = None            # unreplayable record == miss
            if compiled is not None:
                self._remember_live(g, compiled, compiler, key[1])
        if count:
            with self._lock:
                self.stats.record(key[1], hit=compiled is not None,
                                  placement=placement)
        return compiled

    def _note_corrupt(self, key: tuple) -> None:
        """DiskStore callback: count an unreadable record in bucket stats."""
        with self._lock:
            self.stats.record_corrupt(key[1])

    def _verified(self, g: Graph, sig: GraphSignature, rec: PlanRecord,
                  compiler, key: tuple) -> PlanRecord | None:
        from repro.analysis import errors, format_findings, verify_record

        budget = getattr(compiler, "gen_cfg", None)
        budget = budget.scratch_budget if budget is not None else None
        if budget is None:
            budget = compiler.hw.onchip_budget
        findings = verify_record(g, sig.canon_order, rec,
                                 scratch_budget=budget, cost=compiler.cost,
                                 reg_budget=compiler.cost.reg_budget)
        bad = errors(findings)
        if not bad:
            return rec
        with self._lock:
            self.stats.record_demoted(key[1])
            warn = key not in self._verify_warned
            self._verify_warned.add(key)
        if warn:
            import warnings

            warnings.warn(
                f"cached plan for graph {g.name!r} (bucket {key[1][:12]}) "
                f"failed static verification and was demoted to a miss:\n"
                f"{format_findings(bad, limit=5)}",
                RuntimeWarning, stacklevel=4)
        obs.event("cache.verify_demote", cat="cache", graph=g.name,
                  bucket=key[1], codes=sorted({f.code for f in bad}))
        return None

    def _remember_live(self, g: Graph, compiled: CompiledGraph, compiler,
                       bucket: str) -> None:
        with self._lock:
            if len(self._live) >= self._live_capacity:
                self._live.clear()
            self._live[(id(g), compiler.mode, compiler.hw.name,
                        compiler.use_pallas,
                        getattr(compiler, "placement", ""),
                        config_key(getattr(compiler, "gen_cfg", None)))] = (
                g, compiled, bucket, len(g.nodes))

    def insert(
        self,
        g: Graph,
        compiled: CompiledGraph,
        sig: GraphSignature | None = None,
        solve_seconds: float = 0.0,
        compiler: StitchCompiler | None = None,
    ) -> PlanRecord:
        sig = sig or compute_signature(g)
        bucket = sig.bucket_key(self.bucket_policy)
        hw = compiler.hw.name if compiler is not None else ""
        placement = getattr(compiler, "placement", "") if compiler else ""
        cfg_key = (config_key(getattr(compiler, "gen_cfg", None))
                   if compiler is not None else config_key())
        rec = extract_record(g, sig, compiled, bucket, hw, solve_seconds,
                             placement=placement, config=cfg_key)
        with self._lock:
            self.store.put(rec)
        if compiler is not None:
            self._remember_live(g, compiled, compiler, bucket)
        return rec

    def report(self) -> dict:
        with self._lock:
            out = self.stats.as_dict()
            out["memory_entries"] = len(self.store.memory)
            out["memory_evictions"] = self.store.memory.evictions
            out["disk_put_errors"] = self.store.disk_put_errors
            if self.store.disk is not None:
                out["disk_entries"] = len(self.store.disk)
                out["disk_corrupt_reads"] = self.store.disk.corrupt_reads
        return out


class CompilationService:
    """Warm-start compilation frontend for the serving tier."""

    def __init__(
        self,
        cache: StitchCache | None = None,
        hw: HardwareModel = TPU_V5E,
        fallback_mode: str = "xla",
        gen_cfg=None,
        use_pallas: bool = True,
        max_background: int = 2,
        plan_budget: float | None = None,
    ):
        assert fallback_mode in ("off", "xla")
        self.cache = cache or StitchCache()
        self.hw = hw
        self.fallback_mode = fallback_mode
        self.gen_cfg = gen_cfg
        self.use_pallas = use_pallas
        self.max_background = max_background
        # wall-clock budget (seconds) for the fusion-plan ILP of every
        # compile this service spawns — see core.ilp's anytime mode; None
        # means solve to optimality
        self.plan_budget = plan_budget
        self._lock = threading.Lock()
        self._pending: set[tuple] = set()
        self._threads: list[threading.Thread] = []
        self.last_error: str | None = None   # last background-compile failure
        self.errors: dict[tuple, str] = {}   # per-key background failures

    def compiler(self, mode: str, placement: str = "") -> StitchCompiler:
        return StitchCompiler(
            hw=self.hw,
            mode=mode,
            gen_cfg=self.gen_cfg,
            use_pallas=self.use_pallas,
            cache=self.cache if mode == "stitch" else None,
            placement=placement,
            plan_budget=self.plan_budget,
        )

    def error_for(self, sig: GraphSignature, placement: str = "") -> str | None:
        """The recorded background-compile failure for this graph's stitch
        key, or None.  Engines poll it so a doomed compile is surfaced
        (warn-once + report) instead of silently serving the fallback."""
        key = self.cache.key_for(sig, "stitch", self.hw.name, placement,
                                 config_key(self.gen_cfg))
        with self._lock:
            return self.errors.get(key)

    def error_report(self) -> dict[str, str]:
        """Every recorded background failure, keyed by a stable readable
        string (``graph_key/bucket/mode/hw/placement``) — what the unified
        ``StitchedFunction.report()['errors']`` exposes."""
        with self._lock:
            return {"/".join(str(p) for p in key): msg
                    for key, msg in self.errors.items()}

    def compile(self, g: Graph, placement: str = "") -> CompiledGraph:
        """Blocking cache-aware full compile (offline / warmup path)."""
        return self.compiler("stitch", placement).compile(g)

    def compile_or_fallback(self, g: Graph,
                            placement: str = "") -> tuple[CompiledGraph, str]:
        """Never blocks on the stitch pipeline.

        Returns ``(executable, status)`` where status is ``"hit"`` (replayed
        stitched plan), ``"pending"`` (a background compile for this key is
        already in flight, or the worker cap deferred it), or ``"miss"``
        (fallback returned now, upgrade kicked off in the background).

        ``placement`` is the mesh+PartitionSpec key the graph was traced at
        (shard-local shapes); it scopes both the lookup and the background
        compile's insert, so meshes never shadow each other's plans.
        """
        stitch = self.compiler("stitch", placement)
        sig = compute_signature(g)
        hit = self.cache.lookup(g, stitch, sig=sig)
        # one hit-or-miss event per compiled graph: timeline evidence of
        # which requests replayed a plan and which served the fallback
        obs.event("cache.hit" if hit is not None else "cache.miss",
                  cat="cache", graph=g.name, placement=placement,
                  bucket=sig.bucket_key(self.cache.bucket_policy))
        if hit is not None:
            return hit, "hit"
        fallback = self.compiler(self.fallback_mode).compile(g)
        spawned = self.ensure_compiling(g, sig=sig, placement=placement)
        return fallback, "miss" if spawned else "pending"

    def ensure_compiling(self, g: Graph, sig: GraphSignature | None = None,
                         placement: str = "") -> bool:
        """Kick the background stitch compile for ``g`` unless one is already
        in flight for its key.  Returns True when a new compile was spawned.
        A request deferred by the worker cap (cold-start burst) is re-kicked
        by calling this again; a key whose compile *failed* is never retried
        — the failure is recorded in ``errors`` and callers surface it via
        :meth:`error_for`."""
        sig = sig or compute_signature(g)
        key = self.cache.key_for(sig, "stitch", self.hw.name, placement,
                                 config_key(self.gen_cfg))
        with self._lock:
            self._threads = [x for x in self._threads if x.is_alive()]
            if key in self._pending:
                return False
            if key in self.errors:
                # this key's compile already failed: re-running it would fail
                # the same way forever — callers surface it via error_for()
                return False
            if len(self._threads) >= self.max_background:
                # bounded worker count: don't stack N ILP+tuning pipelines on
                # a cold-start burst; this key retries on a later call
                return False
            self._pending.add(key)
        stitch = self.compiler("stitch", placement)
        obs.event("compile.start", cat="compile", graph=g.name,
                  placement=placement, background=True)

        def _upgrade():
            try:
                with obs.span("compile.background", cat="compile",
                              graph=g.name, placement=placement):
                    stitch.compile(g, bypass_cache_lookup=True)
            except Exception as e:          # surfaced via last_error / report
                with self._lock:
                    self.last_error = f"{type(e).__name__}: {e}"
                    self.errors[key] = self.last_error
                obs.event("compile.fail", cat="compile", graph=g.name,
                          placement=placement, error=self.last_error)
            finally:
                with self._lock:
                    self._pending.discard(key)

        t = threading.Thread(target=_upgrade, daemon=True, name="stitch-upgrade")
        with self._lock:
            self._threads.append(t)
        t.start()
        return True

    def wait(self, timeout: float | None = None) -> None:
        """Join in-flight background compiles (tests / orderly shutdown)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(
                None if deadline is None else max(0.0, deadline - time.monotonic())
            )
