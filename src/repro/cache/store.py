"""Two-tier plan store: in-memory LRU over an on-disk JSON layer.

A :class:`PlanRecord` is the durable form of a solved fusion plan — the
output of pattern generation + ILP + tuning, in *canonical coordinates*
(node indices from :mod:`repro.cache.signature`, never names), so it replays
onto any graph with the same ``graph_key`` regardless of how that graph was
traced.  Records deliberately contain no callables or arrays: the stitched
Pallas kernels are re-instantiated from ``(members, row_block, scratch)`` on
replay, which is the cheap tail of compilation (the expensive head — search
and solving — is what the record lets us skip).

Disk layout (reusing the crash-safety idiom of :mod:`repro.ckpt.store`)::

    <dir>/plan_<graph12>_<bucket12>_<mode>_<hw>[_<placement>][_<config12>].json
    written as .tmp then os.replace()d — a torn write is never visible.
"""

from __future__ import annotations

import json
import os
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path

__all__ = ["GroupRecord", "PlanRecord", "MemoryStore", "DiskStore", "TwoTierStore"]

# v2 added the mesh/PartitionSpec placement component to the key (sharded
# stitching); v3 added the GenConfig digest (a plan solved under one set of
# pattern-generation knobs must not replay under another); v4 added the
# horizontal-pack provenance (``GroupRecord.pack``) so packed plans replay
# as packs and the replay verifier can re-check pack legality.  Older
# records are treated as misses on read.
RECORD_VERSION = 4


@dataclass(frozen=True)
class GroupRecord:
    """One kernel of the plan, in canonical node indices."""

    members: tuple[int, ...]
    kind: str                           # "pallas" | "jnp" | "op"
    row_block: int | None = None        # pallas groups: tuned GRID factor
    scratch: tuple[int, ...] = ()       # pallas groups: VMEM-resident members
    # horizontal packs: the independent member subgraphs (canonical indices);
    # () for ordinary dependence-connected groups
    pack: tuple[tuple[int, ...], ...] = ()

    def to_json(self) -> dict:
        d = {
            "members": sorted(self.members),
            "kind": self.kind,
            "row_block": self.row_block,
            "scratch": sorted(self.scratch),
        }
        if self.pack:
            d["pack"] = sorted(sorted(gset) for gset in self.pack)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "GroupRecord":
        return cls(
            members=tuple(d["members"]),
            kind=d["kind"],
            row_block=d.get("row_block"),
            scratch=tuple(d.get("scratch", ())),
            pack=tuple(tuple(gset) for gset in d.get("pack", ())),
        )


@dataclass(frozen=True)
class PlanRecord:
    graph_key: str
    bucket_key: str
    shape_key: str                      # exact shapes the plan was solved at
    mode: str
    hw: str                             # hardware the plan was tuned for
    n_nodes: int                        # canonical-order length (replay check)
    groups: tuple[GroupRecord, ...]
    objective: float = 0.0              # ILP objective (observability)
    ilp_iterations: int = 0
    solve_seconds: float = 0.0          # cold compile wall time
    placement: str = ""                 # mesh+PartitionSpec key ("" = 1-device)
    config: str = ""                    # GenConfig digest (signature.config_key)

    @property
    def key(self) -> tuple[str, str, str, str, str, str]:
        return (self.graph_key, self.bucket_key, self.mode, self.hw,
                self.placement, self.config)

    def to_json(self) -> dict:
        return {
            "v": RECORD_VERSION,
            "graph_key": self.graph_key,
            "bucket_key": self.bucket_key,
            "shape_key": self.shape_key,
            "mode": self.mode,
            "hw": self.hw,
            "placement": self.placement,
            "config": self.config,
            "n_nodes": self.n_nodes,
            "groups": [g.to_json() for g in self.groups],
            "objective": self.objective,
            "ilp_iterations": self.ilp_iterations,
            "solve_seconds": self.solve_seconds,
        }

    @classmethod
    def from_json(cls, d: dict) -> "PlanRecord | None":
        if d.get("v") != RECORD_VERSION:
            return None                  # stale format: treat as miss
        return cls(
            graph_key=d["graph_key"],
            bucket_key=d["bucket_key"],
            shape_key=d["shape_key"],
            mode=d["mode"],
            hw=d["hw"],
            n_nodes=d["n_nodes"],
            groups=tuple(GroupRecord.from_json(g) for g in d["groups"]),
            objective=d.get("objective", 0.0),
            ilp_iterations=d.get("ilp_iterations", 0),
            solve_seconds=d.get("solve_seconds", 0.0),
            placement=d.get("placement", ""),
            config=d.get("config", ""),
        )


class MemoryStore:
    """Bounded LRU of PlanRecords."""

    def __init__(self, capacity: int = 128):
        self.capacity = capacity
        self._d: "OrderedDict[tuple, PlanRecord]" = OrderedDict()
        self.evictions = 0

    def get(self, key: tuple) -> PlanRecord | None:
        rec = self._d.get(key)
        if rec is not None:
            self._d.move_to_end(key)
        return rec

    def put(self, rec: PlanRecord) -> None:
        self._d[rec.key] = rec
        self._d.move_to_end(rec.key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._d)


class DiskStore:
    """One atomic JSON file per entry; survives process restarts.

    A truncated/corrupt/garbage record file reads as a *miss*: the first
    encounter per file emits one ``RuntimeWarning`` (and calls
    ``on_corrupt(key)`` so the owner can count it in its bucket stats);
    it never propagates an exception into the compile path — the plan
    simply recompiles and the next ``put`` overwrites the bad file.
    """

    def __init__(self, directory: str | os.PathLike, max_entries: int | None = None,
                 on_corrupt=None):
        self.directory = Path(directory)
        self.max_entries = max_entries
        self.on_corrupt = on_corrupt
        self.corrupt_reads = 0
        self._warned_corrupt: set[str] = set()

    def _path(self, key: tuple) -> Path:
        graph_key, bucket_key, mode, hw, placement, config = key
        hw_slug = "".join(c if c.isalnum() else "-" for c in hw)
        # placement slug keeps the mesh shape human-greppable; the full
        # string is re-checked against the record body (rec.key != key below)
        pl_slug = "".join(c for c in placement if c.isalnum())[:24]
        pl_part = f"_{pl_slug}" if pl_slug else ""
        cfg_part = f"_{config[:12]}" if config else ""
        return (self.directory
                / f"plan_{graph_key[:12]}_{bucket_key[:12]}_{mode}_{hw_slug}"
                  f"{pl_part}{cfg_part}.json")

    def get(self, key: tuple) -> PlanRecord | None:
        path = self._path(key)
        if not path.exists():
            return None
        try:
            with open(path) as f:
                rec = PlanRecord.from_json(json.load(f))
        except OSError:
            return None                  # transient read failure == miss
        except Exception as err:         # truncated JSON, wrong-typed body,
            self._note_corrupt(path, key, err)   # missing fields, ...
            return None
        if rec is not None and rec.key != key:
            return None                  # 12-hex-char filename collision
        return rec

    def _note_corrupt(self, path: Path, key: tuple, err: Exception) -> None:
        self.corrupt_reads += 1
        if self.on_corrupt is not None:
            self.on_corrupt(key)
        sp = str(path)
        if sp not in self._warned_corrupt:
            self._warned_corrupt.add(sp)
            warnings.warn(
                f"discarding corrupt plan record {path} "
                f"({type(err).__name__}: {err}); treating as a cache miss",
                RuntimeWarning, stacklevel=4)

    def put(self, rec: PlanRecord) -> None:
        self.directory.mkdir(parents=True, exist_ok=True)
        path = self._path(rec.key)
        tmp = path.with_suffix(".json.tmp")
        with open(tmp, "w") as f:
            json.dump(rec.to_json(), f)
        os.replace(tmp, path)
        if self.max_entries is not None:
            entries = sorted(
                self.directory.glob("plan_*.json"), key=lambda p: p.stat().st_mtime
            )
            for stale in entries[: max(0, len(entries) - self.max_entries)]:
                stale.unlink(missing_ok=True)

    def __len__(self) -> int:
        if not self.directory.exists():
            return 0
        return sum(1 for _ in self.directory.glob("plan_*.json"))


class TwoTierStore:
    """Memory LRU in front of (optional) disk persistence.

    Disk hits are promoted into memory; memory inserts are written through
    to disk so a restarted process warm-starts from the same plans.
    """

    def __init__(self, memory: MemoryStore, disk: DiskStore | None = None):
        self.memory = memory
        self.disk = disk
        self.disk_put_errors = 0

    def get(self, key: tuple) -> PlanRecord | None:
        rec = self.memory.get(key)
        if rec is not None:
            return rec
        if self.disk is not None:
            rec = self.disk.get(key)
            if rec is not None:
                self.memory.put(rec)     # promote
        return rec

    def put(self, rec: PlanRecord) -> None:
        self.memory.put(rec)
        if self.disk is not None:
            try:
                self.disk.put(rec)
            except OSError:
                # a full/read-only disk must not discard a finished compile;
                # the memory tier still serves this process
                self.disk_put_errors += 1
