"""Canonical graph signatures — the cache key half of StitchCache.

A fusion plan is a function of graph *structure* (topology, op kinds, attrs,
dtypes), not of node names, trace order, or — up to feasibility — exact
shapes.  This module computes:

* ``graph_key``  — a hex digest of the structure with shapes factored out.
  Invariant to node renaming and to the order nodes were inserted/traced.
  Two graphs with equal ``graph_key`` are isomorphic as op DAGs (same node
  count, same edges under the canonical numbering), so a fusion plan stored
  in canonical coordinates for one replays on the other.
* ``canon_order`` — the canonical node numbering itself: position ``i`` in
  one graph corresponds structurally to position ``i`` in any other graph
  with the same ``graph_key``.  Plans are persisted as sets of canonical
  indices and mapped back through this list on replay.
* ``shape_key`` — digest of every node's concrete shape in canonical order.
  The bucketing policy (:mod:`repro.cache.policy`) coarsens shapes before
  digesting so nearby sequence lengths share one cache entry.

Safety note: a cache collision (two distinct graphs hashing alike) can only
ever produce a *suboptimal* plan, never a wrong answer — replay always
evaluates the actual new graph's nodes; the record only dictates grouping.

Algorithm
---------
1. Bottom-up structural hash per node: ``h(n) = H(kind, dtype, rank,
   normalized attrs, (h(operand_0), h(operand_1), ...))``.  Operand order is
   preserved (sub is not commutative); names never enter the hash.
2. Canonical order: deterministic pre-order DFS from the outputs (in output
   order, operands in positional order) — purely structural.  Nodes
   unreachable from any output (rare dead code) are appended sorted by
   structural hash.
3. ``graph_key`` hashes the canonical sequence of per-node descriptors with
   operand edges rewritten to canonical indices — this captures sharing
   (a diamond and a duplicated subtree hash differently).

Attr normalization: runtime-only attrs (closures such as ``eval_fn``,
declared in :data:`RUNTIME_ONLY_ATTRS`) are excluded; shape-dependent attrs
(slice ``starts``/``limits``) contribute only their arity so shape bucketing
still works; constant payloads contribute their value when scalar and their
dtype/rank otherwise (exact shapes are the shape key's job).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np

from repro.core.ir import Graph

__all__ = [
    "GraphSignature",
    "compute_signature",
    "config_key",
    "node_struct_hashes",
    "placement_key",
    "token_prefix_keys",
    "RUNTIME_ONLY_ATTRS",
    "SHAPE_DEPENDENT_ATTRS",
]

# Attrs that exist only to make a node executable (closures, projections of
# multi-output customs) — never part of the identity of the computation.
RUNTIME_ONLY_ATTRS = frozenset({"eval_fn"})

# Attrs whose *values* scale with tensor shapes; they contribute arity only,
# so a length-100 and a length-120 slice of the same program share a
# graph_key and can share a shape bucket.
SHAPE_DEPENDENT_ATTRS = frozenset({"starts", "limits"})


def _digest(payload: str) -> str:
    return hashlib.sha256(payload.encode()).hexdigest()


def _stable_attr_value(key: str, value) -> str:
    if key in SHAPE_DEPENDENT_ATTRS:
        try:
            return f"len={len(value)}" if value is not None else "none"
        except TypeError:
            return "scalar"
    if isinstance(value, np.ndarray):
        if value.ndim == 0:
            return f"scalar:{value.dtype}:{value.item()!r}"
        return f"array:{value.dtype}:rank{value.ndim}"
    if isinstance(value, (list, tuple)):
        return "(" + ",".join(_stable_attr_value(key, v) for v in value) + ")"
    if callable(value):
        return f"callable:{getattr(value, '__name__', '?')}"
    return repr(value)


def _attr_sig(node) -> str:
    items = []
    for k in sorted(node.attrs):
        if k in RUNTIME_ONLY_ATTRS:
            continue
        v = node.attrs[k]
        if k == "value":
            arr = np.asarray(v)
            if arr.ndim == 0:
                items.append(f"value=scalar:{arr.dtype}:{arr.item()!r}")
            else:
                items.append(f"value=array:{arr.dtype}:rank{arr.ndim}")
            continue
        items.append(f"{k}={_stable_attr_value(k, v)}")
    return ";".join(items)


def node_struct_hashes(g: Graph) -> dict[str, str]:
    """Bottom-up, name-free structural hash for every node."""
    h: dict[str, str] = {}
    for name in g.topo_order():
        node = g.nodes[name]
        payload = "|".join(
            (
                node.kind.value,
                node.dtype,
                str(len(node.shape)),
                _attr_sig(node),
                ",".join(h[o] for o in node.operands),
            )
        )
        h[name] = _digest(payload)
    return h


def _canonical_order(g: Graph, struct: dict[str, str]) -> list[str]:
    order: list[str] = []
    seen: set[str] = set()
    # Pre-order DFS from outputs; operands visited in positional order.
    for out in g.outputs:
        stack = [out]
        while stack:
            cur = stack.pop()
            if cur in seen:
                continue
            seen.add(cur)
            order.append(cur)
            # push reversed so operand 0 is visited first
            stack.extend(reversed(g.nodes[cur].operands))
    # Dead code (unreachable from outputs): identical-hash stragglers are
    # structurally interchangeable below their frontier, so hash order plus
    # a stable secondary key is sufficient for a valid (if arbitrary)
    # correspondence; replay validity is re-checked against the new graph.
    rest = sorted((n for n in g.nodes if n not in seen), key=lambda n: (struct[n], n))
    order.extend(rest)
    return order


@dataclass(frozen=True)
class GraphSignature:
    graph_key: str
    shape_key: str                       # digest of exact shapes, canon order
    canon_order: tuple[str, ...] = field(repr=False)
    shapes: tuple[tuple[int, ...], ...] = field(repr=False)

    @property
    def node_to_index(self) -> dict[str, int]:
        return {n: i for i, n in enumerate(self.canon_order)}

    def bucket_key(self, policy) -> str:
        """Digest of shapes after the policy's coarsening."""
        bucketed = tuple(policy.bucket_shape(s) for s in self.shapes)
        return _digest(repr(bucketed))


def token_prefix_keys(tokens, page_size: int) -> list[str]:
    """Chained content hashes of a token sequence at page granularity — the
    key half of the serving layer's prefix cache.

    ``keys[i]`` digests tokens ``[0, min((i+1)*page_size, len))`` *through
    the chain*: it commits to every earlier page, so two prompts share
    ``keys[i]`` iff their first ``i+1`` pages are token-identical (the
    vLLM-style block-hash chain).  The final key covers the whole sequence
    including a partial tail page, making it a whole-prompt content key.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int64).reshape(-1))
    if toks.size == 0:
        raise ValueError("token_prefix_keys: empty token sequence")
    h = hashlib.sha1(str(page_size).encode())
    keys = []
    for start in range(0, toks.size, page_size):
        h.update(toks[start:start + page_size].tobytes())
        keys.append(h.hexdigest()[:16])
    return keys


def config_key(gen_cfg=None) -> str:
    """Stable digest of the pattern-generation knobs a plan was solved under.

    Two compiles of the same graph under different :class:`GenConfig`
    settings (``large_gemm_flops``, ``stitch_custom``, scratch budget, ...)
    legitimately choose different plans; without this component a plan cached
    under one config replays for all of them — the plan-cache staleness bug.
    ``None`` hashes identically to a default ``GenConfig()``, so callers that
    never touch the knobs keep hitting the same entries.
    """
    import dataclasses

    from repro.core.fusiongen import GenConfig

    cfg = gen_cfg if gen_cfg is not None else GenConfig()
    fields = sorted(dataclasses.asdict(cfg).items())
    return _digest(repr(fields))[:12]


def placement_key(mesh=None, specs=None) -> str:
    """Stable digest of (mesh shape, PartitionSpecs) — the placement half of
    a mesh-aware cache key.

    A stitched plan is solved against *shard-local* shapes and a specific
    data layout: replaying it under a different mesh (or the same mesh with
    different in-specs) would execute a plan tuned for the wrong block
    sizes.  ``placement_key`` spells the mesh axis sizes verbatim (human
    greppable in the disk store's filenames) and digests the flattened
    PartitionSpecs; the empty string is the single-device / unplaced
    placement, so existing callers and on-disk records are unaffected.
    """
    if mesh is None:
        return ""
    axes = ",".join(f"{n}={mesh.shape[n]}" for n in mesh.axis_names)
    spec_part = ""
    if specs is not None:
        from jax.sharding import PartitionSpec as P
        import jax

        flat = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        spec_part = "/" + _digest(repr([tuple(s) if isinstance(s, P) else s
                                        for s in flat]))[:12]
    return f"mesh[{axes}]{spec_part}"


def compute_signature(g: Graph) -> GraphSignature:
    struct = node_struct_hashes(g)
    order = _canonical_order(g, struct)
    index = {n: i for i, n in enumerate(order)}
    desc = []
    for name in order:
        node = g.nodes[name]
        desc.append(
            (
                node.kind.value,
                node.dtype,
                len(node.shape),
                _attr_sig(node),
                tuple(index[o] for o in node.operands),
            )
        )
    outputs = tuple(index[o] for o in g.outputs)
    graph_key = _digest(repr((desc, outputs)))
    shapes = tuple(g.nodes[n].shape for n in order)
    shape_key = _digest(repr(shapes))
    return GraphSignature(graph_key, shape_key, tuple(order), shapes)
