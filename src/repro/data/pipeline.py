"""Deterministic synthetic token pipeline.

Production posture without external data: batches are a pure function of
(seed, step, host), so

* restart-from-checkpoint reproduces the exact stream (no data loss/dup),
* each host generates only its own shard (per-host sharding),
* the stream is cheap enough to never bottleneck the step.

Tokens follow a Zipfian-ish distribution over the vocab (uniform tokens make
losses/collectives unrealistically flat); labels are next-token shifted.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticTokens:
    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        # precompute a Zipf-over-vocab CDF once
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._cdf = np.cumsum(probs / probs.sum())

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.host_id]))

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Local shard of the global batch for `step` (stateless)."""
        rng = self._rng(step)
        u = rng.random((self.local_batch, self.cfg.seq_len + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.clip(toks, 0, self.cfg.vocab - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
