"""RecurrentGemma-style hybrid LM (griffin): repeating (RG-LRU, RG-LRU,
local-attention) blocks, GeGLU MLPs.

Layers scan in *super-blocks* of the 3-layer pattern; a config whose depth
is not a multiple of the pattern gets the remainder as unscanned recurrent
blocks (recurrentgemma-9b: 38 = 12x3 + 2).  Decode state is O(1) in context:
RG-LRU carries (B, D_rnn) per recurrent layer; local attention keeps only a
``window``-sized rolling KV cache.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import HybridConfig, ModelConfig
from .layers import (
    Params, apply_attention, apply_mlp, apply_norm,
    init_attention, init_mlp, init_norm, scan_or_unroll,
)


def _h(cfg: ModelConfig) -> HybridConfig:
    return cfg.hybrid or HybridConfig()


def init_rec_layer(key, cfg: ModelConfig) -> Params:
    h = _h(cfg)
    D, Dr = cfg.d_model, h.d_rnn or cfg.d_model
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(D)
    pd = cfg.param_dtype
    return {
        "norm1": init_norm(ks[0], cfg),
        "x_proj": (jax.random.normal(ks[1], (D, Dr)) * sc).astype(pd),
        "in_gate": (jax.random.normal(ks[2], (D, Dr)) * sc).astype(pd),
        "rec_gate": (jax.random.normal(ks[3], (D, Dr)) * sc).astype(pd),
        "Lambda": jnp.full((Dr,), 0.5, pd),
        "out_proj": (jax.random.normal(ks[4], (Dr, D)) / math.sqrt(Dr)).astype(pd),
        "norm2": init_norm(ks[5], cfg),
        "mlp": init_mlp(ks[5], cfg),
    }


def init_attn_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "norm1": init_norm(k1, cfg),
        "attn": init_attention(k2, cfg),
        "norm2": init_norm(k3, cfg),
        "mlp": init_mlp(k4, cfg),
    }


def _layout(cfg: ModelConfig):
    pat = _h(cfg).pattern
    n_super = cfg.n_layers // len(pat)
    n_rest = cfg.n_layers - n_super * len(pat)
    return pat, n_super, n_rest


def init_params(cfg: ModelConfig, key) -> Params:
    pat, n_super, n_rest = _layout(cfg)
    ke, ks_, kr, kh = jax.random.split(key, 4)
    super_keys = jax.random.split(ks_, n_super)

    def init_super(k):
        kk = jax.random.split(k, len(pat))
        return {
            f"l{i}": (init_rec_layer(kk[i], cfg) if pat[i] == "rec"
                      else init_attn_layer(kk[i], cfg))
            for i in range(len(pat))
        }

    p = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * 0.02).astype(cfg.param_dtype),
        "supers": jax.vmap(init_super)(super_keys),
        "rest": [init_rec_layer(k, cfg) for k in jax.random.split(kr, n_rest)],
        "final_norm": init_norm(kh, cfg),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab))
                    / math.sqrt(cfg.d_model)).astype(cfg.param_dtype),
    }
    return p


def _rec_block(lp: Params, x, cfg: ModelConfig):
    dt = cfg.dtype
    xn = apply_norm(lp["norm1"], x, cfg)
    y = ops.rg_lru(
        xn @ lp["x_proj"].astype(dt),
        xn @ lp["in_gate"].astype(dt),
        xn @ lp["rec_gate"].astype(dt),
        lp["Lambda"].astype(jnp.float32),
        _h(cfg).c,
    )
    x = x + y @ lp["out_proj"].astype(dt)
    return x + apply_mlp(lp["mlp"], apply_norm(lp["norm2"], x, cfg), cfg)


def _attn_block(lp: Params, x, cfg: ModelConfig, positions):
    a, _ = apply_attention(lp["attn"], apply_norm(lp["norm1"], x, cfg), cfg,
                           positions, window=_h(cfg).window)
    x = x + a
    return x + apply_mlp(lp["mlp"], apply_norm(lp["norm2"], x, cfg), cfg)


def backbone(params: Params, h, cfg: ModelConfig, positions):
    pat, n_super, n_rest = _layout(cfg)

    def super_fn(carry, sp):
        x = carry
        if cfg.shard_activations:
            from .sharding import hint_rows
            x = hint_rows(x)
        for i, kind in enumerate(pat):
            lp = sp[f"l{i}"]
            x = _rec_block(lp, x, cfg) if kind == "rec" else _attn_block(lp, x, cfg, positions)
        return x, None

    if cfg.remat == "full":
        super_fn = jax.checkpoint(super_fn)
    _, n_super, _ = _layout(cfg)
    h, _ = scan_or_unroll(super_fn, h, params["supers"], n_super,
                          cfg.scan_layers)
    for lp in params["rest"]:
        h = _rec_block(lp, h, cfg)
    return apply_norm(params["final_norm"], h, cfg)


def train_forward(params: Params, batch: dict, cfg: ModelConfig):
    from .lm import lm_loss
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    h = backbone(params, h, cfg, positions)
    return lm_loss(params, h, labels, cfg), {}


# ---------------------------------------------------------------------------
# serving — rolling-window attention cache + per-layer LRU state
# ---------------------------------------------------------------------------

def _rec_block_state(lp: Params, x, cfg: ModelConfig):
    dt = cfg.dtype
    xn = apply_norm(lp["norm1"], x, cfg)
    y, state = ops.rg_lru(
        xn @ lp["x_proj"].astype(dt),
        xn @ lp["in_gate"].astype(dt),
        xn @ lp["rec_gate"].astype(dt),
        lp["Lambda"].astype(jnp.float32),
        _h(cfg).c,
        return_state=True,
    )
    x = x + y @ lp["out_proj"].astype(dt)
    return x + apply_mlp(lp["mlp"], apply_norm(lp["norm2"], x, cfg), cfg), state


def _attn_block_kv(lp: Params, x, cfg: ModelConfig, positions, W: int):
    """Windowed attention that also returns the last-W ring cache."""
    dt = cfg.dtype
    B, S, D = x.shape
    xn = apply_norm(lp["norm1"], x, cfg)
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = (xn @ lp["attn"]["wq"].astype(dt)).reshape(B, S, Hq, dh)
    k = (xn @ lp["attn"]["wk"].astype(dt)).reshape(B, S, Hkv, dh)
    v = (xn @ lp["attn"]["wv"].astype(dt)).reshape(B, S, Hkv, dh)
    q = ops.rope(q, positions, cfg.rope_theta)
    k = ops.rope(k, positions, cfg.rope_theta)
    from repro.kernels import ref as _ref
    from .layers import _chunked_causal_attention
    scale = 1.0 / math.sqrt(dh)
    if S > 1024 and S % 512 == 0:
        out = _chunked_causal_attention(q, k, v, scale, _h(cfg).window)
    else:
        out = _ref.attention(q, k, v, causal=True, scale=scale,
                             window=_h(cfg).window, positions_q=positions)
    x = x + out.reshape(B, S, Hq * dh) @ lp["attn"]["wo"].astype(dt)
    x = x + apply_mlp(lp["mlp"], apply_norm(lp["norm2"], x, cfg), cfg)
    # ring cache of the last W positions: position p -> slot p mod W
    lastk, lastv = k[:, -W:], v[:, -W:]
    slots = jnp.mod(jnp.arange(S - W, S), W)
    ck = jnp.zeros((B, W, Hkv, dh), dt).at[:, slots].set(lastk.astype(dt))
    cv = jnp.zeros((B, W, Hkv, dh), dt).at[:, slots].set(lastv.astype(dt))
    return x, ck, cv


def prefill(params: Params, tokens, cfg: ModelConfig, max_len: int | None = None):
    pat, n_super, n_rest = _layout(cfg)
    W = min(_h(cfg).window, tokens.shape[1])
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)

    def super_fn(carry, sp):
        x = carry
        lrus, cks, cvs = [], [], []
        for i, kind in enumerate(pat):
            lp = sp[f"l{i}"]
            if kind == "rec":
                x, st = _rec_block_state(lp, x, cfg)
                lrus.append(st)
            else:
                x, ck, cv = _attn_block_kv(lp, x, cfg, positions, W)
                cks.append(ck)
                cvs.append(cv)
        return x, (jnp.stack(lrus), jnp.stack(cks), jnp.stack(cvs))

    h, (lru, ck, cv) = scan_or_unroll(super_fn, h, params["supers"],
                                      n_super, cfg.scan_layers)
    rest_states = []
    for lp in params["rest"]:
        h, st = _rec_block_state(lp, h, cfg)
        rest_states.append(st)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = (h[:, -1] @ params["lm_head"].astype(cfg.dtype)).astype(jnp.float32)
    cache = {
        "lru": lru, "k": ck, "v": cv,
        "lru_rest": (jnp.stack(rest_states) if rest_states
                     else jnp.zeros((0, B, _h(cfg).d_rnn or cfg.d_model), jnp.float32)),
        "length": jnp.asarray(S, jnp.int32),
    }
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    pat, n_super, n_rest = _layout(cfg)
    h = _h(cfg)
    Dr = h.d_rnn or cfg.d_model
    W = min(h.window, max_len)
    n_attn_per_super = sum(1 for k in pat if k == "attn")
    return {
        "lru": jnp.zeros((n_super, len([k for k in pat if k == "rec"]), batch, Dr),
                         jnp.float32),
        "lru_rest": jnp.zeros((n_rest, batch, Dr), jnp.float32),
        "k": jnp.zeros((n_super, n_attn_per_super, batch, W, cfg.n_kv_heads, cfg.dh),
                       cfg.dtype),
        "v": jnp.zeros((n_super, n_attn_per_super, batch, W, cfg.n_kv_heads, cfg.dh),
                       cfg.dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def _rec_decode(lp, x, state, cfg: ModelConfig):
    """x: (B, 1, D); state: (B, Dr)."""
    dt = cfg.dtype
    h = _h(cfg)
    xn = apply_norm(lp["norm1"], x, cfg)[:, 0]          # (B, D)
    xp = xn @ lp["x_proj"].astype(dt)
    ig = jax.nn.sigmoid((xn @ lp["in_gate"].astype(dt)).astype(jnp.float32))
    rg = jax.nn.sigmoid((xn @ lp["rec_gate"].astype(dt)).astype(jnp.float32))
    lam = jax.nn.softplus(lp["Lambda"].astype(jnp.float32))
    a = jnp.exp(-h.c * lam[None] * rg)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    new_state = a * state + mult * (ig * xp.astype(jnp.float32))
    y = new_state.astype(dt)[:, None, :] @ lp["out_proj"].astype(dt)
    x = x + y
    return x + apply_mlp(lp["mlp"], apply_norm(lp["norm2"], x, cfg), cfg), new_state


def _attn_decode(lp, x, ck, cv, cfg: ModelConfig, length):
    """Rolling-window cache: slot = length mod W; positions tracked absolutely."""
    dt = cfg.dtype
    W = ck.shape[1]
    B = x.shape[0]
    xn = apply_norm(lp["norm1"], x, cfg)
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = (xn @ lp["attn"]["wq"].astype(dt)).reshape(B, 1, Hq, dh)
    k = (xn @ lp["attn"]["wk"].astype(dt)).reshape(B, 1, Hkv, dh)
    v = (xn @ lp["attn"]["wv"].astype(dt)).reshape(B, 1, Hkv, dh)
    pos = jnp.broadcast_to(length[None], (B, 1)).astype(jnp.int32)
    q = ops.rope(q, pos, cfg.rope_theta)
    k = ops.rope(k, pos, cfg.rope_theta)
    slot = jnp.mod(length, W)
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
    # absolute position of each cache slot given the ring layout
    idx = jnp.arange(W)
    wraps = jnp.where(idx <= slot, length - slot + idx, length - W - slot + idx)
    valid = wraps >= 0
    group = Hq // Hkv
    kr = jnp.repeat(ck, group, axis=2).astype(jnp.float32)
    vr = jnp.repeat(cv, group, axis=2).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr) / math.sqrt(dh)
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr).astype(dt).reshape(B, 1, Hq * dh)
    x = x + out @ lp["attn"]["wo"].astype(dt)
    return x + apply_mlp(lp["mlp"], apply_norm(lp["norm2"], x, cfg), cfg), ck, cv


def decode_step(params: Params, cache: Params, tokens, cfg: ModelConfig):
    pat, n_super, n_rest = _layout(cfg)
    B, S = tokens.shape
    assert S == 1
    length = cache["length"]
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)

    def super_fn(carry, xs):
        x = carry
        sp, lru, ck, cv = xs
        ri = ai = 0
        new_lru, new_k, new_v = [], [], []
        for i, kind in enumerate(pat):
            lp = sp[f"l{i}"]
            if kind == "rec":
                x, st = _rec_decode(lp, x, lru[ri], cfg)
                new_lru.append(st)
                ri += 1
            else:
                x, nk, nv = _attn_decode(lp, x, ck[ai], cv[ai], cfg, length)
                new_k.append(nk)
                new_v.append(nv)
                ai += 1
        return x, (jnp.stack(new_lru), jnp.stack(new_k), jnp.stack(new_v))

    h, (nlru, nk, nv) = scan_or_unroll(
        super_fn, h, (params["supers"], cache["lru"], cache["k"], cache["v"]),
        n_super, cfg.scan_layers)
    rest_states = []
    for i, lp in enumerate(params["rest"]):
        h, st = _rec_decode(lp, h, cache["lru_rest"][i], cfg)
        rest_states.append(st)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = (h[:, -1] @ params["lm_head"].astype(cfg.dtype)).astype(jnp.float32)
    new_cache = {
        "lru": nlru, "k": nk, "v": nv,
        "lru_rest": jnp.stack(rest_states) if rest_states else cache["lru_rest"],
        "length": length + 1,
    }
    return logits, new_cache
