"""Decoder-only transformer LM — dense, MoE, and VLM-backbone families.

Layers are scanned (``jax.lax.scan`` over stacked parameters) so HLO size is
O(1) in depth; remat policy comes from the config.  The same forward serves:

* ``train_forward``  — full-sequence causal, returns mean-token CE loss;
* ``prefill``        — full-sequence causal, fills a KV cache;
* ``decode_step``    — single-token step against a static-shape KV cache.
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ModelConfig
from .layers import (
    Params,
    scan_or_unroll,
    apply_attention,
    apply_mlp,
    apply_moe,
    apply_moe_dense,
    apply_norm,
    init_attention,
    init_mlp,
    init_moe,
    init_norm,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "norm1": init_norm(k1, cfg),
        "attn": init_attention(k2, cfg),
        "norm2": init_norm(k3, cfg),
    }
    if cfg.family == "moe":
        p["mlp"] = init_moe(k4, cfg)
    else:
        p["mlp"] = init_mlp(k4, cfg)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    p = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model), jnp.float32)
                  * 0.02).astype(cfg.param_dtype),
        "layers": layers,
        "final_norm": init_norm(kh, cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(kh, (cfg.d_model, cfg.vocab), jnp.float32)
                        / math.sqrt(cfg.d_model)).astype(cfg.param_dtype)
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _empty_aux():
    return {"moe_aux": jnp.zeros((), jnp.float32),
            "moe_drop_frac": jnp.zeros((), jnp.float32)}


def _layer_fn(h, lp, cfg: ModelConfig, positions):
    if cfg.shard_activations:
        from .sharding import hint_rows
        h = hint_rows(h)
    a_in = apply_norm(lp["norm1"], h, cfg)
    attn_out, _ = apply_attention(lp["attn"], a_in, cfg, positions)
    h = h + attn_out
    m_in = apply_norm(lp["norm2"], h, cfg)
    if cfg.family == "moe":
        B, S, D = m_in.shape
        y2d, aux = apply_moe(lp["mlp"], m_in.reshape(B * S, D), cfg)
        mlp_out = y2d.reshape(B, S, D)
    else:
        mlp_out = apply_mlp(lp["mlp"], m_in, cfg)
        aux = _empty_aux()
    return h + mlp_out, aux


def block_forward(lp: Params, x, cfg: ModelConfig):
    """Pure single-block forward — the ``Model.block_fn`` stitching entry
    (see examples/stitch_fn.py).  x: (B, S, D) -> (B, S, D).

    MoE blocks use the *dense* expert form (:func:`apply_moe_dense`): the
    sort-based capacity dispatch is gather/scatter-partitioned anyway, while
    the dense form exposes E independent per-expert chains for the
    horizontal packer."""
    B, S, D = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    a_in = apply_norm(lp["norm1"], x, cfg)
    attn_out, _ = apply_attention(lp["attn"], a_in, cfg, positions)
    x = x + attn_out
    m_in = apply_norm(lp["norm2"], x, cfg)
    if cfg.family == "moe":
        y2d = apply_moe_dense(lp["mlp"], m_in.reshape(B * S, D), cfg)
        mlp_out = y2d.reshape(B, S, D)
    else:
        mlp_out = apply_mlp(lp["mlp"], m_in, cfg)
    return x + mlp_out


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def backbone(params: Params, h, cfg: ModelConfig, positions):
    """h: (B, S, D) embeddings -> (B, S, D) final-normed hidden, aux."""
    body = _maybe_remat(
        lambda carry, lp: _layer_fn(carry, lp, cfg, positions), cfg)
    h, auxs = scan_or_unroll(body, h, params["layers"], cfg.n_layers,
                             cfg.scan_layers)
    h = apply_norm(params["final_norm"], h, cfg)
    aux = jax.tree.map(jnp.mean, auxs)
    return h, aux


def embed_tokens(params: Params, tokens, cfg: ModelConfig, patch_embeds=None):
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if patch_embeds is not None:
        # VLM frontend stub: precomputed patch embeddings occupy the first
        # n_patch_tokens positions of the sequence.
        P = patch_embeds.shape[1]
        h = jnp.concatenate([patch_embeds.astype(cfg.dtype), h[:, P:]], axis=1)
    return h


def _head_matrix(params: Params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T.astype(cfg.dtype)
    return params["lm_head"].astype(cfg.dtype)


def lm_loss(params: Params, h, labels, cfg: ModelConfig, n_chunks: int = 16):
    """Chunked CE: the (tokens, vocab) logits tensor is produced one chunk at
    a time inside a scan, never materialized whole.

    ``cfg.loss_groups > 1``: tokens are first split into G groups aligned
    with the DP shards, and chunking slices WITHIN each group — every chunk
    matmul then carries all G shards (stays DP-parallel) instead of mapping
    one contiguous token range (= one DP shard) per chunk (§Perf)."""
    B, S, D = h.shape
    W = _head_matrix(params, cfg)
    h2 = h.reshape(B * S, D)
    if cfg.shard_activations:
        from .sharding import hint_rows
        h2 = hint_rows(h2)
    y2 = labels.reshape(B * S)
    T = B * S
    G = cfg.loss_groups
    while T % G:
        G //= 2
    G = max(G, 1)
    Tg = T // G
    while Tg % n_chunks:
        n_chunks -= 1
    Tc = Tg // n_chunks

    hg = h2.reshape(G, Tg, D)
    yg = y2.reshape(G, Tg)
    if cfg.shard_activations and G > 1:
        from .sharding import hint_rows
        hg = hint_rows(hg)

    def chunk(carry, j):
        hcb = jax.lax.dynamic_slice_in_dim(hg, j * Tc, Tc, axis=1)  # (G,Tc,D)
        ycb = jax.lax.dynamic_slice_in_dim(yg, j * Tc, Tc, axis=1)
        logits = jnp.einsum("gtd,dv->gtv", hcb, W).astype(jnp.float32)
        m = jnp.max(logits, axis=-1)
        lse = jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)) + m
        gold = jnp.take_along_axis(logits, ycb[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = scan_or_unroll(chunk, jnp.zeros((), jnp.float32),
                              jnp.arange(n_chunks), n_chunks, cfg.scan_layers)
    return total / T


def train_forward(params: Params, batch: dict, cfg: ModelConfig,
                  aux_coef: float = 1e-2):
    tokens = batch["tokens"]
    labels = batch["labels"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = embed_tokens(params, tokens, cfg, batch.get("patch_embeds"))
    h, aux = backbone(params, h, cfg, positions)
    loss = lm_loss(params, h, labels, cfg)
    if cfg.family == "moe":
        loss = loss + aux_coef * aux["moe_aux"]
    return loss, aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(params: Params, tokens, cfg: ModelConfig, max_len: int | None = None,
            patch_embeds=None, true_len=None):
    """Full-sequence causal forward that also fills a KV cache.
    Returns (last-position logits, cache).

    ``true_len`` (B,) enables bucketed ragged prefill: ``tokens`` may be
    right-padded to a shape bucket, logits are gathered at each row's true
    last position, and ``cache["length"]`` comes back as a per-row vector.
    Causal masking makes the pad positions inert for every real position,
    so a bucketed prefill is numerically identical to an exact-length one
    at the real positions."""
    B, S = tokens.shape
    max_len = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = embed_tokens(params, tokens, cfg, patch_embeds)

    def body(carry, lp):
        x = carry
        a_in = apply_norm(lp["norm1"], x, cfg)
        attn_out, kv = apply_attention(lp["attn"], a_in, cfg, positions,
                                       return_kv=True)
        x = x + attn_out
        m_in = apply_norm(lp["norm2"], x, cfg)
        if cfg.family == "moe":
            Bq, Sq, D = m_in.shape
            y2d, _ = apply_moe(lp["mlp"], m_in.reshape(Bq * Sq, D), cfg)
            mlp_out = y2d.reshape(Bq, Sq, D)
        else:
            mlp_out = apply_mlp(lp["mlp"], m_in, cfg)
        return x + mlp_out, (kv["k"].astype(cfg.dtype), kv["v"].astype(cfg.dtype))

    body = _maybe_remat(body, cfg)
    h, (ks, vs) = scan_or_unroll(body, h, params["layers"], cfg.n_layers,
                                 cfg.scan_layers)
    h = apply_norm(params["final_norm"], h, cfg)
    if true_len is None:
        last = h[:, -1]
        length = jnp.asarray(S, jnp.int32)
    else:
        tl = jnp.asarray(true_len, jnp.int32)           # (B,)
        last = jnp.take_along_axis(h, (tl - 1)[:, None, None], axis=1)[:, 0]
        length = tl
    logits = (last @ _head_matrix(params, cfg)).astype(jnp.float32)
    if max_len > S:
        pad = [(0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0)]
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {"k": ks, "v": vs, "length": length}
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.dh)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int) -> Params:
    """KV page pool shared by all slots: per layer, ``num_pages`` fixed-size
    pages.  The serving layer owns the page tables (see
    :class:`repro.serve.kv.PagedKV`); the decode step only consumes them."""
    shape = (cfg.n_layers, num_pages, page_size, cfg.n_kv_heads, cfg.dh)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def _decode_layer(h, xs, cfg: ModelConfig, positions, length,
                  page_table=None, kv_limit=None):
    lp, ck, cv = xs
    a_in = apply_norm(lp["norm1"], h, cfg)
    layer_cache = {"k": ck, "v": cv, "length": length}
    if page_table is not None:
        layer_cache["page_table"] = page_table
        layer_cache["kv_limit"] = kv_limit
    attn_out, new_cache = apply_attention(lp["attn"], a_in, cfg, positions,
                                          cache=layer_cache)
    h = h + attn_out
    m_in = apply_norm(lp["norm2"], h, cfg)
    if cfg.family == "moe":
        B, S, D = m_in.shape
        y2d, _ = apply_moe(lp["mlp"], m_in.reshape(B * S, D), cfg)
        mlp_out = y2d.reshape(B, S, D)
    else:
        mlp_out = apply_mlp(lp["mlp"], m_in, cfg)
    return h + mlp_out, (new_cache["k"], new_cache["v"])


def decode_step(params: Params, cache: Params, tokens, cfg: ModelConfig,
                patch_embeds=None, kv_limit=None):
    """tokens: (B, S_new) — S_new=1 for pure decode; larger for prefill.
    Returns (logits_last, new_cache).

    A cache carrying ``page_table`` selects the paged path: ``k``/``v`` are
    the shared page pool and each slot reads/writes through its page-table
    row.  ``kv_limit`` (python int) slices the gathered per-slot view back
    to the engine's max_len so the attention reduction shape — and hence
    the tokens — match the dense layout bitwise."""
    B, S = tokens.shape
    length = cache["length"]
    page_table = cache.get("page_table")
    base = length[:, None] if jnp.ndim(length) else length   # ragged: (B,) offsets
    positions = base + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = embed_tokens(params, tokens, cfg, patch_embeds)

    def body(carry, xs):
        return _decode_layer(carry, xs, cfg, positions, length,
                             page_table=page_table, kv_limit=kv_limit)

    h, (nk, nv) = scan_or_unroll(body, h,
                                 (params["layers"], cache["k"], cache["v"]),
                                 cfg.n_layers, cfg.scan_layers)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = (h[:, -1] @ _head_matrix(params, cfg)).astype(jnp.float32)
    new_cache = {"k": nk, "v": nv, "length": length + S}
    if page_table is not None:
        new_cache["page_table"] = page_table
    return logits, new_cache
