"""Shared layer library: GQA attention (RoPE / qk-norm / bias / local
window / KV cache), MLP variants (SwiGLU / GeGLU / squared-ReLU), norms, and
a sort-based capacity MoE.

All functions are pure; parameters are nested dicts of jnp arrays.  Compute
runs in ``cfg.dtype`` (bf16), params live in ``cfg.param_dtype`` (f32),
reductions in f32.  Memory-bound chains route through
:mod:`repro.kernels.ops`, so the whole model flips between the Pallas
kernels and the clean-HLO reference path with ``kernel_mode``.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ModelConfig

Params = dict[str, Any]


def scan_or_unroll(body, carry, xs, length: int, use_scan: bool):
    """lax.scan when ``use_scan``; otherwise a python loop over leading-axis
    slices.  The dry-run probes unroll so ``compiled.cost_analysis()`` counts
    every iteration (XLA's HLO cost analysis counts while-loop bodies once).
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs) if xs is not None else None
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_norm(key, cfg: ModelConfig, d: int | None = None) -> Params:
    d = d or cfg.d_model
    p = {"g": jnp.ones((d,), cfg.param_dtype)}
    if cfg.norm == "ln":
        p["b"] = jnp.zeros((d,), cfg.param_dtype)
    return p


def init_attention(key, cfg: ModelConfig) -> Params:
    D, dh, Hq, Hkv = cfg.d_model, cfg.dh, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(D)
    p = {
        "wq": _normal(ks[0], (D, Hq * dh), sc, cfg.param_dtype),
        "wk": _normal(ks[1], (D, Hkv * dh), sc, cfg.param_dtype),
        "wv": _normal(ks[2], (D, Hkv * dh), sc, cfg.param_dtype),
        "wo": _normal(ks[3], (Hq * dh, D), 1.0 / math.sqrt(Hq * dh), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * dh,), cfg.param_dtype)
        p["bk"] = jnp.zeros((Hkv * dh,), cfg.param_dtype)
        p["bv"] = jnp.zeros((Hkv * dh,), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm_g"] = jnp.ones((dh,), cfg.param_dtype)
        p["k_norm_g"] = jnp.ones((dh,), cfg.param_dtype)
    return p


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    sc_in, sc_out = 1.0 / math.sqrt(D), 1.0 / math.sqrt(F)
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_gate": _normal(ks[0], (D, F), sc_in, cfg.param_dtype),
            "w_up": _normal(ks[1], (D, F), sc_in, cfg.param_dtype),
            "w_down": _normal(ks[2], (F, D), sc_out, cfg.param_dtype),
        }
    return {  # sqrelu and friends: 2-matrix MLP
        "w_up": _normal(ks[0], (D, F), sc_in, cfg.param_dtype),
        "w_down": _normal(ks[1], (F, D), sc_out, cfg.param_dtype),
    }


def init_moe(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    D = cfg.d_model
    ks = jax.random.split(key, 5)
    sc_in, sc_out = 1.0 / math.sqrt(D), 1.0 / math.sqrt(m.d_expert)
    p = {
        "router": _normal(ks[0], (D, m.n_experts), sc_in, cfg.param_dtype),
        "w_gate": _normal(ks[1], (m.n_experts, D, m.d_expert), sc_in, cfg.param_dtype),
        "w_up": _normal(ks[2], (m.n_experts, D, m.d_expert), sc_in, cfg.param_dtype),
        "w_down": _normal(ks[3], (m.n_experts, m.d_expert, D), sc_out, cfg.param_dtype),
    }
    if m.n_shared:
        p["shared"] = init_mlp(ks[4], cfg, m.d_shared)
    return p


# ---------------------------------------------------------------------------
# norms / MLPs
# ---------------------------------------------------------------------------

def apply_norm(p: Params, x, cfg: ModelConfig):
    if cfg.norm == "ln":
        return ops.layernorm(x, p["g"].astype(cfg.dtype), p["b"].astype(cfg.dtype))
    return ops.rmsnorm(x, p["g"].astype(cfg.dtype))


def apply_mlp(p: Params, x, cfg: ModelConfig):
    dt = cfg.dtype
    if "w_gate" in p:
        gate = x @ p["w_gate"].astype(dt)
        up = x @ p["w_up"].astype(dt)
        h = ops.swiglu(gate, up) if cfg.act == "swiglu" else ops.geglu(gate, up)
    else:
        h = ops.squared_relu(x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

_CHUNK_Q = 512  # ref-path q-chunking threshold/size for long sequences


def _chunked_causal_attention(q, k, v, scale, window, use_scan: bool = True,
                              cfg: ModelConfig | None = None):
    """Memory-sane pure-jnp attention: lax.scan over q chunks so the logits
    tensor never exceeds (B, H, CHUNK, S).  Same math as
    kernels.ref.attention, with two structural optimizations (§Perf):

    * grouped-GQA einsum — K/V are contracted at their native Hkv width
      (no ``jnp.repeat`` materializing group x K/V copies);
    * with ``cfg.shard_activations``, K/V (and thus the logits) are
      sequence-sharded over the model axis (Megatron-SP-style attention):
      softmax reductions psum tiny (B,h,g,q) stats instead of XLA
      re-sharding head-misaligned logits tensors.
    """
    B, Lq, Hq, Dh = q.shape
    _, Lkv, Hkv, _ = k.shape
    group = Hq // Hkv
    nq = Lq // _CHUNK_Q
    qg = q.reshape(B, nq, _CHUNK_Q, Hkv, group, Dh).transpose(1, 0, 2, 3, 4, 5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if cfg is not None and cfg.shard_activations:
        from .sharding import hint
        kf = hint(kf, "data", "model", None, None)
        vf = hint(vf, "data", "model", None, None)
    kpos = jnp.arange(Lkv)

    def chunk(carry, inp):
        ci, qb = inp                       # qb: (B, qc, Hkv, group, Dh)
        qpos = ci * _CHUNK_Q + jnp.arange(_CHUNK_Q)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32), kf) * scale
        mask = qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask = mask & (qpos[:, None] - kpos[None, :] < window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
        return carry, out.astype(qb.dtype)

    _, outs = scan_or_unroll(chunk, None, (jnp.arange(nq), qg), nq, use_scan)
    # (nq, B, qc, Hkv, group, Dh) -> (B, Lq, Hq, Dh)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Lq, Hq, Dh)


def apply_attention(
    p: Params,
    x,
    cfg: ModelConfig,
    positions,
    cache: Params | None = None,
    window: int | None = None,
    return_kv: bool = False,
):
    """x: (B, S, D). If ``cache`` is given (decode), S is the new-token count
    and attention runs against cache+new; returns (out, new_cache).
    With ``return_kv`` (prefill), the post-RoPE k/v are returned instead."""
    dt = cfg.dtype
    B, S, D = x.shape
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh

    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, Hq, dh)
    k = k.reshape(B, S, Hkv, dh)
    v = v.reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = ops.rmsnorm(q, p["q_norm_g"].astype(dt))
        k = ops.rmsnorm(k, p["k_norm_g"].astype(dt))
    q = ops.rope(q, positions, cfg.rope_theta)
    k = ops.rope(k, positions, cfg.rope_theta)

    scale = 1.0 / math.sqrt(dh)
    new_cache = {"k": k, "v": v} if return_kv else None
    if cache is not None:
        # static-shape serving: cache (B, Smax, Hkv, dh); `length` tokens valid.
        # A scalar `length` is the lock-step batch (every row at the same
        # position); a (B,) vector is the continuous-batching ragged batch —
        # each slot writes its new KV at its own offset (vmapped
        # dynamic_update_slice lowers to one batched scatter).
        length = cache["length"]
        if "page_table" in cache:
            # Paged serving: the layer's KV lives in a shared page pool
            # (n_pages, page_size, Hkv, dh); ``page_table`` (B, n) maps each
            # slot's logical pages to pool pages.  Scatter the new token
            # into each slot's current page, then gather the slot-dense
            # view back out — the gathered view is value-identical to the
            # dense cache at every unmasked position, so the attention math
            # below (and the tokens) match the dense path bitwise.  Free
            # slots' tables point at the reserved sink page 0, so their
            # ride-along writes never touch a live page.
            if S != 1:
                raise NotImplementedError("paged decode is single-token")
            pt = cache["page_table"]                  # (B, n_pages) int32
            psz = cache["k"].shape[1]
            phys = jnp.take_along_axis(pt, (length // psz)[:, None],
                                       axis=1)[:, 0]
            off = length % psz
            pk = cache["k"].at[phys, off].set(k[:, 0].astype(cache["k"].dtype))
            pv = cache["v"].at[phys, off].set(v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": pk, "v": pv, "length": length + S}
            n = pt.shape[1]
            flat = pt.reshape(-1)
            ck = jnp.take(pk, flat, axis=0).reshape(B, n * psz, Hkv, dh)
            cv = jnp.take(pv, flat, axis=0).reshape(B, n * psz, Hkv, dh)
            lim = cache.get("kv_limit")
            if lim is not None and lim < n * psz:
                # page capacity rounds max_len up to a page multiple; slice
                # back so the softmax reduction shape matches dense exactly
                ck, cv = ck[:, :lim], cv[:, :lim]
        elif jnp.ndim(length):
            row_upd = lambda c, u, l: jax.lax.dynamic_update_slice(
                c, u, (l, 0, 0))
            ck = jax.vmap(row_upd)(cache["k"], k.astype(cache["k"].dtype), length)
            cv = jax.vmap(row_upd)(cache["v"], v.astype(cache["v"].dtype), length)
            new_cache = {"k": ck, "v": cv, "length": length + S}
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, length, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, length, 0, 0))
            new_cache = {"k": ck, "v": cv, "length": length + S}
        Smax = ck.shape[1]
        group = Hq // Hkv
        if S == 1 and ops.get_mode() == "pallas" \
                and not cfg.shard_activations:
            # registered Pallas decode kernel: the whole masked-softmax
            # attention chain is ONE stitchable CUSTOM node (the position
            # mask covers length validity, so stale cache rows never
            # contribute — same semantics as the einsum path below)
            out = ops.decode_attention(q, ck, cv, positions[:, 0],
                                       scale=scale, window=window)
            out = out.reshape(B, S, Hq * dh) @ p["wo"].astype(dt)
            return out, new_cache
        # grouped-GQA einsum against the cache at native Hkv width: no
        # jnp.repeat copy, no f32 cache clone — bf16 dots accumulate in f32
        # (§Perf decode iteration)
        qg = q.reshape(B, S, Hkv, group, dh)
        if cfg.shard_activations:
            # contract over the cache's (sharded) head-dim: reshard the tiny
            # q instead of letting SPMD all-gather the 1 GB cache
            from .sharding import hint
            qg = hint(qg, "data", None, None, None, "model")
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck,
                            preferred_element_type=jnp.float32) * scale
        kpos = jnp.arange(Smax)[None, None, None, None, :]
        qpos = positions[:, None, None, :, None]
        mask = kpos <= qpos
        if window is not None:
            mask = mask & (qpos - kpos < window)
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(dt), cv,
                         preferred_element_type=jnp.float32)
        out = out.reshape(B, S, Hq, dh).astype(dt)
    elif ops.get_mode() == "pallas" and S % 128 == 0:
        out = ops.attention(q, k, v, causal=True, scale=scale, window=window)
    elif S > _CHUNK_Q and S % _CHUNK_Q == 0:
        out = _chunked_causal_attention(q, k, v, scale, window,
                                        use_scan=cfg.scan_layers, cfg=cfg)
    else:
        from repro.kernels import ref
        out = ref.attention(q, k, v, causal=True, scale=scale, window=window,
                            positions_q=positions)
    out = out.reshape(B, S, Hq * dh) @ p["wo"].astype(dt)
    return out, new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  dtype=None):
    dtype = dtype or cfg.dtype
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.dh)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# sort-based capacity MoE (dropless up to capacity, GShard semantics)
# ---------------------------------------------------------------------------

def _moe_group_dispatch(xg, wg, ig, p, cfg: ModelConfig, C: int):
    """Dispatch ONE token group: xg (Tg, D), router weights wg (Tg, k),
    expert ids ig (Tg, k) -> (yg (Tg, D), counts (E,), n_dropped ())."""
    m = cfg.moe
    dt = cfg.dtype
    Tg, D = xg.shape
    E, k = m.n_experts, m.top_k

    flat_e = ig.reshape(-1)                                    # (Tg*k,)
    flat_w = wg.reshape(-1).astype(dt)
    flat_tok = jnp.arange(Tg * k, dtype=jnp.int32) // k

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]

    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(Tg * k, dtype=jnp.int32) - offsets[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)     # E*C = drop bin

    buf_tok = jnp.full((E * C + 1,), -1, jnp.int32).at[slot].set(sorted_tok)
    buf_tok = buf_tok[:-1]
    gathered = jnp.where(
        (buf_tok >= 0)[:, None],
        xg[jnp.clip(buf_tok, 0, Tg - 1)],
        jnp.zeros((), dt),
    ).reshape(E, C, D)

    gate = jnp.einsum("ecd,edf->ecf", gathered, p["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", gathered, p["w_up"].astype(dt))
    h = ops.swiglu(gate, up)
    yexp = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt)).reshape(E * C, D)
    yexp = jnp.concatenate([yexp, jnp.zeros((1, D), dt)], axis=0)  # drop bin

    slot_of_flat = jnp.full((Tg * k,), E * C, jnp.int32).at[order].set(slot)
    contrib = flat_w[:, None] * yexp[slot_of_flat]
    yg = jnp.zeros((Tg, D), dt).at[flat_tok].add(contrib)
    return yg, counts, jnp.sum(~keep)


def _moe_groups(cfg: ModelConfig, T: int) -> int:
    m = cfg.moe
    G = m.n_groups if m.n_groups else 16
    while T % G:
        G //= 2
    return max(G, 1)


def apply_moe(p: Params, x2d, cfg: ModelConfig):
    """x2d: (T, D) -> (T, D), aux metrics dict.

    Sort-based capacity dispatch: token-expert assignments are sorted by
    expert, packed into (E, C, D) buffers (overflow dropped — GShard
    token-choice semantics), run through batched expert FFNs (EP-shardable
    einsum), and combined back with router weights.  No (T, E, C) one-hot is
    ever materialized, so the HLO stays memory-sane at 1M tokens.

    With ``moe.n_groups > 1`` the dispatch runs independently per token
    group (vmap); groups align with the DP shards so the sort/gather/scatter
    never crosses devices — only the expert einsums communicate (§Perf).
    """
    m = cfg.moe
    T, D = x2d.shape
    E, k = m.n_experts, m.top_k

    logits = (x2d @ p["router"].astype(cfg.dtype)).astype(jnp.float32)
    weights, idx = ops.topk_router(logits, k, m.renormalize)   # (T, k)

    G = _moe_groups(cfg, T)
    Tg = T // G
    C = int(math.ceil(m.capacity_factor * Tg * k / E))
    C = max(8, -(-C // 8) * 8)  # round up to sublane multiple

    if G == 1:
        y, counts, n_drop = _moe_group_dispatch(x2d, weights, idx, p, cfg, C)
    else:
        from .sharding import hint_rows
        xg = x2d.reshape(G, Tg, D)
        if cfg.shard_activations:
            xg = hint_rows(xg)
        yg, counts_g, drop_g = jax.vmap(
            lambda xa, wa, ia: _moe_group_dispatch(xa, wa, ia, p, cfg, C)
        )(xg, weights.reshape(G, Tg, k), idx.reshape(G, Tg, k))
        if cfg.shard_activations:
            yg = hint_rows(yg)
        y = yg.reshape(T, D)
        counts = jnp.sum(counts_g, axis=0)
        n_drop = jnp.sum(drop_g)

    if m.n_shared:
        y = y + apply_mlp(p["shared"], x2d, cfg)

    # load-balance aux (Switch): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    frac = counts.astype(jnp.float32) / (T * k)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
    dropped = n_drop / (T * k)
    return y, {"moe_aux": aux, "moe_drop_frac": dropped.astype(jnp.float32)}


def apply_moe_dense(p: Params, x2d, cfg: ModelConfig):
    """Dense (soft) MoE: every expert runs on every token, combined by the
    full router-softmax gates.  x2d: (T, D) -> (T, D).

    Unlike :func:`apply_moe` there is no sort/gather dispatch, so the HLO is
    E structurally-identical, mutually-independent FFN chains hanging off the
    shared input — exactly the shape the horizontal packer
    (:func:`repro.core.fusiongen.packing_fusion`) bins into shared stitched
    kernels.  This is the block-level stitching form (``Model.block_fn``) and
    the packing benchmark workload; train/serve keep the sparse dispatch.
    """
    m = cfg.moe
    dt = cfg.dtype
    logits = (x2d @ p["router"].astype(dt)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1).astype(dt)        # (T, E)
    y = jnp.zeros_like(x2d)
    for e in range(m.n_experts):
        gt = x2d @ p["w_gate"][e].astype(dt)
        up = x2d @ p["w_up"][e].astype(dt)
        h = ops.swiglu(gt, up)
        ye = h @ p["w_down"][e].astype(dt)
        y = y + gates[:, e:e + 1] * ye
    if m.n_shared:
        y = y + apply_mlp(p["shared"], x2d, cfg)
    return y
