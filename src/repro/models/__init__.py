"""Model zoo for the 10 assigned architectures."""

from .api import Model, build_model
from .config import HybridConfig, ModelConfig, MoEConfig, SHAPES, ShapeConfig, SSMConfig
from .sharding import (batch_pspecs, batch_shard_axes, cache_pspecs,
                       local_avals, local_shape, mesh_axes, param_pspecs,
                       param_shardings, slot_pspecs)

__all__ = [
    "Model", "build_model",
    "ModelConfig", "MoEConfig", "SSMConfig", "HybridConfig",
    "ShapeConfig", "SHAPES",
    "param_pspecs", "param_shardings", "batch_pspecs", "cache_pspecs", "mesh_axes",
    "local_avals", "local_shape", "batch_shard_axes", "slot_pspecs",
]
