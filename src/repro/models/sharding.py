"""Sharding rules: parameter / batch / cache PartitionSpecs for any mesh.

Megatron-style TP on the ``model`` axis, DP over ``data`` (x ``pod`` when
present), EP for MoE experts when the expert count divides the model axis,
sequence-sharded KV caches for decode.  Rules are name-based over the param
tree paths, so every architecture (dense / moe / ssm / hybrid / encdec)
shares one rule table.

Uneven dims: GSPMD pads internally, but padding the *vocab* axis of the
embedding wastes HBM and inserts masked ops; we only shard an axis when it
divides evenly, else fall back to replicated for that axis.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig

Params = dict[str, Any]


def mesh_axes(mesh: Mesh) -> tuple[tuple[str, ...], str]:
    """Returns (dp_axes, tp_axis). The 'pod' axis, when present, is outer DP."""
    names = mesh.axis_names
    tp = "model" if "model" in names else names[-1]
    dp = tuple(n for n in names if n != tp)
    return dp, tp


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


# -- per-leaf rule -----------------------------------------------------------

def _param_spec(path: str, shape: tuple[int, ...], cfg: ModelConfig,
                mesh: Mesh) -> P:
    dp, tp = mesh_axes(mesh)
    tp_n = _axis_size(mesh, tp)

    def shard_if(dim: int, axis=tp):
        """Spec sharding dimension `dim` on `axis` if divisible."""
        if shape[dim] % tp_n == 0:
            return tuple(axis if i == dim else None for i in range(len(shape)))
        return (None,) * len(shape)

    stacked = path.startswith(("layers.", "supers.", "enc_layers.", "dec_layers."))
    off = 1 if stacked else 0  # leading layer-stack dim is never sharded
    d = len(shape)

    def spec(*dims_axes):
        out = [None] * d
        for dim, axis in dims_axes:
            dim += off
            if dim < d and shape[dim] % tp_n == 0:
                out[dim] = axis
        return P(*out)

    leaf = path.split(".")[-1]
    parent = path.split(".")[-2] if "." in path else ""

    # embeddings / heads
    if leaf == "embed":
        return P(tp, None) if shape[0] % tp_n == 0 else P(None, None)
    if leaf == "lm_head":
        return P(None, tp) if shape[1] % tp_n == 0 else P(None, None)

    # attention
    if leaf in ("wq", "wk", "wv"):
        return spec((1, tp))
    if leaf == "wo":
        return spec((0, tp))
    if leaf in ("bq", "bk", "bv"):
        return spec((0, tp))

    # MLP
    if leaf in ("w_gate", "w_up"):
        if d - off == 3:  # MoE experts (E, D, F)
            m = cfg.moe
            if m and m.n_experts % tp_n == 0:
                return spec((0, tp))          # EP
            return spec((2, tp))              # TP within expert
        return spec((1, tp))
    if leaf == "w_down":
        if d - off == 3:  # (E, F, D)
            m = cfg.moe
            if m and m.n_experts % tp_n == 0:
                return spec((0, tp))
            return spec((1, tp))
        return spec((0, tp))
    if leaf == "router":
        return P(*([None] * d))

    # mamba
    if leaf in ("in_proj", "x_proj", "dt_proj", "out_proj") and cfg.family == "ssm":
        if leaf in ("in_proj", "dt_proj"):
            return spec((1, tp))
        return spec((0, tp))
    if leaf in ("conv_w",):
        return spec((1, tp))
    if leaf in ("conv_b", "dt_bias", "D"):
        return spec((0, tp))
    if leaf == "A_log":
        return spec((0, tp))

    # griffin / rg-lru
    if leaf in ("x_proj", "in_gate", "rec_gate"):
        return spec((1, tp))
    if leaf == "out_proj":
        return spec((0, tp))
    if leaf == "Lambda":
        return spec((0, tp))

    # norms, biases, everything small: replicated
    return P(*([None] * d))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def param_pspecs(abstract_params: Params, cfg: ModelConfig, mesh: Mesh):
    """Pytree of PartitionSpec matching the param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(_path_str(path), leaf.shape, cfg, mesh),
        abstract_params,
    )


def param_shardings(abstract_params: Params, cfg: ModelConfig, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_pspecs(abstract_params, cfg, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


# -- data & cache specs --------------------------------------------------------

def _dp_size(mesh: Mesh) -> int:
    dp, _ = mesh_axes(mesh)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return n


def _dp_if_divisible(mesh: Mesh, dim: int):
    """The DP axes tuple when `dim` divides evenly, else None (replicate)."""
    dp, _ = mesh_axes(mesh)
    return dp if dim % _dp_size(mesh) == 0 else None


def batch_pspecs(batch_tree, mesh: Mesh):
    """Anything with a leading global-batch dim: shard it over all DP axes
    (replicate when the batch is too small to split, e.g. long_500k B=1)."""

    def spec(leaf):
        d = len(leaf.shape)
        if d == 0:
            return P()
        return P(_dp_if_divisible(mesh, leaf.shape[0]), *([None] * (d - 1)))

    return jax.tree.map(spec, batch_tree)


# decode-cache TP placement: "seq" (baseline: sequence-sharded) or "dh"
# (head-dim-sharded — keeps the per-token dynamic_update_slice device-local;
# §Perf decode iteration).  Module-level so drivers can flip it per run.
CACHE_KV_DIM = "seq"


def cache_pspecs(cache_tree, cfg: ModelConfig, mesh: Mesh):
    """KV caches: (L, B, S, Hkv, dh) -> batch over DP, TP on the sequence or
    head dim per CACHE_KV_DIM. Recurrent states: batch over DP, channels
    over TP when divisible."""
    dp, tp = mesh_axes(mesh)
    tp_n = _axis_size(mesh, tp)

    def spec(path, leaf):
        name = _path_str(path)
        d = len(leaf.shape)
        if d == 0:
            return P()

        def dpx(dim_size):
            return dp if dim_size % _dp_size(mesh) == 0 else None

        if name.endswith(("k", "v")) and d >= 4:
            out = [None] * d
            out[d - 4] = dpx(leaf.shape[d - 4])   # batch of (..., B, S, H, dh)
            if CACHE_KV_DIM == "dh" and leaf.shape[d - 1] % tp_n == 0:
                out[d - 1] = tp                   # head-dim-sharded KV
            elif leaf.shape[d - 3] % tp_n == 0:
                out[d - 3] = tp                   # sequence-sharded KV
            return P(*out)
        if name.endswith("ssm") and d == 4:       # (L, B, Dm, N)
            out = [None, dpx(leaf.shape[1]), None, None]
            if leaf.shape[2] % tp_n == 0:
                out[2] = tp
            return P(*out)
        if name.endswith(("lru", "lru_rest")) and d >= 3:
            out = [None] * d
            out[d - 2] = dpx(leaf.shape[d - 2])
            if leaf.shape[d - 1] % tp_n == 0:
                out[d - 1] = tp
            return P(*out)
        if name.endswith("conv") and d == 4:      # (L, B, K-1, Dm)
            out = [None, dpx(leaf.shape[1]), None, None]
            if leaf.shape[3] % tp_n == 0:
                out[3] = tp
            return P(*out)
        # tokens / enc_out / misc: batch-sharded on first dim when possible
        out = [None] * d
        out[0] = dpx(leaf.shape[0])
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


# -- shard-local views (shard_map tracing support) -----------------------------

def _spec_axes(entry) -> tuple[str, ...]:
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def local_shape(shape: tuple[int, ...], spec: P, mesh: Mesh) -> tuple[int, ...]:
    """The per-device block shape of a global ``shape`` under ``spec`` — what
    a ``shard_map`` body sees, and therefore the shapes the stitch pipeline
    traces and solves per-shard graphs at."""
    parts = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = list(shape)
    for i, entry in enumerate(parts):
        n = 1
        for a in _spec_axes(entry):
            n *= mesh.shape[a]
        if n > 1:
            if out[i] % n:
                raise ValueError(
                    f"dim {i} of shape {shape} not divisible by mesh axes "
                    f"{_spec_axes(entry)} (size {n})")
            out[i] //= n
    return tuple(out)


def local_avals(tree, specs, mesh: Mesh):
    """ShapeDtypeStruct pytree of shard-local blocks.  ``specs`` is a pytree
    of PartitionSpec matching ``tree`` (PartitionSpecs stay whole because
    ``tree``'s structure drives the map)."""
    return jax.tree.map(
        lambda leaf, spec: jax.ShapeDtypeStruct(
            local_shape(tuple(leaf.shape), spec, mesh), leaf.dtype),
        tree, specs)


def batch_shard_axes(mesh: Mesh, batch_dim: int) -> tuple[str, ...]:
    """Mesh axes to split a leading batch/slot dim over for shard-local
    compute: every axis when the dim divides the whole mesh (the model axis
    moonlights as extra DP — the gathered-params backward/decode body has no
    TP collectives, so its only use for the model axis is more rows), else
    the DP axes, else none (replicated rows; reductions stay correct because
    cross-shard means of identical values are the identity)."""
    if batch_dim % mesh.size == 0:
        return tuple(mesh.axis_names)
    dp, _ = mesh_axes(mesh)
    if batch_dim % _dp_size(mesh) == 0:
        return dp
    return ()


def slot_pspecs(state_tree, mesh: Mesh, axes: tuple[str, ...]):
    """DP-replica specs for the serving decode state: shard each leaf's
    slot/batch dim over ``axes`` and replicate everything else.  Unlike
    :func:`cache_pspecs` there is deliberately no TP dim here — the sharded
    decode body runs shard-locally (no in-model collectives), so sequence or
    head dims must stay whole within a replica."""
    axes = tuple(axes)

    def spec(path, leaf):
        name = _path_str(path)
        d = len(leaf.shape)
        if d == 0 or not axes:
            return P(*([None] * d))
        # slot dim per leaf kind: KV caches (L, B, S, H, dh) -> dim d-4;
        # ssm (L, B, Dm, N) / conv (L, B, K-1, Dm) -> dim 1; lru (..., B, D)
        # -> dim d-2; everything else (length vector, tokens, logits) -> dim 0
        if name.endswith(("k", "v")) and d >= 4:
            dim = d - 4
        elif name.endswith(("ssm", "conv")) and d == 4:
            dim = 1
        elif name.endswith(("lru", "lru_rest")) and d >= 3:
            dim = d - 2
        else:
            dim = 0
        out = [None] * d
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if leaf.shape[dim] % n == 0:
            out[dim] = axes
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, state_tree)


# -- in-graph activation sharding hints ---------------------------------------

def hint(x, *axes):
    """with_sharding_constraint with graceful degradation: tries the spec
    with 'pod'+'data' merged DP first, then plain, then no-op (no mesh)."""
    import jax as _jax
    from jax.sharding import PartitionSpec as _P
    variants = [axes]
    if "data" in axes:
        variants.insert(0, tuple(("pod", "data") if a == "data" else a
                                 for a in axes))
    for spec_axes in variants:
        try:
            spec = list(spec_axes) + [None] * (x.ndim - len(spec_axes))
            return _jax.lax.with_sharding_constraint(x, _P(*spec))
        except Exception:
            continue
    return x


def hint_rows(x, row_dim: int = 0):
    """Constrain `row_dim` of an activation to the DP axes when tracing under
    a mesh context; silently a no-op otherwise (unit tests, single device).

    Beyond-paper optimization knob (`ModelConfig.shard_activations`): GSPMD
    sharding propagation can drop the batch sharding across deep unrolled /
    remat'd stacks, turning per-layer TP all-reduces into full-batch
    all-reduces; pinning the token dim restores the O(tokens/dp) payload.
    """
    import jax as _jax
    from jax.sharding import PartitionSpec as _P
    for dp in (("pod", "data"), "data"):
        try:
            spec = [None] * x.ndim
            spec[row_dim] = dp
            return _jax.lax.with_sharding_constraint(x, _P(*spec))
        except Exception:
            continue
    return x
