"""Unified model interface: build any assigned architecture from its config,
get train/prefill/decode callables and dry-run input specs.

``Model`` methods are pure functions of (params, inputs) — ready for
``jax.jit`` with shardings from :mod:`repro.models.sharding`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec, griffin, lm, mamba
from .config import ModelConfig, ShapeConfig

Params = dict[str, Any]


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    train_forward: Callable[..., tuple]           # (params, batch) -> (loss, aux)
    prefill: Callable[..., tuple]                 # (params, **inputs) -> (logits, cache)
    decode_step: Callable[..., tuple] | None      # (params, cache, tokens, **extra)
    init_cache: Callable[[int, int], Params] | None
    # (num_pages, page_size) -> KV page pool for paged serving; None for
    # families without a paged decode path (their cache is not a dense
    # per-position KV rectangle)
    init_paged_cache: Callable[[int, int], Params] | None = None
    # single-block forward (layer_params, x) -> x': the function-level entry
    # point for repro.exec.stitch() — lets any block be stitched standalone
    # without flowing through the train or serve machinery (see
    # examples/stitch_fn.py).  None for families without a pure block form.
    block_fn: Callable[..., Any] | None = None

    def layer_params(self, params: Params, index: int = 0) -> Params:
        """Slice one layer's params out of the stacked ``layers`` tree —
        the ``block_fn`` operand for layer ``index``."""
        if "layers" not in params:
            raise ValueError(f"{self.cfg.family!r} params carry no stacked "
                             f"'layers' tree")
        return jax.tree.map(lambda l: l[index], params["layers"])

    # -- dry-run input specs --------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        tok = jax.ShapeDtypeStruct((B, S), i32)

        if shape.kind == "train":
            batch: dict[str, Any] = {"tokens": tok, "labels": tok}
            if cfg.family == "vlm":
                batch["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_patch_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
            if cfg.family == "audio":
                batch["frames"] = jax.ShapeDtypeStruct(
                    (B, S, cfg.d_model), jnp.dtype(cfg.dtype))
            return {"batch": batch}

        if shape.kind == "prefill":
            out: dict[str, Any] = {"tokens": tok}
            if cfg.family == "vlm":
                out["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_patch_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
            if cfg.family == "audio":
                out["frames"] = jax.ShapeDtypeStruct(
                    (B, S, cfg.d_model), jnp.dtype(cfg.dtype))
            return out

        # decode / long_decode: one new token against a seq_len cache
        cache = jax.eval_shape(lambda: self.init_cache(B, S))
        out = {
            "cache": cache,
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        }
        if cfg.family == "audio":
            out["enc_out"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.dtype(cfg.dtype))
        return out

    def abstract_params(self) -> Params:
        return jax.eval_shape(self.init, jax.ShapeDtypeStruct((2,), jnp.uint32))


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            init=lambda key: mamba.init_params(cfg, key),
            train_forward=lambda p, batch: mamba.train_forward(p, batch, cfg),
            prefill=lambda p, tokens, **kw: mamba.prefill(p, tokens, cfg),
            decode_step=lambda p, cache, tokens, **kw: mamba.decode_step(
                p, cache, tokens, cfg),
            init_cache=lambda b, s: mamba.init_cache(cfg, b, s),
            block_fn=lambda lp, x: mamba._block(lp, x, cfg),
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: griffin.init_params(cfg, key),
            train_forward=lambda p, batch: griffin.train_forward(p, batch, cfg),
            prefill=lambda p, tokens, **kw: griffin.prefill(p, tokens, cfg),
            decode_step=lambda p, cache, tokens, **kw: griffin.decode_step(
                p, cache, tokens, cfg),
            init_cache=lambda b, s: griffin.init_cache(cfg, b, s),
            block_fn=lambda lp, x: griffin._rec_block(lp, x, cfg),
        )
    if cfg.family == "audio":
        def _train(p, batch):
            return encdec.train_forward(p, batch, cfg)

        def _prefill(p, tokens, frames=None, **kw):
            enc_out = encdec.encode(p, frames, cfg)
            # teacher-forced decoder prefill: build self-attn cache
            h = encdec.decode(p, tokens, enc_out, cfg)
            logits = (h[:, -1] @ p["lm_head"].astype(cfg.dtype)).astype(jnp.float32)
            return logits, enc_out

        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(cfg, key),
            train_forward=_train,
            prefill=_prefill,
            decode_step=lambda p, cache, tokens, enc_out=None, **kw:
                encdec.decode_step(p, cache, tokens, enc_out, cfg),
            init_cache=lambda b, s: encdec.init_cache(cfg, b, s),
        )
    # dense / moe / vlm share the decoder-only LM implementation
    return Model(
        cfg=cfg,
        init=lambda key: lm.init_params(cfg, key),
        train_forward=lambda p, batch: lm.train_forward(p, batch, cfg),
        prefill=lambda p, tokens, patch_embeds=None, max_len=None,
            true_len=None, **kw: lm.prefill(
            p, tokens, cfg, max_len=max_len, patch_embeds=patch_embeds,
            true_len=true_len),
        decode_step=lambda p, cache, tokens, kv_limit=None, **kw:
            lm.decode_step(p, cache, tokens, cfg, kv_limit=kv_limit),
        init_cache=lambda b, s: lm.init_cache(cfg, b, s),
        init_paged_cache=lambda n, ps: lm.init_paged_cache(cfg, n, ps),
        block_fn=lambda lp, x: lm.block_forward(lp, x, cfg),
    )
