"""Encoder-decoder transformer (seamless-m4t-medium family).

The audio frontend is a STUB per the brief: ``input_specs`` feeds
precomputed frame embeddings (B, S_frames, D) straight into the encoder.
Decoder layers add cross-attention over the encoder output.  Pre-LN
LayerNorm, GeGLU-free plain MLP is configurable via cfg.act.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from .config import ModelConfig
from .layers import (
    Params, apply_attention, apply_mlp, apply_norm,
    init_attention, init_mlp, init_norm, scan_or_unroll,
)


def init_enc_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "norm1": init_norm(k1, cfg),
        "attn": init_attention(k2, cfg),
        "norm2": init_norm(k3, cfg),
        "mlp": init_mlp(k4, cfg),
    }


def init_dec_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "norm1": init_norm(k1, cfg),
        "self_attn": init_attention(k2, cfg),
        "norm_x": init_norm(k3, cfg),
        "cross_attn": init_attention(k4, cfg),
        "norm2": init_norm(k5, cfg),
        "mlp": init_mlp(k6, cfg),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ke, k1, k2, kh = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: init_enc_layer(k, cfg))(
        jax.random.split(k1, cfg.n_encoder_layers))
    dec = jax.vmap(lambda k: init_dec_layer(k, cfg))(
        jax.random.split(k2, cfg.n_layers))
    return {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * 0.02).astype(cfg.param_dtype),
        "enc_layers": enc,
        "enc_norm": init_norm(kh, cfg),
        "dec_layers": dec,
        "final_norm": init_norm(kh, cfg),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab))
                    / math.sqrt(cfg.d_model)).astype(cfg.param_dtype),
    }


def _bidir_attention(p, x, cfg: ModelConfig):
    """Full bidirectional self-attention (encoder)."""
    dt = cfg.dtype
    B, S, D = x.shape
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = (x @ p["wq"].astype(dt)).reshape(B, S, Hq, dh)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, Hkv, dh)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, Hkv, dh)
    out = ref.attention(q, k, v, causal=False)
    return out.reshape(B, S, Hq * dh) @ p["wo"].astype(dt)


def _cross_attention(p, x, enc_out, cfg: ModelConfig):
    dt = cfg.dtype
    B, S, D = x.shape
    Se = enc_out.shape[1]
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = (x @ p["wq"].astype(dt)).reshape(B, S, Hq, dh)
    k = (enc_out @ p["wk"].astype(dt)).reshape(B, Se, Hkv, dh)
    v = (enc_out @ p["wv"].astype(dt)).reshape(B, Se, Hkv, dh)
    out = ref.attention(q, k, v, causal=False)
    return out.reshape(B, S, Hq * dh) @ p["wo"].astype(dt)


def encode(params: Params, frames, cfg: ModelConfig):
    """frames: (B, S_frames, D) precomputed frontend embeddings."""
    h = frames.astype(cfg.dtype)

    def body(carry, lp):
        x = carry
        x = x + _bidir_attention(lp["attn"], apply_norm(lp["norm1"], x, cfg), cfg)
        x = x + apply_mlp(lp["mlp"], apply_norm(lp["norm2"], x, cfg), cfg)
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, _ = scan_or_unroll(body, h, params["enc_layers"],
                          cfg.n_encoder_layers, cfg.scan_layers)
    return apply_norm(params["enc_norm"], h, cfg)


def decode(params: Params, tokens, enc_out, cfg: ModelConfig):
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)

    def body(carry, lp):
        x = carry
        a, _ = apply_attention(lp["self_attn"], apply_norm(lp["norm1"], x, cfg),
                               cfg, positions)
        x = x + a
        x = x + _cross_attention(lp["cross_attn"], apply_norm(lp["norm_x"], x, cfg),
                                 enc_out, cfg)
        x = x + apply_mlp(lp["mlp"], apply_norm(lp["norm2"], x, cfg), cfg)
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, _ = scan_or_unroll(body, h, params["dec_layers"], cfg.n_layers,
                          cfg.scan_layers)
    return apply_norm(params["final_norm"], h, cfg)


def train_forward(params: Params, batch: dict, cfg: ModelConfig):
    from .lm import lm_loss
    enc_out = encode(params, batch["frames"], cfg)
    h = decode(params, batch["tokens"], enc_out, cfg)
    return lm_loss(params, h, batch["labels"], cfg), {}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.dh)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cache: Params, tokens, enc_out, cfg: ModelConfig):
    """Single-token decoder step with self-attn KV cache; cross-attn reads
    the static encoder output."""
    B, S = tokens.shape
    length = cache["length"]
    positions = length + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)

    def body(carry, xs):
        x = carry
        lp, ck, cv = xs
        layer_cache = {"k": ck, "v": cv, "length": length}
        a, nc = apply_attention(lp["self_attn"], apply_norm(lp["norm1"], x, cfg),
                                cfg, positions, cache=layer_cache)
        x = x + a
        x = x + _cross_attention(lp["cross_attn"], apply_norm(lp["norm_x"], x, cfg),
                                 enc_out, cfg)
        x = x + apply_mlp(lp["mlp"], apply_norm(lp["norm2"], x, cfg), cfg)
        return x, (nc["k"], nc["v"])

    h, (nk, nv) = scan_or_unroll(
        body, h, (params["dec_layers"], cache["k"], cache["v"]),
        cfg.n_layers, cfg.scan_layers)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = (h[:, -1] @ params["lm_head"].astype(cfg.dtype)).astype(jnp.float32)
    return logits, {"k": nk, "v": nv, "length": length + S}
