"""Mamba-1 LM (falcon-mamba-7b family) — attention-free SSM.

Per block: in_proj -> causal depthwise conv -> SiLU -> selective scan
(:func:`repro.kernels.ops.mamba_scan`) -> output gate -> out_proj.
Training scans the sequence inside the kernel; decode carries (conv window,
SSM state) per layer, so the long_500k cell is O(1) in context length.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ModelConfig, SSMConfig
from .layers import Params, apply_norm, init_norm, scan_or_unroll


def _dims(cfg: ModelConfig):
    s = cfg.ssm or SSMConfig()
    dm = s.expand * cfg.d_model
    dtr = s.dt_rank or math.ceil(cfg.d_model / 16)
    return s, dm, dtr


def init_layer(key, cfg: ModelConfig) -> Params:
    s, dm, dtr = _dims(cfg)
    D, N = cfg.d_model, s.d_state
    ks = jax.random.split(key, 7)
    sc = 1.0 / math.sqrt(D)
    pd = cfg.param_dtype
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (dm, 1))
    return {
        "norm": init_norm(ks[0], cfg),
        "in_proj": (jax.random.normal(ks[1], (D, 2 * dm)) * sc).astype(pd),
        "conv_w": (jax.random.normal(ks[2], (s.d_conv, dm)) / math.sqrt(s.d_conv)).astype(pd),
        "conv_b": jnp.zeros((dm,), pd),
        "x_proj": (jax.random.normal(ks[3], (dm, dtr + 2 * N)) / math.sqrt(dm)).astype(pd),
        "dt_proj": (jax.random.normal(ks[4], (dtr, dm)) / math.sqrt(dtr)).astype(pd),
        "dt_bias": jnp.zeros((dm,), pd),
        "A_log": jnp.log(A).astype(pd),
        "D": jnp.ones((dm,), pd),
        "out_proj": (jax.random.normal(ks[5], (dm, D)) / math.sqrt(dm)).astype(pd),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kl, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(jax.random.split(kl, cfg.n_layers))
    return {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * 0.02).astype(cfg.param_dtype),
        "layers": layers,
        "final_norm": init_norm(kh, cfg),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab))
                    / math.sqrt(cfg.d_model)).astype(cfg.param_dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, Dm); w: (K, Dm)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = b.astype(x.dtype)
    acc = jnp.zeros_like(x) + out
    for i in range(K):
        acc = acc + pad[:, i:i + x.shape[1], :] * w[K - 1 - i].astype(x.dtype)
    return acc


def _block(lp: Params, x, cfg: ModelConfig):
    s, dm, dtr = _dims(cfg)
    dt_ = cfg.dtype
    N = s.d_state
    xz = x @ lp["in_proj"].astype(dt_)
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = _causal_conv(xin, lp["conv_w"], lp["conv_b"])
    xc = jax.nn.silu(xc)
    dbc = xc @ lp["x_proj"].astype(dt_)
    dt_lowrank = dbc[..., :dtr]
    B_ssm = dbc[..., dtr:dtr + N].astype(jnp.float32)
    C_ssm = dbc[..., dtr + N:].astype(jnp.float32)
    delta = jax.nn.softplus(
        (dt_lowrank @ lp["dt_proj"].astype(dt_)) + lp["dt_bias"].astype(dt_))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y = ops.mamba_scan(xc, delta, A, B_ssm, C_ssm, lp["D"].astype(jnp.float32))
    y = y * jax.nn.silu(z)
    return y @ lp["out_proj"].astype(dt_)


def backbone(params: Params, h, cfg: ModelConfig):
    def body(carry, lp):
        if cfg.shard_activations:
            from .layers import scan_or_unroll  # noqa: F401
            from .sharding import hint_rows
            carry = hint_rows(carry)
        x = apply_norm(lp["norm"], carry, cfg)
        return carry + _block(lp, x, cfg), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, _ = scan_or_unroll(body, h, params["layers"], cfg.n_layers,
                          cfg.scan_layers)
    return apply_norm(params["final_norm"], h, cfg)


def train_forward(params: Params, batch: dict, cfg: ModelConfig):
    from .lm import lm_loss
    tokens, labels = batch["tokens"], batch["labels"]
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    h = backbone(params, h, cfg)
    loss = lm_loss(params, h, labels, cfg)
    return loss, {}


# ---------------------------------------------------------------------------
# serving — O(1)-in-context state
# ---------------------------------------------------------------------------

def prefill(params: Params, tokens, cfg: ModelConfig, max_len: int | None = None):
    """Forward over the prompt, returning (last logits, recurrent cache)."""
    s, dm, dtr = _dims(cfg)
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)

    def body(carry, lp):
        x = apply_norm(lp["norm"], carry, cfg)
        dt_ = cfg.dtype
        N = s.d_state
        xz = x @ lp["in_proj"].astype(dt_)
        xin, z = jnp.split(xz, 2, axis=-1)
        xc = jax.nn.silu(_causal_conv(xin, lp["conv_w"], lp["conv_b"]))
        dbc = xc @ lp["x_proj"].astype(dt_)
        B_ssm = dbc[..., dtr:dtr + N].astype(jnp.float32)
        C_ssm = dbc[..., dtr + N:].astype(jnp.float32)
        delta = jax.nn.softplus((dbc[..., :dtr] @ lp["dt_proj"].astype(dt_))
                                + lp["dt_bias"].astype(dt_))
        A = -jnp.exp(lp["A_log"].astype(jnp.float32))
        y, ssm_state = ops.mamba_scan(xc, delta, A, B_ssm, C_ssm,
                                      lp["D"].astype(jnp.float32),
                                      return_state=True)
        y = (y * jax.nn.silu(z)) @ lp["out_proj"].astype(dt_)
        conv_state = xin[:, -(s.d_conv - 1):, :]
        return carry + y, (conv_state.astype(cfg.dtype), ssm_state)

    h, (convs, ssms) = scan_or_unroll(body, h, params["layers"],
                                      cfg.n_layers, cfg.scan_layers)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = (h[:, -1] @ params["lm_head"].astype(cfg.dtype)).astype(jnp.float32)
    cache = {"conv": convs, "ssm": ssms, "length": jnp.asarray(S, jnp.int32)}
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    s, dm, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, s.d_conv - 1, dm), cfg.dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, dm, s.d_state), jnp.float32),
        "length": jnp.zeros((), jnp.int32),
    }


def _decode_block(lp, x1, conv_st, ssm_st, cfg: ModelConfig):
    """x1: (B, 1, D); single-token recurrent update."""
    s, dm, dtr = _dims(cfg)
    dt_ = cfg.dtype
    N = s.d_state
    xz = x1 @ lp["in_proj"].astype(dt_)
    xin, z = jnp.split(xz, 2, axis=-1)              # (B,1,dm)
    window = jnp.concatenate([conv_st, xin], axis=1)     # (B, K, dm)
    new_conv = window[:, 1:, :]
    # training conv applies w[0] to the CURRENT token and w[K-1] to the
    # oldest; the window is ordered oldest->current, so flip the taps.
    w = lp["conv_w"].astype(dt_)[::-1]               # (K, dm)
    xc = jnp.einsum("bkd,kd->bd", window, w) + lp["conv_b"].astype(dt_)
    xc = jax.nn.silu(xc)[:, None, :]                 # (B,1,dm)
    dbc = xc @ lp["x_proj"].astype(dt_)
    dt_lr = dbc[..., :dtr]
    B_ssm = dbc[..., dtr:dtr + N].astype(jnp.float32)[:, 0]   # (B,N)
    C_ssm = dbc[..., dtr + N:].astype(jnp.float32)[:, 0]
    delta = jax.nn.softplus((dt_lr @ lp["dt_proj"].astype(dt_))
                            + lp["dt_bias"].astype(dt_))[:, 0].astype(jnp.float32)  # (B,dm)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))             # (dm,N)
    xf = xc[:, 0].astype(jnp.float32)
    dA = jnp.exp(delta[..., None] * A[None])                  # (B,dm,N)
    dBx = (delta * xf)[..., None] * B_ssm[:, None, :]
    h = dA * ssm_st + dBx
    y = jnp.einsum("bdn,bn->bd", h, C_ssm) + lp["D"].astype(jnp.float32) * xf
    y = (y.astype(dt_) * jax.nn.silu(z[:, 0]))[:, None, :]
    return (y @ lp["out_proj"].astype(dt_)), new_conv, h


def decode_step(params: Params, cache: Params, tokens, cfg: ModelConfig):
    B, S = tokens.shape
    assert S == 1, "mamba decode is single-token"
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)

    def body(carry, xs):
        lp, conv_st, ssm_st = xs
        x = apply_norm(lp["norm"], carry, cfg)
        y, nc, nh = _decode_block(lp, x, conv_st, ssm_st, cfg)
        return carry + y, (nc, nh)

    h, (nconv, nssm) = scan_or_unroll(
        body, h, (params["layers"], cache["conv"], cache["ssm"]),
        cfg.n_layers, cfg.scan_layers)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = (h[:, -1] @ params["lm_head"].astype(cfg.dtype)).astype(jnp.float32)
    return logits, {"conv": nconv, "ssm": nssm, "length": cache["length"] + 1}
