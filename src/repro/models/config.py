"""Model & shape configuration for the 10 assigned architectures."""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN width
    n_shared: int = 0             # shared (always-on) experts
    d_shared: int = 0             # combined shared-expert FFN width
    capacity_factor: float = 1.25
    renormalize: bool = True
    # dispatch group count: tokens are routed/sorted/capacity-packed within
    # groups (GShard G); groups align with DP shards so dispatch stays local.
    # 1 = global dispatch (baseline); 0 = auto (min(16, divisors of T)).
    n_groups: int = 1


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style: repeating (recurrent, recurrent, local-attn)."""
    window: int = 2048
    pattern: tuple[str, ...] = ("rec", "rec", "attn")
    d_rnn: int = 0                # RG-LRU width (0 -> d_model)
    c: float = 8.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    act: str = "swiglu"           # swiglu | geglu | sqrelu
    norm: str = "rms"             # rms | ln
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    # encoder-decoder (seamless): encoder depth; decoder uses n_layers
    n_encoder_layers: int = 0
    # modality frontend stub sizes
    n_patch_tokens: int = 0       # vlm: image patch embeddings per sample
    n_frame_tokens: int = 0       # audio: frames per sample (encoder input)
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "full"           # full | dots | none
    # False -> python-loop layers/chunks instead of lax.scan.  Used by the
    # dry-run probes: XLA cost analysis counts while bodies once, so probes
    # unroll to make flops/bytes/collective counts exact.
    scan_layers: bool = True
    # pin activation token-dim sharding to the DP axes at layer boundaries
    # (beyond-paper collective fix; see DESIGN.md and EXPERIMENTS.md §Perf)
    shard_activations: bool = False
    # chunked-CE grouping: chunk the loss WITHIN each of `loss_groups` token
    # groups (aligned with DP shards) instead of across the global batch, so
    # every chunk matmul stays DP-parallel.  1 = global chunks (baseline).
    loss_groups: int = 1

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid-with-local-window)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline math)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        dh, Hq, Hkv = self.dh, self.n_heads, self.n_kv_heads
        total = V * D                              # embed
        if not self.tie_embeddings:
            total += D * V                         # lm head
        def attn_params() -> int:
            p = D * Hq * dh + 2 * D * Hkv * dh + Hq * dh * D
            if self.qkv_bias:
                p += (Hq + 2 * Hkv) * dh
            if self.qk_norm:
                p += 2 * dh
            return p
        def dense_mlp(f: int) -> int:
            return (3 if self.act in ("swiglu", "geglu") else 2) * D * f
        if self.family == "ssm":
            s = self.ssm or SSMConfig()
            dm = s.expand * D
            dtr = s.dt_rank or math.ceil(D / 16)
            per = (D * 2 * dm) + (dm * s.d_conv) + (dm * (dtr + 2 * s.d_state)) \
                + (dtr * dm) + (dm * s.d_state) + 2 * dm + (dm * D)
            total += L * (per + D)                  # + norm
            total += D                              # final norm
            return total
        if self.family == "hybrid":
            h = self.hybrid or HybridConfig()
            drnn = h.d_rnn or D
            rec = 2 * D * drnn + drnn * D + 3 * drnn  # gates+proj+lru params (approx)
            att = attn_params()
            mlp = dense_mlp(F)
            n_rec = sum(1 for i in range(L) if h.pattern[i % len(h.pattern)] == "rec")
            n_att = L - n_rec
            total += n_rec * (rec + mlp + 2 * D) + n_att * (att + mlp + 2 * D)
            total += D
            return total
        per_layer = attn_params() + 2 * D           # norms
        if self.family == "moe" and self.moe:
            m = self.moe
            per_layer += D * m.n_experts            # router
            per_layer += m.n_experts * (3 * D * m.d_expert)
            if m.n_shared:
                per_layer += 3 * D * m.d_shared
        else:
            per_layer += dense_mlp(F)
        total += L * per_layer
        if self.n_encoder_layers:
            enc_per = attn_params() + dense_mlp(F) + 2 * D
            total += self.n_encoder_layers * (enc_per + attn_params() + D)  # +cross-attn
        total += D                                   # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-in experts)."""
        if self.family != "moe" or not self.moe:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        all_exp = self.n_layers * m.n_experts * 3 * self.d_model * m.d_expert
        act_exp = self.n_layers * m.top_k * 3 * self.d_model * m.d_expert
        return full - all_exp + act_exp


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "long_decode"),
}
