"""``stitch()`` — a jit-like transform executing through the fusion pipeline.

This module is the single execution layer the whole repo dispatches
through: before it existed, the trace / compile-or-fallback /
miss-then-upgrade-polling / shard_map-dispatch / shape-drift-fallback logic
lived as three divergent copies inside the stitched train step, the serving
engine, and the packed optimizer.  ``stitch()`` owns all of it:

* **Tracing** is pytree-aware: positional args, kwargs, and arbitrarily
  nested containers flatten at the boundary and unflatten on return, so any
  ``fn(pytree...) -> pytree`` round-trips.  ``static_argnums`` values are
  baked into the trace (jit-like: they must be hashable) and a *changed*
  static value retraces into a new specialization.
* **Compilation** goes through :class:`repro.cache.CompilationService`
  miss-then-upgrade: the first call returns the instantly-available
  XLA-mode fallback artifact while the full stitch pipeline (pattern
  generation, ILP, tuning) runs on a background thread; every later call
  polls the cache and upgrades mid-flight.  A background compile that
  *fails* is surfaced once as a :class:`RuntimeWarning` and in
  :meth:`StitchedFunction.report` — the fallback keeps serving, and the
  doomed compile is not re-kicked.
* **Dispatch** is single-device or ``shard_map``, derived from the
  partition specs: with ``mesh=`` the function is traced at *shard-local*
  shapes (collectives inside ``fn`` trace via ``axis_env`` into executable
  CUSTOM fusion partitions), compiled under a mesh+spec placement cache
  key, and executed inside a jit-memoized ``shard_map`` wrapper rebuilt
  only when an upgrade swaps the artifact.
* **Fallback**: trace failure or a per-call shape/structure drift serves
  that call through ``jax.jit(fn)`` (sharded when specs resolve), counted
  in :meth:`StitchedFunction.report`.

Modes: ``"stitch"`` executes through the artifact; ``"shadow"`` compiles
and reports but serves jit (the serving engine's ``stitch_execute=False``);
``"offline"`` compiles synchronously at trace time (no background thread);
``"jit"`` disables stitching entirely and is pure (sharded) jit dispatch.
"""

from __future__ import annotations

import hashlib
import time
import warnings
from collections import OrderedDict
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.obs import timer as _ktimer

__all__ = ["StitchedFunction", "shard_wrap", "stitch", "tree_avals"]

MODES = ("stitch", "shadow", "offline", "jit")

_UNSEEN = object()   # jit-memo sentinel: signature not yet classified


def tree_avals(tree) -> tuple:
    """(shape, dtype) per leaf — the signature every drift/eligibility
    check in the repo compares; Python scalars get a scalar stand-in."""
    return tuple(
        (getattr(x, "shape", ()), str(getattr(x, "dtype", type(x).__name__)))
        for x in jax.tree_util.tree_leaves(tree))


_avals = tree_avals


def _resolve(spec_or_fn, args):
    """Partition specs may be given as values or as ``callable(*args)`` so
    they can depend on the concrete pytree structure (e.g. a KV cache whose
    slot specs are leaf-name based).  ``None`` means "this signature is not
    shardable — use the plain jit path"."""
    if spec_or_fn is None:
        return None
    if callable(spec_or_fn) and not isinstance(spec_or_fn, P):
        return spec_or_fn(*args)
    return spec_or_fn


def _is_spec_leaf(x) -> bool:
    return x is None or isinstance(x, P)


def _local_avals(args, in_specs, mesh: Mesh):
    """Shard-local ShapeDtypeStruct tree for ``args`` under (possibly
    pytree-prefix) ``in_specs`` — the shapes a ``shard_map`` body sees and
    therefore the shapes the stitch pipeline traces per-shard graphs at."""
    from repro.models.sharding import local_shape

    spec_leaves, spec_def = jax.tree_util.tree_flatten(
        in_specs, is_leaf=_is_spec_leaf)
    subtrees = spec_def.flatten_up_to(args)
    mapped = []
    for spec, sub in zip(spec_leaves, subtrees):
        spec = spec if spec is not None else P()
        mapped.append(jax.tree.map(
            lambda l, _s=spec: jax.ShapeDtypeStruct(
                local_shape(tuple(l.shape), _s, mesh), l.dtype), sub))
    return jax.tree_util.tree_unflatten(spec_def, mapped)


def shard_wrap(fn: Callable, mesh: Mesh, in_specs, out_specs,
               refresh_key: Callable[[], Any] | None = None) -> Callable:
    """Jit-memoized ``shard_map`` dispatch for a shard-local body.

    The wrapper is compiled once and reused; ``refresh_key`` (a zero-arg
    callable) identifies mutable state the body closes over — e.g. a
    compiled artifact that a background upgrade may swap — and a changed
    key rebuilds the wrapper so the new state is baked in.  This is the
    dispatch idiom every mesh-aware caller shares; keeping it here means no
    caller hand-writes shard_map construction."""
    state: dict[str, Any] = {"key": _UNSEEN, "fn": None}

    def dispatch(*args):
        key = refresh_key() if refresh_key is not None else None
        if state["fn"] is None or state["key"] is not key:
            state["fn"] = jax.jit(shard_map(
                fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=False))
            state["key"] = key
        return state["fn"](*args)

    return dispatch


class _Specialization:
    """One traced-and-compiled (graph, artifact) pair at fixed avals."""

    __slots__ = ("status", "graph", "names", "compiled", "out_tree",
                 "in_sig", "placement", "sig", "lookup_compiler",
                 "executable", "error", "warned", "sharded",
                 "sm_in_specs", "sm_out_specs", "sm_fn", "sm_for")

    def __init__(self):
        self.status: str | None = None
        self.graph = None
        self.names: list[str] | None = None
        self.compiled = None
        self.out_tree = None
        self.in_sig = None
        self.placement = ""
        self.sig = None
        self.lookup_compiler = None
        self.executable = False
        self.error: str | None = None
        self.warned = False
        self.sharded = False
        self.sm_in_specs = None
        self.sm_out_specs = None
        self.sm_fn = None
        self.sm_for = None

    @property
    def ok(self) -> bool:
        return self.graph is not None and self.executable \
            and self.compiled is not None


class StitchedFunction:
    """The callable :func:`stitch` returns — see the module docstring.

    Observability compatible with the pre-refactor phases: ``status``,
    ``graph``, ``compiled``, ``placement`` expose the active
    specialization; :meth:`report` aggregates call counts, plan stats,
    cache hit rates, and any background-compile failure.
    """

    def __init__(self, fn: Callable, *, mode: str = "stitch", service=None,
                 mesh: Mesh | None = None, in_specs=None, out_specs=None,
                 donate_argnums=(), static_argnums=(),
                 eligibility_argnums=None, respecialize: int = 0,
                 placement: str = "", name: str | None = None):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.fn = fn
        self.mode = mode
        self.name = name or getattr(fn, "__name__", "stitched")
        self.mesh = mesh if (mesh is not None and mesh.size > 1) else None
        self.static_argnums = tuple(sorted(set(static_argnums)))
        self.donate_argnums = tuple(sorted(set(donate_argnums)))
        # respecialize=N: a new input signature (shape/structure drift)
        # traces a NEW specialization instead of falling back to jit —
        # jit-like shape polymorphism through the fusion pipeline, bounded
        # at N live specializations (LRU eviction).  The serving engine's
        # bucketed prefill dispatch is the canonical user: each pow2 bucket
        # lands its own placement-keyed plan.
        self.respecialize = int(respecialize)
        if self.respecialize and mesh is not None:
            raise ValueError("respecialize is not supported together with "
                             "mesh dispatch")
        # args whose avals the per-call drift check covers (None = all).
        # Callers with an operand that is fixed for the function's lifetime
        # (e.g. the serving engine's params) exclude it so the hot-path
        # check stays O(small); excluded args are still traced normally.
        self.eligibility_argnums = (
            tuple(sorted(set(eligibility_argnums)))
            if eligibility_argnums is not None else None)
        if self.mesh is not None and self.static_argnums:
            raise ValueError("static_argnums is not supported together with "
                             "mesh dispatch")
        if self.mesh is not None and (in_specs is None or out_specs is None):
            raise ValueError("mesh dispatch requires in_specs and out_specs")
        if set(self.static_argnums) & set(self.donate_argnums):
            raise ValueError("an argument cannot be both static and donated")
        if mode != "jit" and service is None:
            from repro.cache import CompilationService
            service = CompilationService()
        self.service = service
        self.in_specs = in_specs
        self.out_specs = out_specs
        self._placement_override = placement
        self._specs: dict[Any, _Specialization] = {}
        self._active: _Specialization | None = None
        self._jit_plain = jax.jit(fn, static_argnums=self.static_argnums,
                                  donate_argnums=self.donate_argnums)
        self._jit_sharded: dict = {}     # (treedef, avals) -> jit(shard_map)
        # with respecialize, jit-served signatures get their OWN jit
        # instance in a same-cap LRU: evicting an entry drops its compiled
        # executable too, so jit/shadow modes are as bounded as stitch mode
        self._jit_lru: OrderedDict = OrderedDict()
        self.stitched_calls = 0          # served through the compiled artifact
        self.fallback_calls = 0          # drift / trace failure -> jit
        self.jit_calls = 0               # by-design jit ("jit"/"shadow" modes)
        # path -> measured-wall-clock Histogram, populated only while the
        # opt-in kernel timer (repro.obs.timer) is enabled
        self._measured: dict[str, obs.Histogram] = {}

    # -- argument plumbing -----------------------------------------------------
    def _split(self, args):
        statics = tuple(args[i] for i in self.static_argnums if i < len(args))
        dyn = tuple(a for i, a in enumerate(args)
                    if i not in self.static_argnums)
        return statics, dyn

    def _bind(self, statics):
        if not self.static_argnums:
            return self.fn
        at = dict(zip(self.static_argnums, statics))
        n_static = len(statics)

        def bound(*dyn, **kwargs):
            merged, di = [], iter(dyn)
            for i in range(len(dyn) + n_static):
                merged.append(at[i] if i in at else next(di))
            return self.fn(*merged, **kwargs)

        return bound

    def _in_sig(self, dyn, kwargs):
        if self.eligibility_argnums is not None:
            sel, di = [], 0
            for i in range(len(dyn) + len(self.static_argnums)):
                if i in self.static_argnums:
                    continue
                if i in self.eligibility_argnums:
                    sel.append(dyn[di])
                di += 1
            dyn = tuple(sel)
        return (jax.tree_util.tree_structure((dyn, kwargs)),
                _avals((dyn, kwargs)))

    # -- tracing ---------------------------------------------------------------
    def _trace(self, statics, dyn, kwargs) -> _Specialization:
        from repro.cache.signature import compute_signature, placement_key
        from repro.core.trace import trace_to_graph

        sp = _Specialization()
        sp.in_sig = self._in_sig(dyn, kwargs)
        sp.placement = self._placement_override
        if self.respecialize:
            # per-signature placement: each specialization (e.g. each pow2
            # prefill bucket) gets its own cache entry/plan even when the
            # bucket policy would coarsen their shapes together
            digest = hashlib.sha1(repr(sp.in_sig).encode()).hexdigest()[:8]
            base = self._placement_override or self.name
            sp.placement = f"{base}@{digest}"
        bound = self._bind(statics)
        tsp = obs.span("exec.trace", cat="exec", fn=self.name, mode=self.mode)
        tsp.__enter__()
        try:
            axis_env = None
            targs = ((dyn, kwargs),)

            def run_fn(packed):
                return bound(*packed[0], **packed[1])

            if self.mesh is not None:
                in_specs = _resolve(self.in_specs, dyn)
                if in_specs is not None:
                    if kwargs:
                        raise ValueError("kwargs unsupported on the sharded "
                                         "stitched path")
                    sp.sharded = True
                    sp.sm_in_specs = in_specs
                    sp.sm_out_specs = _resolve(self.out_specs, dyn)
                    sp.placement = placement_key(self.mesh, in_specs)
                    axis_env = [(a, self.mesh.shape[a])
                                for a in self.mesh.axis_names]
                    targs = tuple(_local_avals(dyn, in_specs, self.mesh))
                    run_fn = bound
            sp.graph, sp.names = trace_to_graph(
                run_fn, *targs, name=self.name, axis_env=axis_env)
            _, out_shape = jax.make_jaxpr(
                run_fn, axis_env=axis_env, return_shape=True)(*targs)
            sp.out_tree = jax.tree_util.tree_structure(out_shape)
            # duplicated outputs collapse in the graph: not executable, but
            # the plan still powers reporting / cache warmth
            sp.executable = sp.out_tree.num_leaves == len(sp.graph.outputs)
            if self.mode == "offline":
                sp.compiled = self.service.compile(
                    sp.graph, placement=sp.placement)
                sp.status = "compiled"
            else:
                sp.compiled, sp.status = self.service.compile_or_fallback(
                    sp.graph, placement=sp.placement)
            sp.sig = compute_signature(sp.graph)
            sp.lookup_compiler = self.service.compiler("stitch", sp.placement)
        except Exception as e:              # noqa: BLE001 — degrade to jit
            sp.status = "error"
            sp.error = f"{type(e).__name__}: {e}"
            sp.graph = None
            sp.compiled = None
            sp.executable = False
        finally:
            tsp.set(status=sp.status, placement=sp.placement)
            tsp.__exit__(None, None, None)
        return sp

    def _spec_key(self, statics, dyn, kwargs):
        if not self.respecialize:
            return statics
        return (statics, self._in_sig(dyn, kwargs))

    def _get(self, statics, dyn, kwargs) -> _Specialization:
        key = self._spec_key(statics, dyn, kwargs)
        sp = self._specs.get(key)
        if sp is None:
            sp = self._trace(statics, dyn, kwargs)
            self._specs[key] = sp
        elif self.respecialize:
            self._specs[key] = self._specs.pop(key)      # LRU touch
        while self.respecialize and len(self._specs) > self.respecialize:
            evicted = next(iter(self._specs))
            if evicted == key:                           # never evict current
                break
            del self._specs[evicted]
        self._active = sp
        return sp

    # -- miss-then-upgrade polling ---------------------------------------------
    def _poll(self, sp: _Specialization) -> None:
        if sp.status not in ("miss", "pending"):
            return
        svc = self.service
        hit = svc.cache.lookup(sp.graph, sp.lookup_compiler, sig=sp.sig,
                               count=False)
        if hit is not None:
            sp.compiled = hit
            sp.status = "hit"
            # the acceptance-critical marker: this call flips from the XLA
            # fallback to the stitched artifact mid-flight
            obs.event("exec.upgrade", cat="exec", fn=self.name,
                      placement=sp.placement,
                      n_kernels=hit.stats.n_kernels,
                      modeled_time_s=hit.stats.modeled_time)
            return
        err = svc.error_for(sp.sig, sp.placement)
        if err is not None:
            # the background stitch compile died: keep serving the fallback
            # artifact, stop re-kicking the doomed compile, and say so once
            sp.status = "failed"
            sp.error = err
            if not sp.warned:
                sp.warned = True
                warnings.warn(
                    f"background stitch compile for {self.name!r} failed; "
                    f"serving the fallback artifact permanently: {err}",
                    RuntimeWarning, stacklevel=4)
            return
        # re-kick if the background compile was deferred (worker cap): a
        # long-lived function must not serve the fallback forever
        svc.ensure_compiling(sp.graph, sig=sp.sig, placement=sp.placement)

    def poll_upgrade(self) -> None:
        """Poll the active specialization's background upgrade (also done
        automatically on every call)."""
        if self._active is not None and self.mode not in ("jit", "offline"):
            self._poll(self._active)

    # -- execution -------------------------------------------------------------
    def _run(self, sp: _Specialization, dyn, kwargs):
        if sp.sharded:
            if sp.sm_for is not sp.compiled:
                compiled, graph = sp.compiled, sp.graph
                names, out_tree = sp.names, sp.out_tree

                def body(*local_args):
                    env = dict(zip(names,
                                   jax.tree_util.tree_leaves(local_args)))
                    outs = compiled(env)
                    flat = [outs[o] for o in graph.outputs]
                    return jax.tree_util.tree_unflatten(out_tree, flat)

                # memoized per artifact: steady state is a jit-cache hit,
                # an upgrade swap rebuilds once
                sp.sm_fn = jax.jit(shard_map(
                    body, mesh=self.mesh, in_specs=sp.sm_in_specs,
                    out_specs=sp.sm_out_specs, check_rep=False))
                sp.sm_for = sp.compiled
            return sp.sm_fn(*dyn)
        env = dict(zip(sp.names, jax.tree_util.tree_leaves((dyn, kwargs))))
        outs = sp.compiled(env)
        flat = [outs[o] for o in sp.graph.outputs]
        return jax.tree_util.tree_unflatten(sp.out_tree, flat)

    def _jit_call(self, args, dyn, kwargs):
        if self.respecialize and self.mesh is None:
            key = self._in_sig(dyn, kwargs)
            fn = self._jit_lru.get(key)
            if fn is None:
                fn = jax.jit(self.fn, static_argnums=self.static_argnums,
                             donate_argnums=self.donate_argnums)
            else:
                self._jit_lru.pop(key)               # LRU touch
            self._jit_lru[key] = fn
            while len(self._jit_lru) > self.respecialize:
                self._jit_lru.popitem(last=False)
            return fn(*args, **kwargs)
        if self.mesh is not None and not kwargs:
            # signature-keyed memo holds the shardable/unshardable decision
            # too, so the spec callable (a pytree walk) runs once per
            # signature, not once per call.  The key is the eligibility
            # signature: args excluded there are lifetime-fixed by contract
            # (and the inner jit re-specializes on concrete shapes anyway).
            key = self._in_sig(dyn, {})
            fn = self._jit_sharded.get(key, _UNSEEN)
            if fn is _UNSEEN:
                if len(self._jit_sharded) >= 64:   # long-lived servers with
                    self._jit_sharded.clear()      # ever-new extra shapes
                specs = _resolve(self.in_specs, dyn)
                fn = None if specs is None else jax.jit(shard_map(
                    self.fn, mesh=self.mesh, in_specs=specs,
                    out_specs=_resolve(self.out_specs, dyn),
                    check_rep=False))
                self._jit_sharded[key] = fn
            if fn is not None:
                return fn(*dyn)
        return self._jit_plain(*args, **kwargs)

    def _donate(self, args, out) -> None:
        """Stitched analogue of the jit path's ``donate_argnums``: free the
        consumed operands once the artifact has been dispatched.  Leaves
        aliased by the outputs (a passthrough input) are kept — jit's
        donation aliases them safely, so deleting would corrupt the
        result."""
        keep = {id(l) for l in jax.tree_util.tree_leaves(out)
                if isinstance(l, jax.Array)}
        for i in self.donate_argnums:
            if i < len(args):
                for leaf in jax.tree_util.tree_leaves(args[i]):
                    if (isinstance(leaf, jax.Array) and id(leaf) not in keep
                            and not leaf.is_deleted()):
                        leaf.delete()

    def _observe(self, path: str, fn, *call_args):
        """Span + opt-in measured timer around one served call.  Both
        tracer and timer off (the default) is a two-attribute-read
        passthrough, so the serving hot path pays nothing unobserved."""
        if not (obs.tracer.enabled or _ktimer.enabled):
            return fn(*call_args)
        with obs.span(f"exec.{self.name}", cat="exec", path=path):
            if not _ktimer.enabled:
                return fn(*call_args)
            t0 = time.perf_counter()
            out = fn(*call_args)
            # bracket device execution, not just async dispatch
            jax.block_until_ready(out)
            self._record_measured(path, time.perf_counter() - t0)
            return out

    def _record_measured(self, path: str, measured_s: float) -> None:
        h = self._measured.get(path)
        if h is None:
            h = self._measured[path] = obs.Histogram()
        h.observe(measured_s)
        sp = self._active
        modeled = None
        if path == "stitched" and sp is not None and sp.compiled is not None:
            modeled = sp.compiled.stats.modeled_time
        _ktimer.record(self.name, path, measured_s, modeled_s=modeled,
                       placement=sp.placement if sp is not None else "")

    def __call__(self, *args, **kwargs):
        statics, dyn = self._split(args)
        if self.mode == "jit":
            self.jit_calls += 1
            return self._observe("jit", self._jit_call, args, dyn, kwargs)
        sp = self._get(statics, dyn, kwargs)
        if not sp.ok or sp.in_sig != self._in_sig(dyn, kwargs):
            self.fallback_calls += 1
            return self._observe("fallback", self._jit_call, args, dyn, kwargs)
        if self.mode != "offline":
            self._poll(sp)
        if self.mode == "shadow":
            self.jit_calls += 1
            return self._observe("jit", self._jit_call, args, dyn, kwargs)
        out = self._observe("stitched", self._run, sp, dyn, kwargs)
        self.stitched_calls += 1
        if self.donate_argnums:
            self._donate(args, out)
        return out

    # -- introspection ---------------------------------------------------------
    def warmup(self, *args, **kwargs) -> str | None:
        """Trace and compile (or fetch the fallback) at these example
        arguments — ShapeDtypeStructs are fine — without executing.
        Returns the resulting status."""
        statics, dyn = self._split(args)
        if self.mode == "jit":
            return None
        return self._get(statics, dyn, kwargs).status

    def eligible(self, *args, **kwargs) -> bool:
        """True when a call with these arguments would execute through the
        compiled artifact (already traced, executable, signature match)."""
        statics, dyn = self._split(args)
        sp = self._specs.get(self._spec_key(statics, dyn, kwargs))
        return (sp is not None and sp.ok
                and sp.in_sig == self._in_sig(dyn, kwargs))

    @property
    def ok(self) -> bool:
        return self._active is not None and self._active.ok

    @property
    def status(self) -> str | None:
        return self._active.status if self._active is not None else None

    @property
    def graph(self):
        return self._active.graph if self._active is not None else None

    @property
    def compiled(self):
        return self._active.compiled if self._active is not None else None

    @property
    def placement(self) -> str:
        return self._active.placement if self._active is not None else ""

    def plan_stats(self) -> dict | None:
        if self._active is None or self._active.compiled is None:
            return None
        return self._plan_stats(self._active)

    @staticmethod
    def _plan_stats(sp: _Specialization) -> dict | None:
        if sp.compiled is None:
            return None
        s = sp.compiled.stats
        return {"mode": s.mode, "n_kernels": s.n_kernels, "n_ops": s.n_ops,
                "pallas_groups": s.pallas_groups,
                "packs": getattr(s, "packs", 0),
                "packed_subgraphs": getattr(s, "packed_subgraphs", 0),
                "modeled_time": s.modeled_time,
                "cache_status": s.cache_status,
                "verify": getattr(s, "verify", None)}

    def land_plans(self, timeout: float | None = None) -> int:
        """Join background compiles and poll EVERY specialization's upgrade
        (``_poll`` only tracks the active one) until no compile is in
        flight; returns how many specializations still lack a stitched
        plan.  Benches and tests use this to read deterministic per-bucket
        kernel counts out of :meth:`report`."""
        if self.mode in ("jit", "offline") or self.service is None:
            return 0
        for _ in range(1 + len(self._specs)):
            pending = 0
            for sp in self._specs.values():
                self._poll(sp)
                if sp.status in ("miss", "pending"):
                    pending += 1
            if not pending:
                break
            self.service.wait(timeout)
        return sum(sp.status in ("miss", "pending", "failed", "error")
                   for sp in self._specs.values())

    def report(self) -> dict:
        """Call routing, plan + kernel stats, cache hit rates, every
        background-compile failure, and measured kernel timing — one dict
        conforming to :data:`repro.obs.EXEC_REPORT_SCHEMA` (see
        :mod:`repro.obs.report` for the documented key table)."""
        out: dict[str, Any] = {
            "schema": obs.EXEC_REPORT_SCHEMA,
            "name": self.name,
            "status": self.status,
            "mode": self.mode,
            "calls": {"stitched": self.stitched_calls,
                      "fallback": self.fallback_calls,
                      "jit": self.jit_calls},
            # compatibility aliases — prefer ``calls``
            "stitched_calls": self.stitched_calls,
            "fallback_calls": self.fallback_calls,
            "jit_calls": self.jit_calls,
            "specializations": len(self._specs),
            "specialization_cap": self.respecialize or None,
            "jit_specializations": len(self._jit_lru),
            "placement": (self._active.placement
                          if self._active is not None else ""),
            "plan": self.plan_stats(),
            # per-specialization plans (placement-keyed) — with
            # ``respecialize`` each shape bucket reports its own
            "plans": {sp.placement: {"status": sp.status,
                                     "plan": self._plan_stats(sp)}
                      for sp in self._specs.values()},
            "error": (self._active.error
                      if self._active is not None else None),
            "errors": {},
            # structured StitchInfeasible records from tuning: why chosen
            # patterns degraded to fused-jnp (see core.tuner._diagnostic)
            "diagnostics": (list(self._active.compiled.stats.diagnostics)
                            if self._active is not None
                            and self._active.compiled is not None else []),
            "cache": None,
            "service_error": None,
            "measured": ({p: h.summary()
                          for p, h in sorted(self._measured.items())}
                         if self._measured else None),
        }
        if self.service is not None:
            out["cache"] = self.service.cache.report()
            out["service_error"] = self.service.last_error
            out["errors"] = self.service.error_report()
        return out

    def wait(self, timeout: float | None = None) -> None:
        """Join in-flight background compiles (tests / orderly shutdown)."""
        if self.service is not None:
            self.service.wait(timeout)


def stitch(fn: Callable, *, mode: str = "stitch", service=None,
           mesh: Mesh | None = None, in_specs=None, out_specs=None,
           donate_argnums=(), static_argnums=(), eligibility_argnums=None,
           respecialize: int = 0, placement: str = "",
           name: str | None = None) -> StitchedFunction:
    """Wrap ``fn`` for execution through the FusionStitching pipeline —
    the jit-like public entry point of the repo.

    Args:
      fn: any JAX-traceable function of pytree args/kwargs.
      mode: ``"stitch"`` (execute stitched, miss-then-upgrade),
        ``"shadow"`` (compile + report, serve jit), ``"offline"``
        (blocking compile at first call), ``"jit"`` (no stitching).
      service: :class:`repro.cache.CompilationService`; a default
        (in-memory cache) is created when omitted and mode needs one.
      mesh / in_specs / out_specs: ``shard_map`` dispatch — specs may be
        values or ``callable(*args)`` returning specs (``None`` =
        unshardable signature, plain jit).  Collectives inside ``fn``
        trace via ``axis_env``.  Plans cache under a mesh+spec placement.
      donate_argnums: consumed args: donated on the jit path, deleted
        after dispatch on the stitched path.
      static_argnums: hashable args baked into the trace; a new value
        retraces into a new specialization.
      eligibility_argnums: restrict the per-call shape-drift check to these
        args (default all) — for operands fixed over the function's
        lifetime, keeping the hot-path check cheap.
      respecialize: N > 0 makes a drifted input signature trace a NEW
        specialization (own graph, own placement-keyed plan) instead of
        serving through jit — jit-like shape polymorphism, LRU-bounded at N
        live specializations.  The serving engine routes its pow2-bucketed
        prefills through this.  Incompatible with ``mesh``.
      placement: explicit cache-placement override for bodies that run
        inside someone else's ``shard_map`` (e.g. the packed optimizer).
      name: graph name for dumps, cache records, and warnings.

    Returns a :class:`StitchedFunction`.
    """
    return StitchedFunction(
        fn, mode=mode, service=service, mesh=mesh, in_specs=in_specs,
        out_specs=out_specs, donate_argnums=donate_argnums,
        static_argnums=static_argnums, eligibility_argnums=eligibility_argnums,
        respecialize=respecialize, placement=placement, name=name)
