"""repro.exec — the one stitched-execution layer.

:func:`stitch` is the public, jit-like transform: wrap any JAX function and
it executes through the FusionStitching pipeline (trace -> cached fusion
plan -> stitched kernels) with miss-then-upgrade compilation, single-device
or ``shard_map`` dispatch, and jit fallback on trace failure or shape drift.
Training, serving, and the packed optimizer are all built on it.
"""

from .function import StitchedFunction, shard_wrap, stitch, tree_avals

__all__ = ["StitchedFunction", "shard_wrap", "stitch", "tree_avals"]
