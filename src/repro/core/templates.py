"""Implementation templates (paper §5.2) — TPU dialect.

The paper's template is a compact schedule description per op::

    reduce_1[GRID,WARP,WARP,CTA]S; mul_1[GRID,CTA];

On TPU there are no warps or CTAs; the corresponding parallelization levels
of a Pallas kernel are the sequential *grid*, the 8-row *sublane* dimension
and the 128-wide *lane* dimension of the VPU tile, plus a purely sequential
in-kernel loop.  The storage attribute generalizes the paper's ``S``:

    GPU attr   TPU attr    meaning
    --------   ---------   ------------------------------------------------
    GRID       GRID        dimension mapped to the pallas grid (outer loop)
    WARP       SUBLANE     dimension mapped to VPU sublanes (8)
    CTA        LANE        dimension mapped to VPU lanes (128)
    THREAD     SEQ         sequential within the kernel body (no parallelism)
    S          S           keep result in on-chip scratch: VMEM (block comp.)
    (default)  (default)   result stays in VREG (thread composition) or HBM
                           (pattern output)

Multi-level tiling is kept: ``GRID_128-SUBLANE_2`` splits one dimension into
a grid component of 128 tiles with 2 sublane-parallel sub-tiles, exactly the
paper's ``GRID_128-WARP_2``.

The grammar below is the paper's, re-terminalized::

    template      := schedule+
    schedule      := ident '[' attr-list ']' storage? ';'
    attr-list     := attr (',' attr)*
    attr          := subattr ('-' subattr)*
    subattr       := ATTRTYPE ('_' INT)?
    ATTRTYPE      := 'GRID' | 'SUBLANE' | 'LANE' | 'SEQ'
    storage       := 'S'

Templates are *value objects*: parse -> :class:`Template`, print -> the same
string.  The tuner (Alg. 3) enumerates them; the emitter consumes them.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["Attr", "SubAttr", "Schedule", "Template", "parse_template",
           "ATTR_TYPES", "GPU_TO_TPU_ATTR"]

ATTR_TYPES = ("GRID", "SUBLANE", "LANE", "SEQ")
GPU_TO_TPU_ATTR = {"GRID": "GRID", "WARP": "SUBLANE", "CTA": "LANE", "THREAD": "SEQ"}


@dataclass(frozen=True)
class SubAttr:
    kind: str                 # one of ATTR_TYPES
    factor: int | None = None  # tiling factor, e.g. GRID_128

    def __post_init__(self):
        if self.kind not in ATTR_TYPES:
            raise ValueError(f"unknown attr type {self.kind!r}")

    def __str__(self) -> str:
        return self.kind if self.factor is None else f"{self.kind}_{self.factor}"


@dataclass(frozen=True)
class Attr:
    """Per-dimension (possibly multi-level) tiling spec."""
    levels: tuple[SubAttr, ...]

    def __str__(self) -> str:
        return "-".join(str(l) for l in self.levels)

    @property
    def primary(self) -> str:
        return self.levels[0].kind


@dataclass(frozen=True)
class Schedule:
    op: str
    attrs: tuple[Attr, ...]
    scratch: bool = False     # the paper's S attribute -> VMEM scratch

    def __str__(self) -> str:
        body = ",".join(str(a) for a in self.attrs)
        return f"{self.op}[{body}]{'S' if self.scratch else ''};"

    def dims_with(self, kind: str) -> list[int]:
        return [i for i, a in enumerate(self.attrs) if any(l.kind == kind for l in a.levels)]


@dataclass(frozen=True)
class Template:
    schedules: tuple[Schedule, ...]

    def __str__(self) -> str:
        return " ".join(str(s) for s in self.schedules)

    def __iter__(self):
        return iter(self.schedules)

    def schedule_for(self, op: str) -> Schedule | None:
        for s in self.schedules:
            if s.op == op:
                return s
        return None

    @property
    def scratch_ops(self) -> list[str]:
        return [s.op for s in self.schedules if s.scratch]


_SCHED_RE = re.compile(
    r"\s*(?P<op>[A-Za-z_][\w.]*)\s*\[(?P<attrs>[^\]]*)\]\s*(?P<S>S)?\s*;"
)


def _parse_attr(text: str) -> Attr:
    levels = []
    for part in text.strip().split("-"):
        m = re.fullmatch(r"([A-Za-z]+)(?:_(\d+))?", part.strip())
        if not m:
            raise ValueError(f"bad attr {part!r}")
        kind = m.group(1).upper()
        kind = GPU_TO_TPU_ATTR.get(kind, kind)  # accept the paper's spelling
        levels.append(SubAttr(kind, int(m.group(2)) if m.group(2) else None))
    return Attr(tuple(levels))


def parse_template(text: str) -> Template:
    """Parse a template string (accepts both GPU and TPU attr spellings)."""
    schedules = []
    pos = 0
    for m in _SCHED_RE.finditer(text):
        if text[pos:m.start()].strip():
            raise ValueError(f"garbage in template: {text[pos:m.start()]!r}")
        attrs = tuple(
            _parse_attr(a) for a in m.group("attrs").split(",") if a.strip()
        )
        schedules.append(Schedule(m.group("op"), attrs, m.group("S") is not None))
        pos = m.end()
    if text[pos:].strip():
        raise ValueError(f"trailing garbage in template: {text[pos:]!r}")
    if not schedules:
        raise ValueError("empty template")
    return Template(tuple(schedules))
