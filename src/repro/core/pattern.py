"""Fusion patterns — candidate subgraphs the ILP chooses among (paper §4.1).

A :class:`FusionPattern` is an immutable set of node names of one graph plus
cached facts the cost model and ILP need: external I/O tensors, internal
(saved) bytes, the paper's three-way classification (elemwise / reduction /
gemm, §6.4), and whether contracting it keeps the graph acyclic.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property
from typing import Iterable

from .ir import Graph, OpKind, OpNode, ReduceKind

__all__ = ["FusionPattern", "PackPattern", "PatternClass",
           "contraction_creates_cycle"]


class PatternClass:
    ELEMWISE = "elemwise"
    REDUCTION = "reduction"
    GEMM = "gemm"


@dataclass(frozen=True)
class FusionPattern:
    graph: Graph = field(compare=False, repr=False)
    members: frozenset[str] = field(compare=True)
    origin: str = "manual"  # "substitution" | "exploratory" | "manual"

    def __post_init__(self):
        if not self.members:
            raise ValueError("empty fusion pattern")
        for m in self.members:
            if m not in self.graph:
                raise ValueError(f"pattern member {m!r} not in graph")

    # -- cached structural facts --------------------------------------------
    @cached_property
    def nodes(self) -> list[OpNode]:
        order = [n for n in self.graph.topo_order() if n in self.members]
        return [self.graph[n] for n in order]

    @cached_property
    def compute_members(self) -> list[OpNode]:
        return [n for n in self.nodes if not n.is_source() and n.kind is not OpKind.TUPLE]

    @cached_property
    def external_inputs(self) -> list[str]:
        return self.graph.external_inputs(self.members)

    @cached_property
    def external_outputs(self) -> list[str]:
        return self.graph.external_outputs(self.members)

    @cached_property
    def input_bytes(self) -> int:
        return sum(self.graph[n].bytes for n in self.external_inputs)

    @cached_property
    def output_bytes(self) -> int:
        return sum(self.graph[n].bytes for n in self.external_outputs)

    @cached_property
    def saved_bytes(self) -> int:
        """Off-chip traffic eliminated by this fusion: every internal
        intermediate is a write+read (2x bytes) that no longer touches HBM."""
        return 2 * self.graph.internal_edges_bytes(self.members)

    @cached_property
    def pattern_class(self) -> str:
        """Paper §6.4: gemm > reduction > elemwise precedence.  Stitchable
        CUSTOM kernels (flash attention etc.) are compute-bearing, so they
        classify with the GEMMs."""
        kinds = {n.kind for n in self.nodes}
        if kinds & {OpKind.GEMM, OpKind.BATCHED_GEMM, OpKind.CUSTOM}:
            return PatternClass.GEMM
        if OpKind.REDUCTION in kinds:
            return PatternClass.REDUCTION
        return PatternClass.ELEMWISE

    @cached_property
    def custom_members(self) -> tuple[str, ...]:
        """CUSTOM member names (registered or not), projections included."""
        return tuple(
            n.name for n in self.nodes if n.kind is OpKind.CUSTOM
        )

    @cached_property
    def reduce_kinds(self) -> set[ReduceKind]:
        return {n.reduce_kind for n in self.nodes if n.kind is OpKind.REDUCTION}

    @cached_property
    def has_data_dependences(self) -> bool:
        """False for pure packing patterns (no member feeds another member)."""
        return any(
            any(o in self.members for o in n.operands) for n in self.nodes
        )

    def overlaps(self, other: "FusionPattern") -> bool:
        return bool(self.members & other.members)

    def creates_cycle(self) -> bool:
        return contraction_creates_cycle(self.graph, self.members)

    def key(self) -> frozenset[str]:
        return self.members

    def __len__(self) -> int:
        return len(self.members)

    def __repr__(self) -> str:  # compact, deterministic
        names = ",".join(sorted(self.members)[:6])
        more = f",+{len(self.members)-6}" if len(self.members) > 6 else ""
        return f"FusionPattern[{self.pattern_class}]({names}{more})"


@dataclass(frozen=True)
class PackPattern(FusionPattern):
    """A *horizontal* pattern: the union of several mutually independent
    member subgraphs packed into one kernel (paper §4.2's independent-op
    packing).  ``member_groups`` records the provenance — which nodes came
    from which packed subgraph — so the plan verifier can re-check pack
    legality (disjoint groups, no cross-group dependence) and ``report()``
    can surface pack statistics.  Everything else (cost, ILP exclusivity,
    emission) treats the pack as an ordinary pattern over ``members``."""

    member_groups: tuple[frozenset[str], ...] = field(
        default=(), compare=False)

    def __post_init__(self):
        super().__post_init__()
        if len(self.member_groups) < 2:
            raise ValueError("pack needs >= 2 member subgraphs")
        seen: set[str] = set()
        union: set[str] = set()
        for grp in self.member_groups:
            if not grp:
                raise ValueError("empty pack member subgraph")
            if grp & seen:
                raise ValueError(
                    f"pack member subgraphs overlap on {sorted(grp & seen)[:4]}")
            seen |= grp
            union |= grp
        if union != set(self.members):
            raise ValueError("pack member subgraphs do not cover the pattern")

    @cached_property
    def cross_group_edges(self) -> list[tuple[str, str]]:
        """(producer, consumer) pairs crossing two member subgraphs — must be
        empty for a legal pack (the subgraphs are independent by
        construction; the verifier re-checks via this property)."""
        owner: dict[str, int] = {}
        for i, grp in enumerate(self.member_groups):
            for m in grp:
                owner[m] = i
        bad: list[tuple[str, str]] = []
        for n in self.nodes:
            for o in n.operands:
                if o in owner and owner[o] != owner[n.name]:
                    bad.append((o, n.name))
        return bad

    def __repr__(self) -> str:
        return (f"PackPattern[{self.pattern_class}]"
                f"({len(self.member_groups)}x{len(self.members)//max(len(self.member_groups),1)})")


def contraction_creates_cycle(graph: Graph, members: Iterable[str]) -> bool:
    """True iff contracting `members` to a single node creates a cycle, i.e.
    there is a path  member -> (outside nodes) -> member.

    We BFS forward from the out-frontier of the member set through non-member
    nodes only; reaching any member again means a cycle (Fig. 3 in the paper).
    """
    mset = set(members)
    frontier: list[str] = []
    for m in mset:
        for u in graph.users(m):
            if u not in mset:
                frontier.append(u)
    seen = set(frontier)
    while frontier:
        cur = frontier.pop()
        if cur in mset:
            return True
        for u in graph.users(cur):
            if u in mset:
                return True
            if u not in seen:
                seen.add(u)
                frontier.append(u)
    return False
