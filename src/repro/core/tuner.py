"""Kernel generation & tuning — the paper's Alg. 3.

For a fusion pattern: enumerate implementation templates (different
parallelization / scratch / launch trade-offs), run RegisterPlanning and
SharedPlanning (volume + layout constraints; Alg. 4 reuse), generate the
kernel per schedule kind, evaluate, keep the best.

Evaluation is model-based by default (fast, the paper's JIT story) and
execution-based on request (times the interpret-mode kernel — the "optimize
once, run many times" offline path).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

from .cost import CostModel, HardwareModel, TPU_V5E
from .ir import Graph, OpKind
from .pattern import FusionPattern
from .scratch import ScratchAllocator, ScratchPlan
from .templates import Attr, Schedule, SubAttr, Template

__all__ = ["TunedKernel", "TemplateTuner", "generate_templates", "grid_row_block"]


def grid_row_block(template: Template) -> int | None:
    """The GRID tiling factor a template was tuned with (None if unfactored)."""
    rb = None
    for s in template:
        for a in s.attrs:
            for lvl in a.levels:
                if lvl.kind == "GRID" and lvl.factor:
                    rb = lvl.factor
    return rb


@dataclass
class TunedKernel:
    pattern: FusionPattern
    template: Template
    scratch_plan: ScratchPlan
    modeled_time: float
    measured_time: float | None = None
    backend: str = "pallas"     # "pallas" | "jnp" (fallback)
    callable: Callable | None = field(default=None, repr=False)


def _attrs_for_node(node, row_block: int, seq_small_reduce: bool) -> tuple[Attr, ...]:
    """Default per-dimension tiling spec: rows -> GRID_<rb>, then trailing
    dims map minor-most to LANE, second-minor to SUBLANE, others SEQ."""
    rank = max(len(node.shape), 1)
    attrs: list[Attr] = []
    for d in range(rank):
        if d == 0:
            attrs.append(Attr((SubAttr("GRID", row_block),)))
        elif d == rank - 1:
            if (
                seq_small_reduce
                and node.kind is OpKind.REDUCTION
                and node.shape
                and node.shape[-1] < 128
            ):
                attrs.append(Attr((SubAttr("SEQ"),)))
            else:
                attrs.append(Attr((SubAttr("LANE"),)))
        elif d == rank - 2:
            attrs.append(Attr((SubAttr("SUBLANE"),)))
        else:
            attrs.append(Attr((SubAttr("SEQ"),)))
    return tuple(attrs)


def _diagnostic(p: FusionPattern, stage: str, err: Exception) -> dict:
    """Structured record of one StitchInfeasible: which pattern, at which
    tuning stage, and the human-readable reason — surfaced through
    ``FusionStats.diagnostics`` / ``report()["diagnostics"]`` instead of
    being silently swallowed into a fused-jnp fallback."""
    members = sorted(n.name for n in p.compute_members)
    return {
        "stage": stage,                  # "analyze" | "build" | "measure"
        "pattern_class": p.pattern_class,
        "members": members[:8],
        "n_members": len(members),
        "reason": str(err),
    }


def _note_diagnostic(diagnostics: list | None, p: FusionPattern, stage: str,
                     err: Exception, bound: int = 256) -> None:
    from repro import obs

    d = _diagnostic(p, stage, err)
    obs.event("tune.infeasible", cat="compile", **d)
    if diagnostics is None:
        return
    diagnostics.append(d)
    if len(diagnostics) > bound:
        del diagnostics[: len(diagnostics) - bound]


def generate_templates(
    p: FusionPattern, cost: CostModel, max_templates: int = 12,
    diagnostics: list | None = None,
) -> list[Template]:
    """TemplatesGeneration: row-block sweep x scratch-storage choice.

    Scratch choice: heavy-crossing intermediates (the cost model's
    scratch_request set) either all go to VMEM (block composition) or stay in
    VREG (thread composition) when small enough; both variants are emitted so
    KernelEvalUpdate can pick.  An infeasible pattern yields no templates;
    when ``diagnostics`` is given the reason is appended to it.
    """
    from repro.kernels.stitched import StitchInfeasible, analyze_pattern

    try:
        ana = analyze_pattern(p)
    except StitchInfeasible as err:
        _note_diagnostic(diagnostics, p, "analyze", err)
        return []
    req = cost.scratch_request(p)
    templates: list[Template] = []
    scratch_variants = [tuple(sorted(req))] if req else [()]
    if req:
        scratch_variants.append(())  # VREG-only variant
    for rb in ana.feasible_blocks:
        for scratch in scratch_variants:
            scheds = []
            for node in p.compute_members:
                scheds.append(
                    Schedule(
                        node.name,
                        _attrs_for_node(node, rb, seq_small_reduce=False),
                        scratch=node.name in scratch,
                    )
                )
            templates.append(Template(tuple(scheds)))
            if len(templates) >= max_templates:
                return templates
    return templates


class TemplateTuner:
    """Alg. 3 driver."""

    # keep the diagnostics log bounded: a long-lived serving process tunes
    # many graphs and only the recent tail is useful for debugging
    MAX_DIAGNOSTICS = 256

    def __init__(self, hw: HardwareModel = TPU_V5E, execution_based: bool = False):
        self.hw = hw
        self.cost = CostModel(hw)
        self.execution_based = execution_based
        # structured StitchInfeasible records (see _diagnostic); the compiler
        # snapshots the slice produced by each graph's tuning run into
        # FusionStats.diagnostics
        self.diagnostics: list[dict] = []
        # ScratchAllocator builds a whole-graph post-dominator tree; reuse it
        # across the many (pattern, template) pairs of one graph's tuning run.
        # Keyed by graph identity, invalidated when the graph grows OR its
        # outputs change (mark_output moves the virtual post-dominance sink).
        self._allocators: dict[int, tuple[ScratchAllocator, int, tuple]] = {}

    def _note_infeasible(self, p: FusionPattern, stage: str, err: Exception) -> None:
        _note_diagnostic(self.diagnostics, p, stage, err,
                         bound=self.MAX_DIAGNOSTICS)

    def _allocator(self, g) -> ScratchAllocator:
        hit = self._allocators.get(id(g))
        if (hit is not None and hit[0].g is g and hit[1] == len(g.nodes)
                and hit[2] == tuple(g.outputs)):
            return hit[0]
        if len(self._allocators) > 8:
            self._allocators.clear()
        alloc = ScratchAllocator(g)
        self._allocators[id(g)] = (alloc, len(g.nodes), tuple(g.outputs))
        return alloc

    # -- SharedPlanning -------------------------------------------------------
    def shared_planning(self, p: FusionPattern, template: Template) -> ScratchPlan | None:
        req_all = self.cost.scratch_request(p)
        req = {k: v for k, v in req_all.items() if k in set(template.scratch_ops)}
        plan = self._allocator(p.graph).allocate(req)
        # registered custom-kernel bodies allocate their own scratch inside
        # the composed kernel; it shares the same on-chip volume
        if plan.allocated + self.cost.custom_scratch(p) > self.hw.onchip_budget:
            return None
        return plan

    # -- abstract validation --------------------------------------------------
    def validate(self, p: FusionPattern, fn: Callable) -> bool:
        """Trace the stitched kernel abstractly and check its output avals.

        ``pallas_call`` only traces the kernel body on first *call*, so an
        analysis soundness gap (wild graphs: traced backward passes) would
        otherwise surface as a TypeError at execution time deep inside a
        compiled artifact.  eval_shape catches the whole class at tune time;
        a failing candidate is discarded (callers fall back to fused-jnp,
        numerics unaffected)."""
        import jax
        import jax.numpy as jnp

        from .codegen import canonical_dtype

        g = p.graph
        try:
            args = [
                jax.ShapeDtypeStruct(g[i].shape, canonical_dtype(g[i].dtype))
                for i in p.external_inputs
            ]
            outs = jax.eval_shape(fn, *args)
            for name, o in zip(p.external_outputs, outs):
                if tuple(o.shape) != tuple(g[name].shape):
                    return False
                if jnp.dtype(o.dtype) != canonical_dtype(g[name].dtype):
                    return False
        except Exception:
            return False
        return True

    # -- KernelEvalUpdate -----------------------------------------------------
    def _measure(self, fn: Callable, args: list, repeats: int = 3) -> float:
        fn(*args)  # warmup (trace+compile)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn(*args)
            import jax

            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best

    def tune(self, p: FusionPattern, sample_inputs: list | None = None) -> TunedKernel | None:
        from repro.kernels.stitched import StitchInfeasible, build_stitched_callable

        templates = generate_templates(p, self.cost,
                                       diagnostics=self.diagnostics)
        candidates: list[tuple[float, int, TunedKernel]] = []
        for i, template in enumerate(templates):
            plan = self.shared_planning(p, template)
            if plan is None:
                continue  # infeasible template (paper: skip)
            rb = grid_row_block(template)
            try:
                fn = build_stitched_callable(
                    p, row_block=rb, scratch_ops=template.scratch_ops
                )
            except StitchInfeasible as err:
                self._note_infeasible(p, "build", err)
                continue
            modeled = self.cost.fused_time(p)
            # tiny grid-utilization nudge: prefer sublane-aligned row blocks
            if rb and rb % 8:
                modeled *= 1.05
            measured = None
            if self.execution_based and sample_inputs is not None:
                try:
                    measured = self._measure(fn, sample_inputs)
                except Exception as err:
                    self._note_infeasible(p, "measure", err)
                    continue
            cand = TunedKernel(p, template, plan, modeled, measured, "pallas", fn)
            key = measured if measured is not None else modeled
            candidates.append((key, i, cand))
        # best candidate first; abstract validation runs once per pattern in
        # the common case and only walks down on analysis soundness gaps
        for _key, _i, cand in sorted(candidates, key=lambda t: (t[0], t[1])):
            if self.validate(p, cand.callable):
                return cand
        return None

    # -- plan replay (cache hits) --------------------------------------------
    def instantiate(
        self,
        p: FusionPattern,
        row_block: int | None = None,
        scratch_names=(),
    ) -> TunedKernel | None:
        """Build ONE kernel from a previously tuned ``(row_block, scratch)``
        choice, skipping template enumeration and candidate evaluation.

        This is the warm path of :mod:`repro.cache`: the stored choice is
        re-validated against this pattern's concrete shapes (row blocks are
        clamped to the feasible set; scratch must fit the on-chip budget),
        so a plan recorded at a nearby bucketed shape still instantiates
        soundly or falls back to fused-jnp (return None).
        """
        from repro.kernels.stitched import (
            StitchInfeasible, analyze_pattern, build_stitched_callable)

        try:
            ana = analyze_pattern(p)
        except StitchInfeasible as err:
            self._note_infeasible(p, "analyze", err)
            return None
        rb = row_block or ana.feasible_blocks[0]
        if rb not in ana.feasible_blocks:
            rb = max((b for b in ana.feasible_blocks if b <= rb),
                     default=ana.feasible_blocks[0])
        member_names = {n.name for n in p.compute_members}
        scratch = {n for n in scratch_names if n in member_names}
        template = Template(tuple(
            Schedule(
                node.name,
                _attrs_for_node(node, rb, seq_small_reduce=False),
                scratch=node.name in scratch,
            )
            for node in p.compute_members
        ))
        plan = self.shared_planning(p, template)
        if plan is None:
            return None
        try:
            fn = build_stitched_callable(
                p, row_block=rb, scratch_ops=template.scratch_ops)
        except StitchInfeasible as err:
            self._note_infeasible(p, "build", err)
            return None
        if not self.validate(p, fn):
            return None
        return TunedKernel(p, template, plan, self.cost.fused_time(p), None,
                           "pallas", fn)
