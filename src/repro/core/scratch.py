"""Scratch-space (VMEM) optimization — the paper's Alg. 4.

Goal (paper §5.4): bound *worst-case* on-chip usage of a fused kernel by
letting later ops reuse scratch buffers whose values are provably dead.  The
paper diverts the classic dominance-tree algorithm (Cooper-Harvey-Kennedy)
from control-flow graphs to the dataflow DAG.

Soundness note (also recorded in DESIGN.md): on a dataflow DAG rooted at a
virtual sink collecting all outputs, *post-dominance* is the relation that
makes reuse sound — if ``inst`` post-dominates ``prev_inst``, every path from
``prev_inst``'s value to any kernel output passes through ``inst``, so by the
time ``inst`` executes (topo order) no future op can still need
``prev_inst``'s buffer, and ``inst`` may take it over.  We therefore build
the dominance tree of the *reversed* DAG (sink-rooted); the paper's
``dom.Dominates(inst, prev_inst)`` test maps to ``postdom(inst, prev_inst)``.

The allocator walks ops in topo order, propagates "which allocations flow
here" along data edges (the paper's PropagateAllocInfo/CollectAllocInfo), and
on each scratch request either reuses a dominated predecessor's buffer
(Share) — reclaiming further dominated duplicates (Reclaim) — or allocates
fresh space (Alloc).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .ir import Graph

__all__ = ["dominator_tree", "post_dominates", "ScratchAllocator", "ScratchPlan"]


# ---------------------------------------------------------------------------
# Cooper-Harvey-Kennedy "engineered" dominance on an arbitrary rooted DAG
# ---------------------------------------------------------------------------

def dominator_tree(
    nodes: list[str], preds: dict[str, list[str]], root: str
) -> dict[str, str | None]:
    """idom map via Cooper-Harvey-Kennedy iteration.

    `nodes` must be reverse-post-order reachable-from-root; `preds[v]` are
    predecessors in the rooted graph.
    """
    rpo_index = {n: i for i, n in enumerate(nodes)}
    idom: dict[str, str | None] = {n: None for n in nodes}
    idom[root] = root

    def intersect(a: str, b: str) -> str:
        while a != b:
            while rpo_index[a] > rpo_index[b]:
                a = idom[a]  # type: ignore[assignment]
            while rpo_index[b] > rpo_index[a]:
                b = idom[b]  # type: ignore[assignment]
        return a

    changed = True
    while changed:
        changed = False
        for v in nodes:
            if v == root:
                continue
            cands = [p for p in preds.get(v, []) if idom.get(p) is not None]
            if not cands:
                continue
            new = cands[0]
            for p in cands[1:]:
                new = intersect(new, p)
            if idom[v] != new:
                idom[v] = new
                changed = True
    idom[root] = None
    return idom


_SINK = "__sink__"


def _postdom_idom(g: Graph) -> dict[str, str | None]:
    """Immediate post-dominators of the dataflow DAG (virtual sink over the
    graph outputs and any otherwise-unused values)."""
    # reversed graph: edges user -> operand ; root = sink -> outputs
    succ_rev: dict[str, list[str]] = {n: list(dict.fromkeys(g.nodes[n].operands)) for n in g.nodes}
    sinks = set(g.outputs) | {n for n in g.nodes if not g.users(n)}
    succ_rev[_SINK] = sorted(sinks)
    preds_rev: dict[str, list[str]] = {n: [] for n in list(g.nodes) + [_SINK]}
    for src, dsts in succ_rev.items():
        for d in dsts:
            preds_rev[d].append(src)

    # RPO of the reversed graph from sink
    order: list[str] = []
    seen: set[str] = set()

    def dfs(v: str):
        stack = [(v, iter(succ_rev.get(v, [])))]
        seen.add(v)
        while stack:
            cur, it = stack[-1]
            advanced = False
            for w in it:
                if w not in seen:
                    seen.add(w)
                    stack.append((w, iter(succ_rev.get(w, []))))
                    advanced = True
                    break
            if not advanced:
                order.append(cur)
                stack.pop()

    dfs(_SINK)
    rpo = list(reversed(order))
    return dominator_tree(rpo, preds_rev, _SINK)


def post_dominates(idom: dict[str, str | None], a: str, b: str) -> bool:
    """Does `a` post-dominate `b` (a on every path b -> outputs)?"""
    cur: str | None = b
    while cur is not None:
        if cur == a:
            return True
        cur = idom.get(cur)
    return False


# ---------------------------------------------------------------------------
# Alg. 4 — scratch allocation with dominance-based reuse
# ---------------------------------------------------------------------------

@dataclass
class ScratchPlan:
    # buffer id -> size in bytes
    buffers: dict[int, int] = field(default_factory=dict)
    # op name -> buffer id it writes
    assignment: dict[str, int] = field(default_factory=dict)
    requested: int = 0

    @property
    def allocated(self) -> int:
        return sum(self.buffers.values())

    @property
    def alloc_over_req(self) -> float:
        """The paper's Table-4 ``alloc/req`` ratio (lower = more reuse)."""
        return self.allocated / self.requested if self.requested else 1.0


class ScratchAllocator:
    """Alg. 4 on a fusion pattern's subgraph."""

    def __init__(self, g: Graph):
        self.g = g
        self.ipdom = _postdom_idom(g)

    def allocate(self, req_map: dict[str, int]) -> ScratchPlan:
        g = self.g
        plan = ScratchPlan(requested=sum(req_map.values()))
        next_buf = 0
        # alloc-info flowing to each op: set of (op, buffer) live allocations
        flow: dict[str, set[tuple[str, int]]] = {}

        for inst in g.topo_order():
            incoming: set[tuple[str, int]] = set()
            for operand in g.nodes[inst].operands:          # CollectAllocInfo
                incoming |= flow.get(operand, set())
            if inst not in req_map:
                flow[inst] = incoming                        # PropagateAllocInfo
                continue

            shared = False
            taken: tuple[str, int] | None = None
            dead: set[tuple[str, int]] = set()
            for prev in sorted(incoming, key=lambda t: (-req_map.get(t[0], 0), t[0])):
                prev_inst, buf = prev
                if post_dominates(self.ipdom, inst, prev_inst):
                    if not shared and plan.buffers[buf] >= req_map[inst]:
                        taken = prev                          # Share
                        shared = True
                        dead.add(prev)
                        continue
                    dead.add(prev)                            # Reclaim
            if shared and taken is not None:
                plan.assignment[inst] = taken[1]
            else:
                plan.buffers[next_buf] = req_map[inst]        # Alloc
                plan.assignment[inst] = next_buf
                next_buf += 1
            # this op's allocation flows onward; dominated dead ones do not
            flow[inst] = (incoming - dead) | {(inst, plan.assignment[inst])}

        return plan
