"""jaxpr -> StitchIR frontend.

``trace_to_graph(fn, *example_args)`` traces a JAX function with abstract
values and translates the closed jaxpr into a :class:`Graph`, so the fusion
planner runs on real model code, not just hand-built graphs (the paper sits
inside XLA and consumes HLO; this is our equivalent entry point).

Coverage: the elementwise / broadcast / reshape / transpose / reduction /
dot_general / gather vocabulary of StitchIR, with ``pjit``/``custom_jvp`` /
``custom_vjp`` calls inlined, plus the scatter family (first-class SCATTER
nodes — the transpose of gather, surfaced by every embedding-table gradient).
Any other primitive becomes an executable CUSTOM node (it partitions fusion —
same role as the paper's opaque ops — but the graph stays runnable
end-to-end because the node carries a closure evaluating the original
primitive); backward passes built by ``jax.value_and_grad`` trace through
the same entry point as forward code.

Shard-local functions (``shard_map`` bodies) that contain collectives —
``psum``/``pmean``/``all_gather`` over a named mesh axis — trace with
``axis_env=[(axis, size), ...]``: the collective becomes an executable
CUSTOM node (a fusion partition, exactly like the paper's opaque ops, which
is also the right cost-model story: a collective is a data-movement barrier
no kernel fusion may cross) whose closure re-binds the primitive, so the
compiled artifact runs *inside* ``shard_map`` where the axis names are live.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.extend import core as jcore

from .ir import Graph, OpKind, OpNode

__all__ = ["trace_to_graph", "TraceError"]


class TraceError(Exception):
    pass


_EW_PRIMS = {
    "add": "add", "sub": "sub", "mul": "mul", "div": "div",
    "max": "max", "min": "min", "pow": "pow", "neg": "neg",
    "exp": "exp", "log": "log", "log1p": "log1p", "tanh": "tanh",
    "sqrt": "sqrt", "rsqrt": "rsqrt", "abs": "abs", "sign": "sign",
    "erf": "erf", "logistic": "sigmoid", "square": "square",
    "cos": "cos", "sin": "sin",
    "ge": "ge", "gt": "gt", "le": "le", "lt": "lt", "eq": "eq",
    "and": "and", "or": "or", "not": "not", "xor": "xor",
    # backward-only spellings: the grad-accumulation add (symbolic-zero aware)
    # is an ordinary add once both operands are materialized
    "add_any": "add",
}

_REDUCE_PRIMS = {
    "reduce_sum": "sum", "reduce_max": "max",
    "reduce_min": "min", "reduce_prod": "prod",
}

_INLINE_CALLS = {"pjit", "jit", "custom_jvp_call", "custom_vjp_call",
                 "custom_jvp_call_jaxpr", "remat", "checkpoint", "closed_call"}

# Backward-only data movement: the transpose of gather/dynamic-slice is a
# scatter(-add), so every embedding-table gradient surfaces one.  They get a
# first-class SCATTER kind (the planner already treats SCATTER as a fusion
# partition op) but stay executable through the same closure mechanism as
# CUSTOM nodes.
_SCATTER_PRIMS = {"scatter", "scatter-add", "scatter-mul",
                  "scatter-min", "scatter-max"}


def _dtype_str(aval) -> str:
    return str(np.dtype(aval.dtype))


def _stable_params_sig(params: dict) -> str:
    """Deterministic, value-level spelling of a primitive's static params.

    CUSTOM nodes execute through a closure (``eval_fn``), which no hash can
    see; this canonicalization hook preserves the *identity* of the opaque
    op (dimension numbers, window shapes, ...) so graph signatures
    (:mod:`repro.cache.signature`) distinguish configurations while staying
    invariant to node naming and trace order.  Arrays and sub-jaxprs are
    spelled by dtype/rank only — their content is runtime detail.
    """
    def spell(v) -> str:
        if isinstance(v, (bool, int, float, str, type(None))):
            return repr(v)
        if isinstance(v, (tuple, list)):
            return "(" + ",".join(spell(x) for x in v) + ")"
        if isinstance(v, np.ndarray):
            return f"array:{v.dtype}:rank{v.ndim}"
        if isinstance(v, np.dtype) or isinstance(v, type):
            return str(v)
        return type(v).__name__
    return ";".join(f"{k}={spell(params[k])}" for k in sorted(params))


def trace_to_graph(fn: Callable, *example_args, name: str = "traced",
                   axis_env=None) -> tuple[Graph, list[str]]:
    """Returns (graph, input_names) where input_names[i] is the PARAMETER
    node for positional argument i (flattened pytree order).

    ``axis_env``: sequence of ``(axis_name, size)`` pairs making mesh axes
    visible to the trace, for shard-local functions containing collectives
    (see module docstring)."""
    if axis_env:
        closed = jax.make_jaxpr(fn, axis_env=list(axis_env))(*example_args)
    else:
        closed = jax.make_jaxpr(fn)(*example_args)
    g = Graph(name)
    fresh_ctr = [0]

    def fresh(stem: str) -> str:
        fresh_ctr[0] += 1
        return f"{stem}_{fresh_ctr[0]}"

    env: dict = {}

    def read(var) -> str:
        if isinstance(var, jcore.Literal):
            val = np.asarray(var.val)
            nm = fresh("lit")
            g.add(OpNode(nm, OpKind.CONSTANT, tuple(val.shape), str(val.dtype),
                         (), {"value": val}))
            return nm
        return env[var]

    input_names: list[str] = []
    flat_args = jax.tree_util.tree_leaves(example_args)
    for i, v in enumerate(closed.jaxpr.invars):
        nm = f"arg{i}"
        g.add(OpNode(nm, OpKind.PARAMETER, tuple(v.aval.shape), _dtype_str(v.aval)))
        env[v] = nm
        input_names.append(nm)
    for v, val in zip(closed.jaxpr.constvars, closed.consts):
        nm = fresh("const")
        arr = np.asarray(val)
        g.add(OpNode(nm, OpKind.CONSTANT, tuple(arr.shape), str(arr.dtype),
                     (), {"value": arr}))
        env[v] = nm

    def emit_eqn(eqn) -> None:
        prim = eqn.primitive.name
        if prim in _INLINE_CALLS:
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is None and prim == "custom_jvp_call":
                sub = eqn.params.get("fun_jaxpr")
            if sub is not None:
                inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                consts = getattr(sub, "consts", eqn.params.get("consts", ()))
                for cv, cval in zip(inner.constvars, consts):
                    nm = fresh("const")
                    arr = np.asarray(cval)
                    g.add(OpNode(nm, OpKind.CONSTANT, tuple(arr.shape),
                                 str(arr.dtype), (), {"value": arr}))
                    env[cv] = nm
                for iv, outer in zip(inner.invars, eqn.invars):
                    env[iv] = read(outer)
                for ieqn in inner.eqns:
                    emit_eqn(ieqn)
                for ov, iv in zip(eqn.outvars, inner.outvars):
                    env[ov] = read(iv)
                return

        out = eqn.outvars[0]
        shape = tuple(out.aval.shape)
        dtype = _dtype_str(out.aval)
        operands = tuple(read(v) for v in eqn.invars)

        if len(eqn.outvars) > 1:
            _emit_custom(eqn, operands)
            return

        if prim in _EW_PRIMS:
            nm = fresh(_EW_PRIMS[prim])
            g.add(OpNode(nm, OpKind.ELEMENTWISE, shape, dtype, operands,
                         {"op": _EW_PRIMS[prim]}))
        elif prim == "integer_pow":
            p = eqn.params["y"]
            if p == 2:
                nm = fresh("square")
                g.add(OpNode(nm, OpKind.ELEMENTWISE, shape, dtype, operands,
                             {"op": "square"}))
            else:
                # replay integer_pow exactly: lowering to pow(x, float(p))
                # computes exp(p*log x) — a different rounding (and NaN for
                # negative bases) than XLA's repeated-multiply
                nm = fresh("ipow")
                g.add(OpNode(nm, OpKind.ELEMENTWISE, shape, dtype, operands,
                             {"op": "integer_pow", "y": int(p)}))
        elif prim == "select_n":
            nm = fresh("select")
            # lax.select_n(pred, on_false, on_true) -> where(pred, on_true, on_false)
            pred, *cases = operands
            if len(cases) != 2:
                _emit_custom(eqn, operands); return
            g.add(OpNode(nm, OpKind.ELEMENTWISE, shape, dtype,
                         (pred, cases[1], cases[0]), {"op": "select"}))
        elif prim == "convert_element_type":
            nm = fresh("convert")
            g.add(OpNode(nm, OpKind.ELEMENTWISE, shape, dtype, operands,
                         {"op": "convert"}))
        elif prim == "broadcast_in_dim":
            nm = fresh("bcast")
            g.add(OpNode(nm, OpKind.BROADCAST, shape, dtype, operands,
                         {"bcast_dims": tuple(eqn.params["broadcast_dimensions"])}))
        elif prim in ("reshape", "squeeze", "expand_dims"):
            nm = fresh("reshape")
            g.add(OpNode(nm, OpKind.RESHAPE, shape, dtype, operands))
        elif prim == "slice" and not eqn.params.get("strides"):
            nm = fresh("slice")
            g.add(OpNode(nm, OpKind.SLICE, shape, dtype, operands,
                         {"starts": tuple(eqn.params["start_indices"]),
                          "limits": tuple(eqn.params["limit_indices"]),
                          "strides": None}))
        elif prim == "transpose":
            nm = fresh("transpose")
            g.add(OpNode(nm, OpKind.TRANSPOSE, shape, dtype, operands,
                         {"perm": tuple(eqn.params["permutation"])}))
        elif prim in _REDUCE_PRIMS:
            nm = fresh(f"reduce_{_REDUCE_PRIMS[prim]}")
            in_rank = len(eqn.invars[0].aval.shape)
            g.add(OpNode(nm, OpKind.REDUCTION, shape, dtype, operands,
                         {"op": _REDUCE_PRIMS[prim],
                          "axes": tuple(eqn.params["axes"]),
                          "in_rank": in_rank, "keepdims": False}))
        elif prim == "dot_general":
            (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
            kind = OpKind.BATCHED_GEMM if lb else OpKind.GEMM
            nm = fresh("dot")
            # the jaxpr's accumulation request must survive into the IR:
            # dropping it made the executors re-derive accumulation dtype
            # from the (possibly bf16) output dtype — see eval_node
            pref = eqn.params.get("preferred_element_type")
            g.add(OpNode(nm, kind, shape, dtype, operands,
                         {"contract": (tuple(lc), tuple(rc)),
                          "batch": (tuple(lb), tuple(rb)),
                          "preferred": None if pref is None
                          else str(np.dtype(pref))}))
        elif prim in _SCATTER_PRIMS and len(eqn.outvars) == 1:
            params = dict(eqn.params)

            def run_scatter(*vals, _prim=eqn.primitive, _params=params):
                return _prim.bind(*vals, **_params)

            nm = fresh(f"scatter_{prim.split('-')[-1]}")
            g.add(OpNode(nm, OpKind.SCATTER, shape, dtype, operands,
                         {"prim": prim, "params_sig": _stable_params_sig(params),
                          "eval_fn": run_scatter}))
        elif prim == "stop_gradient" or prim == "copy":
            env[out] = operands[0]
            return
        else:
            _emit_custom(eqn, operands)
            return
        env[out] = nm

    def _emit_custom(eqn, operands):
        """Opaque but executable node (one per output)."""
        prim = eqn.primitive
        params = dict(eqn.params)

        unwrap = prim.multiple_results and len(eqn.outvars) == 1

        def run(*vals, _prim=prim, _params=params, _unwrap=unwrap):
            res = _prim.bind(*vals, **_params)
            # a multiple_results primitive with ONE outvar (e.g. a scan whose
            # carry is its only output) binds to a 1-element list
            if _unwrap:
                (res,) = res
            return res

        psig = _stable_params_sig(params)
        extra = {}
        if prim.name == "pallas_call":
            # the kernel-body function name identifies WHICH Pallas kernel
            # this is; the stitchable-kernel registry keys on it, and making
            # it an attr (not just params_sig type-name soup) also makes
            # kernel identity visible to cache signatures
            nsi = params.get("name_and_src_info")
            tag = getattr(nsi, "name", None) or params.get("name")
            if tag:
                extra["kernel"] = str(tag)
        if len(eqn.outvars) == 1:
            out = eqn.outvars[0]
            nm = fresh(f"custom_{prim.name}")
            g.add(OpNode(nm, OpKind.CUSTOM, tuple(out.aval.shape),
                         _dtype_str(out.aval), operands,
                         {"prim": prim.name, "params_sig": psig,
                          "eval_fn": run, **extra}))
            env[out] = nm
        else:
            base = fresh(f"custom_{prim.name}")
            g.add(OpNode(base, OpKind.CUSTOM, (), "float32", operands,
                         {"prim": prim.name, "params_sig": psig,
                          "eval_fn": run, "multi": True, **extra}))
            for i, out in enumerate(eqn.outvars):
                nm = f"{base}.o{i}"
                g.add(OpNode(nm, OpKind.CUSTOM, tuple(out.aval.shape),
                             _dtype_str(out.aval), (base,),
                             {"prim": prim.name, "project": i, **extra}))
                env[out] = nm

    for eqn in closed.jaxpr.eqns:
        emit_eqn(eqn)

    outputs = []
    for v in closed.jaxpr.outvars:
        outputs.append(read(v))
    g.mark_output(*outputs)
    _fold_widening_converts(g)
    g.validate()
    return g, input_names


def _is_float(dtype: str) -> bool:
    return jnp.issubdtype(jnp.dtype(dtype), jnp.floating)


def _fold_widening_converts(g: Graph) -> None:
    """Mirror XLA's ``convert_f32(dot_bf16) -> dot_f32`` simplification.

    Under jit, a dot whose value is consumed only by converts to a *wider*
    float type never materializes the narrow intermediate — XLA computes the
    dot at the wide type directly.  The op-by-op executors replay the graph
    literally, rounding to the narrow dtype between the dot and the convert:
    one bf16 ulp of divergence on every logit (the stitched-executor "logit
    wobble").  Widening the dot's declared dtype here (the converts become
    value-preserving no-ops) keeps every executor bitwise-consistent with
    jit.  Dots that are graph outputs keep their spelled dtype.  XLA applies
    the rewrite even when the jaxpr pins ``preferred_element_type`` to the
    narrow dtype (jnp.matmul does), so a narrow ``preferred`` is widened
    along with the output; an already-wide ``preferred`` needs no fold."""
    for node in g.nodes.values():
        if node.kind not in (OpKind.GEMM, OpKind.BATCHED_GEMM):
            continue
        if node.name in g.outputs or not _is_float(node.dtype):
            continue
        pref = node.attrs.get("preferred")
        if pref is not None and np.dtype(pref).itemsize > np.dtype(node.dtype).itemsize:
            continue
        users = g.users(node.name)
        if not users:
            continue
        widths = []
        for u in users:
            un = g[u]
            if (un.kind is not OpKind.ELEMENTWISE
                    or un.attrs.get("op") != "convert"
                    or not _is_float(un.dtype)
                    or np.dtype(un.dtype).itemsize <= np.dtype(node.dtype).itemsize):
                break
            widths.append(un.dtype)
        else:
            wide = max(widths, key=lambda d: np.dtype(d).itemsize)
            node.dtype = wide
            node.attrs["preferred"] = wide
