"""StitchIR — the computation-graph IR FusionStitching plans over.

The paper operates on XLA-HLO-like dataflow graphs whose vertices are tensor
ops classified into the categories the fusion planner reasons about
(elementwise / reduction / gemm / batched-gemm / data-movement).  StitchIR is
that graph: a small, explicit DAG of :class:`OpNode` with static shapes and
dtypes, cheap to build by hand (benchmarks, tests) or from a traced jaxpr
(:mod:`repro.core.trace`).

Design notes
------------
* Nodes are identified by unique string names; the graph owns a dict
  ``name -> OpNode`` plus explicit use/def edges derived from operand lists.
* Shapes are plain tuples of ints; dtype is a numpy dtype string.  We never
  carry tracer state here — the IR is a value-level description, which is what
  makes plan optimization (a pure combinatorial problem) fast and hermetic.
* ``OpKind`` mirrors the paper's vocabulary (§4.2): ELEMENTWISE, REDUCTION
  (with row/column/scalar sub-kinds derived from the reduced axes), GEMM,
  BATCHED_GEMM, plus the glue kinds every real graph has (PARAMETER, CONSTANT,
  BROADCAST, RESHAPE, TRANSPOSE, TUPLE).
"""

from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

__all__ = [
    "OpKind",
    "ReduceKind",
    "OpNode",
    "Graph",
    "GraphBuilder",
    "itemsize",
    "tensor_bytes",
]


class OpKind(enum.Enum):
    PARAMETER = "parameter"
    CONSTANT = "constant"
    ELEMENTWISE = "elementwise"
    BROADCAST = "broadcast"
    RESHAPE = "reshape"
    TRANSPOSE = "transpose"
    REDUCTION = "reduction"
    GEMM = "gemm"
    BATCHED_GEMM = "batched_gemm"
    SLICE = "slice"
    GATHER = "gather"
    SCATTER = "scatter"
    TUPLE = "tuple"
    CUSTOM = "custom"  # opaque (e.g. an op we never fuse across)


class ReduceKind(enum.Enum):
    """Sub-classification of reductions, following the paper's partition-op
    widening order (§4.2.1): row reductions are the friendliest (fusible with
    warp/sublane composition), column and scalar reductions have dedicated
    parallelization needs and start life as partition ops."""

    ROW = "row"        # innermost (minor-most) dims reduced
    COLUMN = "column"  # non-innermost dims reduced
    SCALAR = "scalar"  # all dims reduced
    NONE = "none"


def itemsize(dtype: str) -> int:
    return np.dtype(dtype).itemsize


def tensor_bytes(shape: Sequence[int], dtype: str) -> int:
    return int(math.prod(shape)) * itemsize(dtype) if shape else itemsize(dtype)


@dataclass
class OpNode:
    """One vertex of the dataflow DAG."""

    name: str
    kind: OpKind
    shape: tuple[int, ...]
    dtype: str
    operands: tuple[str, ...] = ()
    # Op-specific payload:
    #   ELEMENTWISE: {"op": "add"|"mul"|...}  (primitive spelling)
    #   REDUCTION:   {"axes": (..,), "op": "sum"|"max"|...}
    #   GEMM/BATCHED_GEMM: {"contract": ((lhs_dims),(rhs_dims)), "batch": ((..),(..))}
    #   BROADCAST:   {"bcast_dims": (..,)}
    #   TRANSPOSE:   {"perm": (..,)}
    attrs: dict = field(default_factory=dict)

    # -- derived helpers ----------------------------------------------------
    @property
    def size(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1

    @property
    def bytes(self) -> int:
        return tensor_bytes(self.shape, self.dtype)

    @property
    def reduce_kind(self) -> ReduceKind:
        if self.kind is not OpKind.REDUCTION:
            return ReduceKind.NONE
        axes = tuple(self.attrs.get("axes", ()))
        if not axes:
            return ReduceKind.NONE
        in_rank = self.attrs.get("in_rank")
        if in_rank is None:
            in_rank = len(self.shape) + len(axes)
        if len(axes) == in_rank:
            return ReduceKind.SCALAR
        if (in_rank - 1) in axes:
            return ReduceKind.ROW
        return ReduceKind.COLUMN

    def is_compute_intensive(self) -> bool:
        return self.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM)

    def is_memory_intensive(self) -> bool:
        return self.kind in (
            OpKind.ELEMENTWISE,
            OpKind.BROADCAST,
            OpKind.RESHAPE,
            OpKind.TRANSPOSE,
            OpKind.REDUCTION,
            OpKind.SLICE,
        )

    def is_source(self) -> bool:
        return self.kind in (OpKind.PARAMETER, OpKind.CONSTANT)

    def __hash__(self) -> int:  # nodes are interned by name within a graph
        return hash(self.name)


class Graph:
    """A static-shape dataflow DAG.

    Invariants (checked by :meth:`validate`):
      * every operand of every node exists in the graph,
      * the graph is acyclic,
      * outputs reference existing nodes.
    """

    def __init__(self, name: str = "graph") -> None:
        self.name = name
        self.nodes: dict[str, OpNode] = {}
        self.outputs: list[str] = []
        self._users: dict[str, set[str]] | None = None  # lazy cache
        self._topo: list[str] | None = None             # lazy cache

    # -- construction -------------------------------------------------------
    def add(self, node: OpNode) -> OpNode:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node name {node.name!r}")
        for o in node.operands:
            if o not in self.nodes:
                raise ValueError(f"{node.name}: unknown operand {o!r}")
        self.nodes[node.name] = node
        self._users = None
        self._topo = None
        return node

    def mark_output(self, *names: str) -> None:
        for n in names:
            if n not in self.nodes:
                raise ValueError(f"unknown output {n!r}")
            if n not in self.outputs:
                self.outputs.append(n)
        self._users = None

    # -- queries --------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    def __contains__(self, name: str) -> bool:
        return name in self.nodes

    def __getitem__(self, name: str) -> OpNode:
        return self.nodes[name]

    def users(self, name: str) -> set[str]:
        if self._users is None:
            users: dict[str, set[str]] = {n: set() for n in self.nodes}
            for node in self.nodes.values():
                for o in node.operands:
                    users[o].add(node.name)
            self._users = users
        return self._users[name]

    def producers(self, name: str) -> tuple[str, ...]:
        return self.nodes[name].operands

    def compute_nodes(self) -> list[OpNode]:
        """Nodes that correspond to executed kernels (excludes params/consts/
        tuples) — the denominator for kernel-count statistics."""
        return [
            n
            for n in self.nodes.values()
            if n.kind not in (OpKind.PARAMETER, OpKind.CONSTANT, OpKind.TUPLE)
        ]

    def topo_order(self) -> list[str]:
        """Deterministic Kahn topological order (insertion-order tiebreak).

        Cached until the next :meth:`add`; a fresh copy is returned so
        callers may mutate their list freely."""
        if self._topo is not None:
            return list(self._topo)
        # count operand edges (duplicates count once per unique producer)
        indeg = {n: len(set(self.nodes[n].operands)) for n in self.nodes}
        order: list[str] = []
        ready = [n for n in self.nodes if indeg[n] == 0]
        users = {n: sorted(self.users(n)) for n in self.nodes}
        seen_ready = set(ready)
        while ready:
            cur = ready.pop(0)
            order.append(cur)
            for u in users[cur]:
                indeg[u] -= 1
                if indeg[u] == 0 and u not in seen_ready:
                    ready.append(u)
                    seen_ready.add(u)
        if len(order) != len(self.nodes):
            raise ValueError(f"cycle detected in graph {self.name!r}")
        self._topo = order
        return list(order)

    def validate(self) -> None:
        self.topo_order()  # raises on cycles / dangling operands
        for out in self.outputs:
            if out not in self.nodes:
                raise ValueError(f"output {out!r} missing")

    # -- subgraph / pattern helpers ------------------------------------------
    def external_inputs(self, members: Iterable[str]) -> list[str]:
        """Tensors read by `members` but produced outside the set."""
        mset = set(members)
        ext: list[str] = []
        seen = set()
        for m in mset:
            for o in self.nodes[m].operands:
                if o not in mset and o not in seen:
                    ext.append(o)
                    seen.add(o)
        return ext

    def external_outputs(self, members: Iterable[str]) -> list[str]:
        """Tensors produced by `members` and read outside the set (or graph
        outputs)."""
        mset = set(members)
        outs: list[str] = []
        for m in sorted(mset):
            used_outside = any(u not in mset for u in self.users(m))
            if used_outside or m in self.outputs:
                outs.append(m)
        return outs

    def internal_edges_bytes(self, members: Iterable[str]) -> int:
        """Bytes of intermediates that fusion keeps on-chip: tensors produced
        AND consumed entirely inside the member set."""
        mset = set(members)
        total = 0
        for m in mset:
            node = self.nodes[m]
            if node.is_source():
                continue
            users = self.users(m)
            if users and users.issubset(mset) and m not in self.outputs:
                total += node.bytes
        return total

    def induced_reaches(self, src: str, dst: str, forbidden: set[str]) -> bool:
        """Is there a path src -> dst that leaves `forbidden` (used for cycle
        checks when contracting a candidate pattern)?"""
        stack = [src]
        seen = {src}
        while stack:
            cur = stack.pop()
            if cur == dst:
                return True
            for u in self.users(cur):
                if u in seen:
                    continue
                seen.add(u)
                stack.append(u)
        return False

    # -- pretty ---------------------------------------------------------------
    def dump(self) -> str:
        lines = [f"Graph {self.name} ({len(self.nodes)} nodes)"]
        for n in self.topo_order():
            node = self.nodes[n]
            ops = ", ".join(node.operands)
            extra = ""
            if node.kind is OpKind.REDUCTION:
                extra = f" axes={node.attrs.get('axes')}"
            elif node.kind is OpKind.ELEMENTWISE:
                extra = f" op={node.attrs.get('op')}"
            lines.append(
                f"  {n} = {node.kind.value}{extra} {node.dtype}{list(node.shape)}"
                + (f" ({ops})" if ops else "")
            )
        lines.append(f"  outputs: {self.outputs}")
        return "\n".join(lines)


class GraphBuilder:
    """Ergonomic construction API used by benchmarks/tests.

    >>> b = GraphBuilder("softmax")
    >>> x = b.param("x", (256, 1024))
    >>> m = b.reduce("max", x, axes=(1,))
    >>> e = b.ew("exp", b.ew("sub", x, b.bcast(m, (256, 1024), (0,))))
    >>> s = b.reduce("sum", e, axes=(1,))
    >>> y = b.ew("div", e, b.bcast(s, (256, 1024), (0,)))
    >>> g = b.build(outputs=[y])
    """

    def __init__(self, name: str = "graph") -> None:
        self.graph = Graph(name)
        self._ctr: dict[str, int] = {}

    def _fresh(self, stem: str) -> str:
        i = self._ctr.get(stem, 0)
        self._ctr[stem] = i + 1
        return f"{stem}_{i}" if i or stem in self.graph.nodes else stem

    def _node(self, stem, kind, shape, dtype, operands=(), **attrs) -> str:
        name = self._fresh(stem)
        self.graph.add(
            OpNode(name, kind, tuple(int(s) for s in shape), dtype, tuple(operands), attrs)
        )
        return name

    # sources
    def param(self, name: str, shape, dtype: str = "float32") -> str:
        return self._node(name, OpKind.PARAMETER, shape, dtype)

    def const(self, name: str, shape=(), dtype: str = "float32") -> str:
        return self._node(name, OpKind.CONSTANT, shape, dtype)

    # elementwise (shape = first operand's shape unless given)
    def ew(self, op: str, *operands: str, shape=None, dtype=None) -> str:
        first = self.graph[operands[0]]
        shape = tuple(shape) if shape is not None else first.shape
        dtype = dtype or first.dtype
        return self._node(op, OpKind.ELEMENTWISE, shape, dtype, operands, op=op)

    def bcast(self, operand: str, shape, dims: tuple[int, ...]) -> str:
        src = self.graph[operand]
        return self._node(
            "bcast", OpKind.BROADCAST, shape, src.dtype, (operand,), bcast_dims=tuple(dims)
        )

    def reshape(self, operand: str, shape) -> str:
        src = self.graph[operand]
        return self._node("reshape", OpKind.RESHAPE, shape, src.dtype, (operand,))

    def transpose(self, operand: str, perm: tuple[int, ...]) -> str:
        src = self.graph[operand]
        shape = tuple(src.shape[p] for p in perm)
        return self._node("transpose", OpKind.TRANSPOSE, shape, src.dtype, (operand,), perm=tuple(perm))

    def reduce(self, op: str, operand: str, axes: tuple[int, ...], keepdims: bool = False) -> str:
        src = self.graph[operand]
        axes = tuple(sorted(a % len(src.shape) for a in axes))
        if keepdims:
            shape = tuple(1 if i in axes else s for i, s in enumerate(src.shape))
        else:
            shape = tuple(s for i, s in enumerate(src.shape) if i not in axes)
        return self._node(
            f"reduce_{op}", OpKind.REDUCTION, shape, src.dtype, (operand,),
            op=op, axes=axes, in_rank=len(src.shape), keepdims=keepdims,
        )

    def dot(self, lhs: str, rhs: str, name: str = "dot") -> str:
        """Plain 2-D matmul (m,k) @ (k,n)."""
        a, b = self.graph[lhs], self.graph[rhs]
        m, k = a.shape
        k2, n = b.shape
        assert k == k2, f"dot shape mismatch {a.shape} @ {b.shape}"
        return self._node(
            name, OpKind.GEMM, (m, n), a.dtype, (lhs, rhs),
            contract=((1,), (0,)), batch=((), ()),
        )

    def batched_dot(self, lhs: str, rhs: str, name: str = "bdot") -> str:
        """(b,m,k) @ (b,k,n)."""
        a, b = self.graph[lhs], self.graph[rhs]
        bb, m, k = a.shape
        bb2, k2, n = b.shape
        assert bb == bb2 and k == k2, f"bdot mismatch {a.shape} @ {b.shape}"
        return self._node(
            name, OpKind.BATCHED_GEMM, (bb, m, n), a.dtype, (lhs, rhs),
            contract=((2,), (1,)), batch=((0,), (0,)),
        )

    def slice_(self, operand: str, starts, limits, name: str = "slice") -> str:
        src_node = self.graph[operand]
        shape = tuple(l - s for s, l in zip(starts, limits))
        return self._node(name, OpKind.SLICE, shape, src_node.dtype, (operand,),
                          starts=tuple(starts), limits=tuple(limits))

    def gather(self, table: str, indices: str, name: str = "gather") -> str:
        t, ix = self.graph[table], self.graph[indices]
        shape = ix.shape + t.shape[1:]
        return self._node(name, OpKind.GATHER, shape, t.dtype, (table, indices))

    def custom(self, name: str, shape, dtype: str, operands=(), **attrs) -> str:
        return self._node(name, OpKind.CUSTOM, shape, dtype, operands, **attrs)

    def tuple_(self, *operands: str) -> str:
        return self._node("tuple", OpKind.TUPLE, (), "float32", operands)

    def build(self, outputs: Sequence[str]) -> Graph:
        self.graph.mark_output(*outputs)
        self.graph.validate()
        return self.graph
