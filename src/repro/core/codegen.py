"""Code generation for StitchIR graphs.

Three consumers share the single node evaluator below:

* :func:`build_reference_fn` — pure-jnp executor for a whole graph.  Running
  it under ``jax.jit`` is the **XLA baseline** execution mode; running each
  node as its own jitted callable is the **unfused ("TensorFlow") baseline**.
  It is also the numerical oracle every generated kernel is tested against.
* the **Pallas stitched-kernel emitter** (:mod:`repro.kernels.stitched`) —
  evaluates the same nodes *inside* a kernel body on block values.
* :func:`emit_source` — renders the kernel a template would generate as
  readable Pallas-style Python (the paper's CUDA-C emitter had the same
  diagnosis role, §5.5).
"""

from __future__ import annotations

import math
from typing import Callable, Mapping

import jax
import jax.numpy as jnp
from jax import lax

from .ir import Graph, OpKind, OpNode
from .pattern import FusionPattern
from .templates import Template

__all__ = ["EW_OPS", "canonical_dtype", "accumulation_dtype", "dot_accumulate",
           "eval_node", "build_reference_fn", "build_per_op_fns", "emit_source"]


def canonical_dtype(dtype) -> jnp.dtype:
    """The dtype JAX will actually store under the current x64 setting.

    Traced graphs (and np scalar constants) may carry 64-bit dtypes; asking
    jnp for them with x64 disabled emits a truncation ``UserWarning`` per
    call.  Canonicalizing once keeps the graph dtype authoritative without
    ever requesting an unavailable width."""
    return jax.dtypes.canonicalize_dtype(jnp.dtype(dtype))


# -- elementwise vocabulary --------------------------------------------------

EW_OPS: dict[str, Callable] = {
    "add": lax.add,
    "sub": lax.sub,
    "mul": lax.mul,
    "div": lax.div,
    "max": lax.max,
    "min": lax.min,
    "pow": lax.pow,
    "neg": lax.neg,
    "exp": lax.exp,
    "log": lax.log,
    "log1p": lax.log1p,
    "tanh": lax.tanh,
    "sqrt": lax.sqrt,
    "rsqrt": lax.rsqrt,
    "abs": lax.abs,
    "sign": lax.sign,
    "erf": lax.erf,
    "square": lambda x: x * x,
    "sigmoid": jax.nn.sigmoid,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "softplus": jax.nn.softplus,
    "select": lambda c, a, b: jnp.where(c, a, b),
    "cos": lax.cos,
    "sin": lax.sin,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "not": lambda a: ~a,
    "xor": lambda a, b: a ^ b,
    "ge": lambda a, b: (a >= b).astype(a.dtype),
    "gt": lambda a, b: (a > b).astype(a.dtype),
    "le": lambda a, b: (a <= b).astype(a.dtype),
    "lt": lambda a, b: (a < b).astype(a.dtype),
    "eq": lambda a, b: (a == b).astype(a.dtype),
}

_REDUCERS = {
    "sum": jnp.sum,
    "max": jnp.max,
    "min": jnp.min,
    "prod": jnp.prod,
    "mean": jnp.mean,
}


def accumulation_dtype(node: OpNode) -> jnp.dtype:
    """Accumulation dtype for a GEMM/BATCHED_GEMM node.

    The traced ``preferred`` attr (the jaxpr's ``preferred_element_type``)
    wins; otherwise float dots accumulate in at least f32.  Replaying with
    ``preferred_element_type=<output dtype>`` is NOT equivalent for bf16/f16
    outputs: it forces genuinely low-precision accumulation where XLA's
    default dot accumulates in f32 and rounds once — the source of the
    stitched-executor logit wobble vs plain jit."""
    pref = node.attrs.get("preferred")
    if pref is not None:
        return canonical_dtype(pref)
    out_dt = canonical_dtype(node.dtype)
    if jnp.issubdtype(out_dt, jnp.floating):
        return jnp.promote_types(out_dt, jnp.float32)
    return out_dt


def dot_accumulate(node: OpNode, lhs, rhs, *, dimension_numbers):
    """`lax.dot_general` with explicit accumulation dtype, rounded once to
    the node's declared output dtype.  Every executor (fused-jnp groups,
    the xla fallback artifact, and in-kernel stitched dots) funnels through
    here so they are bitwise-consistent with each other and with jit."""
    out = lax.dot_general(lhs, rhs, dimension_numbers=dimension_numbers,
                          preferred_element_type=accumulation_dtype(node))
    return out.astype(canonical_dtype(node.dtype))


def eval_node(node: OpNode, operands: list, g: Graph | None = None):
    """Evaluate one StitchIR node on concrete/traced jnp values."""
    k = node.kind
    if k is OpKind.ELEMENTWISE:
        op = node.attrs["op"]
        if op == "convert":
            return operands[0].astype(canonical_dtype(node.dtype))
        if op == "integer_pow":
            return lax.integer_pow(operands[0], node.attrs["y"])
        fn = EW_OPS.get(op)
        if fn is None:
            raise NotImplementedError(f"elementwise op {op!r}")
        # numpy-style broadcasting between operands of different ranks
        out = fn(*operands)
        # the node's declared dtype is authoritative: comparison lambdas cast
        # to their operand dtype, but traced graphs declare bool outputs that
        # downstream logical ops (and/or/select) require
        dt = canonical_dtype(node.dtype)
        if out.dtype != dt:
            out = out.astype(dt)
        return out
    if k is OpKind.BROADCAST:
        return lax.broadcast_in_dim(
            operands[0], node.shape, tuple(node.attrs["bcast_dims"])
        )
    if k is OpKind.RESHAPE:
        return jnp.reshape(operands[0], node.shape)
    if k is OpKind.TRANSPOSE:
        return jnp.transpose(operands[0], tuple(node.attrs["perm"]))
    if k is OpKind.SLICE:
        return lax.slice(operands[0], node.attrs["starts"], node.attrs["limits"],
                         node.attrs.get("strides"))
    if k is OpKind.REDUCTION:
        red = _REDUCERS[node.attrs.get("op", "sum")]
        return red(
            operands[0],
            axis=tuple(node.attrs["axes"]),
            keepdims=bool(node.attrs.get("keepdims", False)),
        )
    if k in (OpKind.GEMM, OpKind.BATCHED_GEMM):
        contract = tuple(tuple(d) for d in node.attrs["contract"])
        batch = tuple(tuple(d) for d in node.attrs.get("batch", ((), ())))
        return dot_accumulate(node, operands[0], operands[1],
                              dimension_numbers=(contract, batch))
    if k is OpKind.GATHER:
        table, idx = operands
        return jnp.take(table, idx.astype(jnp.int32), axis=0)
    if k is OpKind.TUPLE:
        return tuple(operands)
    if k in (OpKind.CUSTOM, OpKind.SCATTER):
        if "project" in node.attrs:
            return operands[0][node.attrs["project"]]
        fn = node.attrs.get("eval_fn")
        if fn is not None:
            return fn(*operands)
    raise NotImplementedError(f"cannot evaluate node kind {k}")


def source_value(node: OpNode, inputs: Mapping[str, jax.Array] | None = None):
    """Resolve a PARAMETER/CONSTANT node to a value: explicit input first,
    then the constant payload captured at trace time."""
    if inputs is not None and node.name in inputs:
        return jnp.asarray(inputs[node.name], dtype=canonical_dtype(node.dtype))
    if node.kind is OpKind.CONSTANT and "value" in node.attrs:
        return jnp.asarray(node.attrs["value"], dtype=canonical_dtype(node.dtype))
    raise KeyError(f"missing input {node.name!r}")


def build_reference_fn(g: Graph) -> Callable[[Mapping[str, jax.Array]], dict]:
    """Whole-graph executor: {param/const name: array} -> {output name: array}."""
    topo = g.topo_order()

    def run(inputs: Mapping[str, jax.Array]) -> dict:
        env: dict[str, jax.Array] = {}
        for name in topo:
            node = g[name]
            if node.is_source():
                env[name] = source_value(node, inputs)
            else:
                env[name] = eval_node(node, [env[o] for o in node.operands], g)
        return {o: env[o] for o in g.outputs}

    return run


def build_per_op_fns(g: Graph) -> dict[str, Callable]:
    """One jitted callable per compute node — the unfused baseline: running
    the graph this way dispatches exactly one 'kernel' per op."""
    fns: dict[str, Callable] = {}
    for node in g.compute_nodes():
        def fn(*operands, _node=node):
            return eval_node(_node, list(operands), g)
        fns[node.name] = jax.jit(fn)
    return fns


# -- source emitter -----------------------------------------------------------

def emit_source(p: FusionPattern, template: Template, name: str = "stitched") -> str:
    """Render the kernel `template` implies for pattern `p` as readable
    Pallas-style Python — the diagnosis artifact (paper's CUDAEmitter role)."""
    g = p.graph
    ins = p.external_inputs
    outs = p.external_outputs
    lines = [
        f"# stitched kernel: {len(p.compute_members)} ops, class={p.pattern_class}",
        f"# template: {template}",
        f"def {name}_kernel({', '.join(i + '_ref' for i in ins)},",
        f"                  {', '.join(o + '_ref' for o in outs)}, *scratch):",
    ]
    scratch_ops = set(template.scratch_ops)
    for i in ins:
        lines.append(f"    {i} = {i}_ref[...]  # HBM->VMEM block load")
    for node in p.nodes:
        if node.is_source() or node.name in ins:
            continue
        sched = template.schedule_for(node.name)
        how = f"  # [{sched and ','.join(str(a) for a in sched.attrs)}]"
        args = ", ".join(node.operands)
        if node.kind is OpKind.ELEMENTWISE:
            expr = f"ew.{node.attrs['op']}({args})"
        elif node.kind is OpKind.REDUCTION:
            expr = f"jnp.{node.attrs.get('op','sum')}({args}, axis={tuple(node.attrs['axes'])})"
        elif node.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM):
            expr = f"jnp.dot({args})  # MXU"
        elif node.kind is OpKind.BROADCAST:
            expr = f"broadcast_in_dim({args}, {node.shape}, {tuple(node.attrs['bcast_dims'])})"
        elif node.kind is OpKind.RESHAPE:
            expr = f"{args}.reshape({node.shape})"
        elif node.kind is OpKind.TRANSPOSE:
            expr = f"{args}.transpose({tuple(node.attrs['perm'])})"
        else:
            expr = f"<{node.kind.value}>({args})"
        lines.append(f"    {node.name} = {expr}{how}")
        if node.name in scratch_ops:
            lines.append(f"    scratch_{node.name}[...] = {node.name}  # VMEM scratch (S)")
            lines.append(f"    {node.name} = scratch_{node.name}[...]")
    for o in outs:
        lines.append(f"    {o}_ref[...] = {o}  # VMEM->HBM store")
    return "\n".join(lines) + "\n"
