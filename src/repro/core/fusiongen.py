"""Fusion-pattern generation — the paper's §4.2.

Two domain-specific searches produce the candidate set the ILP chooses from:

* :func:`substitution_fusion` (Alg. 1) — topo-sort the graph, collapse every
  run of ops between adjacent *partition ops* into one pattern.  Driven by
  :func:`multi_step_substitution`, which widens the partition-op set in the
  paper's order (large GEMMs -> batched-GEMMs -> column reductions -> scalar
  reductions), collecting patterns at every step.

* :func:`exploratory_fusion` (Alg. 2) — recursive producer/consumer expansion
  from seed patterns, gated by the two fusibility conditions: member kinds
  restricted to elementwise / reduction / batched-gemm (+ shape glue), and no
  cyclic data dependence after contraction.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .ir import Graph, OpKind, OpNode, ReduceKind
from .pattern import FusionPattern, contraction_creates_cycle

__all__ = [
    "GenConfig",
    "substitution_fusion",
    "multi_step_substitution",
    "exploratory_fusion",
    "generate_patterns",
]

# kinds that may always live inside a fused kernel
_FUSIBLE_GLUE = {
    OpKind.ELEMENTWISE,
    OpKind.BROADCAST,
    OpKind.RESHAPE,
    OpKind.TRANSPOSE,
    OpKind.SLICE,
}
_FUSIBLE_EXPLORE = _FUSIBLE_GLUE | {OpKind.REDUCTION, OpKind.BATCHED_GEMM}


@dataclass
class GenConfig:
    # §4.2.1 — a GEMM is "large" (left to the native library / MXU pipeline)
    # when its FLOPs exceed this; small ones are stitched. cuBLAS rule -> MXU rule.
    large_gemm_flops: float = 2.0e9
    # §4.2.2 seed heuristics
    max_operands: int = 10           # ops with more operands are never seeds
    seed_min_bytes: int = 1 << 20    # "large input/output tensors"
    # exploration budget ("if it still takes long, give up")
    max_patterns: int = 4000
    max_pattern_size: int = 64
    max_depth: int = 12
    # registered Pallas custom kernels (kernels/registry.py) stop partitioning
    # from `custom_fuse_step` on, so attention/router bodies can live inside a
    # stitched kernel alongside their surrounding projections
    stitch_custom: bool = True
    custom_fuse_step: int = 1
    # on-chip scratch ceiling for candidate partitions; None = hardware budget
    scratch_budget: int | None = None


def _gemm_flops(g: Graph, node: OpNode) -> float:
    import math

    lhs = g[node.operands[0]]
    k = math.prod(lhs.shape[d] for d in node.attrs["contract"][0])
    return 2.0 * node.size * k


def _is_partition_op(g: Graph, node: OpNode, step: int, cfg: GenConfig) -> bool:
    """Paper's multi-step widening: step 0 partitions on large GEMMs only;
    each later step *removes* a class from the partition set (i.e. allows it
    to fuse).  Order: large gemm | batched-gemm | column reductions | scalar
    reductions.  GATHER/SCATTER always partition (opaque); CUSTOM partitions
    unless the kernel is registered stitchable and the step has widened past
    ``cfg.custom_fuse_step``."""
    if node.kind is OpKind.CUSTOM:
        if cfg.stitch_custom and step >= cfg.custom_fuse_step:
            from repro.kernels.registry import lookup
            if lookup(node) is not None:
                return False
        return True
    if node.kind in (OpKind.GATHER, OpKind.SCATTER):
        return True
    if node.kind is OpKind.SLICE:
        return False
    if node.kind is OpKind.GEMM:
        return _gemm_flops(g, node) >= cfg.large_gemm_flops or step < 1
    if node.kind is OpKind.BATCHED_GEMM:
        return step < 1
    if node.kind is OpKind.REDUCTION:
        rk = node.reduce_kind
        if rk is ReduceKind.COLUMN:
            return step < 2
        if rk is ReduceKind.SCALAR:
            return step < 3
        return False  # row reductions always fusible
    return False


def substitution_fusion(
    g: Graph, partition: set[str], origin: str = "substitution",
) -> list[FusionPattern]:
    """Alg. 1: collapse all ops between adjacent partition ops (in topo order)
    into a single pattern each."""
    topo = g.topo_order()
    patterns: list[FusionPattern] = []
    run: list[str] = []

    def flush():
        nonlocal run
        members = [
            m for m in run
            if not g[m].is_source() and g[m].kind is not OpKind.TUPLE
        ]
        if len(members) >= 2:
            patterns.append(FusionPattern(g, frozenset(members), origin))
        run = []

    for name in topo:
        if name in partition:
            flush()
        else:
            run.append(name)
    flush()
    return patterns


def multi_step_substitution(g: Graph, cfg: GenConfig) -> list[FusionPattern]:
    """§4.2.1 multi-step procedure: run Alg. 1 once per widening step."""
    out: list[FusionPattern] = []
    seen: set[frozenset[str]] = set()
    for step in range(4):
        partition = {
            n.name for n in g.nodes.values() if _is_partition_op(g, n, step, cfg)
        }
        for p in substitution_fusion(g, partition):
            if p.members not in seen and not p.creates_cycle():
                seen.add(p.members)
                out.append(p)
    return out


def _explore_fusible(g: Graph, name: str, cfg: GenConfig | None = None) -> bool:
    node = g[name]
    if node.kind in _FUSIBLE_EXPLORE:
        return True
    if cfg is None:
        return False
    # exploration may also pull in small GEMMs and registered custom kernels —
    # the same classes the widened substitution steps stop partitioning on
    if node.kind is OpKind.GEMM:
        return _gemm_flops(g, node) < cfg.large_gemm_flops
    if node.kind is OpKind.CUSTOM and cfg.stitch_custom:
        from repro.kernels.registry import lookup
        return lookup(node) is not None
    return False


def exploratory_fusion(
    g: Graph, seeds: list[frozenset[str]] | None = None, cfg: GenConfig | None = None,
) -> list[FusionPattern]:
    """Alg. 2 with the paper's seed heuristics and a search budget."""
    cfg = cfg or GenConfig()
    if seeds is None:
        seeds = []
        for node in g.nodes.values():
            if node.kind not in (OpKind.ELEMENTWISE, OpKind.REDUCTION, OpKind.BATCHED_GEMM):
                continue
            if len(node.operands) > cfg.max_operands:
                continue
            io = node.bytes + sum(g[o].bytes for o in node.operands)
            if io < cfg.seed_min_bytes:
                continue
            seeds.append(frozenset([node.name]))

    patterns: list[FusionPattern] = []
    seen: set[frozenset[str]] = set()

    def expand_candidates(members: frozenset[str]) -> list[str]:
        cands: set[str] = set()
        for m in members:
            # ProducerExpansion
            for o in g[m].operands:
                if o not in members and _explore_fusible(g, o, cfg):
                    cands.add(o)
            # ConsumerExpansion
            for u in g.users(m):
                if u not in members and _explore_fusible(g, u, cfg):
                    cands.add(u)
        return sorted(cands)

    def explore(members: frozenset[str], depth: int):
        if len(patterns) >= cfg.max_patterns or depth > cfg.max_depth:
            return
        for cand in expand_candidates(members):
            fused = members | {cand}
            if fused in seen or len(fused) > cfg.max_pattern_size:
                continue
            seen.add(fused)
            if contraction_creates_cycle(g, fused):
                continue
            if len(fused) >= 2:
                patterns.append(FusionPattern(g, fused, "exploratory"))
            if len(patterns) >= cfg.max_patterns:
                return
            explore(fused, depth + 1)

    for s in seeds:
        explore(s, 0)
    return patterns


def generate_patterns(g: Graph, cfg: GenConfig | None = None) -> list[FusionPattern]:
    """§4.2 composition rule: substitution fusion is the base strategy,
    exploratory fusion is supplementary."""
    cfg = cfg or GenConfig()
    out = multi_step_substitution(g, cfg)
    seen = {p.members for p in out}
    for p in exploratory_fusion(g, None, cfg):
        if p.members not in seen:
            seen.add(p.members)
            out.append(p)
    return out
