"""Fusion-pattern generation — the paper's §4.2.

Two domain-specific searches produce the candidate set the ILP chooses from:

* :func:`substitution_fusion` (Alg. 1) — topo-sort the graph, collapse every
  run of ops between adjacent *partition ops* into one pattern.  Driven by
  :func:`multi_step_substitution`, which widens the partition-op set in the
  paper's order (large GEMMs -> batched-GEMMs -> column reductions -> scalar
  reductions), collecting patterns at every step.

* :func:`exploratory_fusion` (Alg. 2) — recursive producer/consumer expansion
  from seed patterns, gated by the two fusibility conditions: member kinds
  restricted to elementwise / reduction / batched-gemm (+ shape glue), and no
  cyclic data dependence after contraction.

* :func:`packing_fusion` — §4.2's *independent-op packing*: find
  structurally-similar independent subgraphs (per-expert MoE FFN chains,
  per-head attention tails), grow exclusive producer cones around each twin,
  and bin the cones with capacity-bounded first-fit-decreasing over the
  register/scratch budgets.  Each bin becomes one :class:`PackPattern` — a
  horizontal kernel whose member subgraphs share a grid but exchange no data.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .ir import Graph, OpKind, OpNode, ReduceKind
from .pattern import FusionPattern, PackPattern, contraction_creates_cycle

__all__ = [
    "GenConfig",
    "substitution_fusion",
    "multi_step_substitution",
    "exploratory_fusion",
    "packing_fusion",
    "generate_patterns",
]

# kinds that may always live inside a fused kernel
_FUSIBLE_GLUE = {
    OpKind.ELEMENTWISE,
    OpKind.BROADCAST,
    OpKind.RESHAPE,
    OpKind.TRANSPOSE,
    OpKind.SLICE,
}
_FUSIBLE_EXPLORE = _FUSIBLE_GLUE | {OpKind.REDUCTION, OpKind.BATCHED_GEMM}


@dataclass
class GenConfig:
    # §4.2.1 — a GEMM is "large" (left to the native library / MXU pipeline)
    # when its FLOPs exceed this; small ones are stitched. cuBLAS rule -> MXU rule.
    large_gemm_flops: float = 2.0e9
    # §4.2.2 seed heuristics
    max_operands: int = 10           # ops with more operands are never seeds
    seed_min_bytes: int = 1 << 20    # "large input/output tensors"
    # exploration budget ("if it still takes long, give up")
    max_patterns: int = 4000
    max_pattern_size: int = 64
    max_depth: int = 12
    # registered Pallas custom kernels (kernels/registry.py) stop partitioning
    # from `custom_fuse_step` on, so attention/router bodies can live inside a
    # stitched kernel alongside their surrounding projections
    stitch_custom: bool = True
    custom_fuse_step: int = 1
    # on-chip scratch ceiling for candidate partitions; None = hardware budget
    scratch_budget: int | None = None
    # §4.2 independent-op packing: propose horizontal PackPatterns over
    # structurally-similar independent subgraphs, binned first-fit-decreasing
    # under the register/scratch budgets
    pack_patterns: bool = True
    pack_min_group: int = 2        # twin-class multiplicity needed to seed packs
    pack_max_members: int = 16     # max packed subgraphs per bin
    # live-register ceiling for one kernel (cost.register_pressure); None =
    # hardware reg_budget.  Also the FFD bin capacity.
    reg_budget: int | None = None


def _gemm_flops(g: Graph, node: OpNode) -> float:
    import math

    lhs = g[node.operands[0]]
    k = math.prod(lhs.shape[d] for d in node.attrs["contract"][0])
    return 2.0 * node.size * k


def _is_partition_op(g: Graph, node: OpNode, step: int, cfg: GenConfig) -> bool:
    """Paper's multi-step widening: step 0 partitions on large GEMMs only;
    each later step *removes* a class from the partition set (i.e. allows it
    to fuse).  Order: large gemm | batched-gemm | column reductions | scalar
    reductions.  GATHER/SCATTER always partition (opaque); CUSTOM partitions
    unless the kernel is registered stitchable and the step has widened past
    ``cfg.custom_fuse_step``."""
    if node.kind is OpKind.CUSTOM:
        if cfg.stitch_custom and step >= cfg.custom_fuse_step:
            from repro.kernels.registry import lookup
            if lookup(node) is not None:
                return False
        return True
    if node.kind in (OpKind.GATHER, OpKind.SCATTER):
        return True
    if node.kind is OpKind.SLICE:
        return False
    if node.kind is OpKind.GEMM:
        return _gemm_flops(g, node) >= cfg.large_gemm_flops or step < 1
    if node.kind is OpKind.BATCHED_GEMM:
        return step < 1
    if node.kind is OpKind.REDUCTION:
        rk = node.reduce_kind
        if rk is ReduceKind.COLUMN:
            return step < 2
        if rk is ReduceKind.SCALAR:
            return step < 3
        return False  # row reductions always fusible
    return False


def substitution_fusion(
    g: Graph, partition: set[str], origin: str = "substitution",
) -> list[FusionPattern]:
    """Alg. 1: collapse all ops between adjacent partition ops (in topo order)
    into a single pattern each."""
    topo = g.topo_order()
    patterns: list[FusionPattern] = []
    run: list[str] = []

    def flush():
        nonlocal run
        members = [
            m for m in run
            if not g[m].is_source() and g[m].kind is not OpKind.TUPLE
        ]
        if len(members) >= 2:
            patterns.append(FusionPattern(g, frozenset(members), origin))
        run = []

    for name in topo:
        if name in partition:
            flush()
        else:
            run.append(name)
    flush()
    return patterns


def multi_step_substitution(g: Graph, cfg: GenConfig) -> list[FusionPattern]:
    """§4.2.1 multi-step procedure: run Alg. 1 once per widening step."""
    out: list[FusionPattern] = []
    seen: set[frozenset[str]] = set()
    for step in range(4):
        partition = {
            n.name for n in g.nodes.values() if _is_partition_op(g, n, step, cfg)
        }
        for p in substitution_fusion(g, partition):
            if p.members not in seen and not p.creates_cycle():
                seen.add(p.members)
                out.append(p)
    return out


def _explore_fusible(g: Graph, name: str, cfg: GenConfig | None = None) -> bool:
    node = g[name]
    if node.kind in _FUSIBLE_EXPLORE:
        return True
    if cfg is None:
        return False
    # exploration may also pull in small GEMMs and registered custom kernels —
    # the same classes the widened substitution steps stop partitioning on
    if node.kind is OpKind.GEMM:
        return _gemm_flops(g, node) < cfg.large_gemm_flops
    if node.kind is OpKind.CUSTOM and cfg.stitch_custom:
        from repro.kernels.registry import lookup
        return lookup(node) is not None
    return False


def exploratory_fusion(
    g: Graph, seeds: list[frozenset[str]] | None = None, cfg: GenConfig | None = None,
) -> list[FusionPattern]:
    """Alg. 2 with the paper's seed heuristics and a search budget."""
    cfg = cfg or GenConfig()
    if seeds is None:
        seeds = []
        for node in g.nodes.values():
            if node.kind not in (OpKind.ELEMENTWISE, OpKind.REDUCTION, OpKind.BATCHED_GEMM):
                continue
            if len(node.operands) > cfg.max_operands:
                continue
            io = node.bytes + sum(g[o].bytes for o in node.operands)
            if io < cfg.seed_min_bytes:
                continue
            seeds.append(frozenset([node.name]))

    patterns: list[FusionPattern] = []
    seen: set[frozenset[str]] = set()

    def expand_candidates(members: frozenset[str]) -> list[str]:
        cands: set[str] = set()
        for m in members:
            # ProducerExpansion
            for o in g[m].operands:
                if o not in members and _explore_fusible(g, o, cfg):
                    cands.add(o)
            # ConsumerExpansion
            for u in g.users(m):
                if u not in members and _explore_fusible(g, u, cfg):
                    cands.add(u)
        return sorted(cands)

    def explore(members: frozenset[str], depth: int):
        if len(patterns) >= cfg.max_patterns or depth > cfg.max_depth:
            return
        for cand in expand_candidates(members):
            fused = members | {cand}
            if fused in seen or len(fused) > cfg.max_pattern_size:
                continue
            seen.add(fused)
            if contraction_creates_cycle(g, fused):
                continue
            if len(fused) >= 2:
                patterns.append(FusionPattern(g, fused, "exploratory"))
            if len(patterns) >= cfg.max_patterns:
                return
            explore(fused, depth + 1)

    for s in seeds:
        explore(s, 0)
    return patterns


# ---------------------------------------------------------------------------
# §4.2 independent-op packing — horizontal FFD packs
# ---------------------------------------------------------------------------

def _node_sig(g: Graph, n: OpNode) -> tuple:
    """Structural twin signature: two nodes with equal signatures compute the
    same op at the same shapes over same-shaped operands — per-expert chain
    ops hash equal across experts, per-head tails across heads."""
    extra: tuple = ()
    if n.kind is OpKind.REDUCTION:
        extra = (tuple(n.attrs.get("axes", ())), n.attrs.get("op"))
    elif n.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM):
        extra = (tuple(map(tuple, n.attrs.get("contract", ((), ())))),
                 tuple(map(tuple, n.attrs.get("batch", ((), ())))))
    elif n.kind is OpKind.BROADCAST:
        extra = (tuple(n.attrs.get("bcast_dims", ())),)
    elif n.kind is OpKind.TRANSPOSE:
        extra = (tuple(n.attrs.get("perm", ())),)
    elif n.kind is OpKind.SLICE:
        extra = (tuple(n.attrs.get("starts", ())), tuple(n.attrs.get("limits", ())))
    elif n.kind is OpKind.CUSTOM:
        extra = (n.attrs.get("kernel"), n.attrs.get("project"))
    return (n.kind.value, n.attrs.get("op"), n.shape, n.dtype, extra,
            tuple(g[o].shape for o in n.operands),
            tuple(g[o].dtype for o in n.operands))


def _grow_cone(g: Graph, sink: str, cfg: GenConfig,
               taken: set[str]) -> frozenset[str]:
    """Exclusive producer cone of ``sink``: pull in an operand iff it is
    fusible, unclaimed, and *all* of its users already live in the cone —
    shared producers (the block input feeding every expert, router gates)
    stay external, which is what keeps sibling cones independent."""
    members: set[str] = {sink}
    changed = True
    while changed and len(members) < cfg.max_pattern_size:
        changed = False
        frontier: set[str] = set()
        for m in members:
            frontier.update(g[m].operands)
        for o in sorted(frontier - members):
            node = g[o]
            if node.is_source() or node.kind is OpKind.TUPLE or o in taken:
                continue
            if not _explore_fusible(g, o, cfg):
                continue
            if not all(u in members for u in g.users(o)):
                continue
            members.add(o)
            changed = True
            if len(members) >= cfg.max_pattern_size:
                break
    return frozenset(members)


def packing_fusion(g: Graph, cfg: GenConfig | None = None,
                   hw=None) -> list[PackPattern]:
    """Propose horizontal packs of independent subgraphs (paper §4.2).

    1. Hash every fusible compute node into structural twin classes; keep
       classes with multiplicity >= ``cfg.pack_min_group``.
    2. Walking classes sink-first (latest topo position first), grow an
       exclusive producer cone from each unclaimed twin.  A class whose
       cones collapse into fewer than ``pack_min_group`` disjoint cones
       (e.g. the combine-add chain joining the experts — its "twins" depend
       on each other) is discarded.
    3. First-fit-decreasing: cones sorted by register-pressure weight are
       binned under the register and scratch budgets (capacity-bounded, max
       ``pack_max_members`` subgraphs per bin); only mutually independent
       cones with a common row dimension share a bin.  Register capacity is
       a *max* over the bin's cones (independent subgraphs serialise inside
       a block, so the widest one sets the working set — the §4.2 occupancy
       argument); scratch is summed (one allocation serves the kernel).

    Each bin with >= 2 cones becomes a :class:`PackPattern` whose
    ``member_groups`` are the cones (pack provenance for the verifier).
    """
    cfg = cfg or GenConfig()
    if not cfg.pack_patterns:
        return []
    from .cost import CostModel, TPU_V5E
    hw = hw or TPU_V5E
    cost = CostModel(hw, reg_budget=cfg.reg_budget)
    reg_cap = cost.reg_budget
    scratch_cap = (cfg.scratch_budget if cfg.scratch_budget is not None
                   else hw.onchip_budget)

    topo_pos = {name: i for i, name in enumerate(g.topo_order())}
    classes: dict[tuple, list[str]] = {}
    for name, node in g.nodes.items():
        if node.is_source() or node.kind is OpKind.TUPLE:
            continue
        if not _explore_fusible(g, name, cfg):
            continue
        classes.setdefault(_node_sig(g, node), []).append(name)
    twin_classes = [sorted(v, key=lambda n: -topo_pos[n])
                    for v in classes.values() if len(v) >= cfg.pack_min_group]
    # sink classes first: their cones swallow whole chains, later (earlier-
    # topo) classes only pick over the uncovered remainder
    twin_classes.sort(key=lambda ns: -topo_pos[ns[0]])

    taken: set[str] = set()
    cones: list[frozenset[str]] = []
    for names in twin_classes:
        cand: list[frozenset[str]] = []
        claimed: set[str] = set(taken)
        for name in names:
            if name in claimed:
                continue
            cone = _grow_cone(g, name, cfg, claimed)
            claimed |= cone
            cand.append(cone)
        if len(cand) < cfg.pack_min_group:
            continue  # twins were dependent (combiner chains) or claimed
        # pipeline stages masquerade as twins (the two residual adds of one
        # block): their cones feed one another.  A true packing family is
        # mutually independent — any cross-cone edge disqualifies the class.
        owner = {m: i for i, c in enumerate(cand) for m in c}
        if any(owner.get(o) is not None and owner[o] != owner[m]
               for m in owner for o in g[m].operands):
            continue
        cones.extend(cand)
        taken = claimed

    if len(cones) < 2:
        return []

    def cone_rows(cone: frozenset[str]) -> int | None:
        # leading non-1 dim of the first sized output — the row grid the
        # emitter parallelises over (leading 1s are batch padding)
        for o in g.external_outputs(cone):
            for d in g[o].shape:
                if d > 1:
                    return d
        return None

    def cone_weight(cone: frozenset[str]) -> tuple[int, int]:
        p = FusionPattern(g, cone, "pack")
        reg = cost.register_pressure(p)
        if reg == 0:  # singleton cone: one live row tile
            reg = sum(cost._tile_bytes(g[m]) for m in cone)
        scr = sum(cost.scratch_request(p).values()) + cost.custom_scratch(p)
        return reg, scr

    weighted = []
    for cone in cones:
        rows = cone_rows(cone)
        if rows is None:
            continue
        reg, scr = cone_weight(cone)
        if reg > reg_cap or scr > scratch_cap:
            continue  # a cone that can't fuse alone can't join a bin
        weighted.append((reg, scr, rows, cone))
    # first-fit-decreasing over register weight (the binding budget)
    weighted.sort(key=lambda t: (-t[0], -t[1], sorted(t[3])[0]))

    def independent(cone: frozenset[str], others: list[frozenset[str]]) -> bool:
        pool = set().union(*others) if others else set()
        for m in cone:
            if any(o in pool for o in g[m].operands):
                return False
        for grp in others:
            for m in grp:
                if any(o in cone for o in g[m].operands):
                    return False
        # transitive dependence through external nodes (attention cone ->
        # residual add -> expert cone) would make the merged bin cyclic
        return not contraction_creates_cycle(g, frozenset(cone | pool))

    bins: list[dict] = []
    for reg, scr, rows, cone in weighted:
        placed = False
        for b in bins:
            if (b["rows"] == rows
                    and len(b["cones"]) < cfg.pack_max_members
                    and max(b["reg"], reg) <= reg_cap
                    and b["scr"] + scr <= scratch_cap
                    and independent(cone, b["cones"])):
                b["cones"].append(cone)
                b["reg"] = max(b["reg"], reg)
                b["scr"] += scr
                placed = True
                break
        if not placed:
            bins.append({"rows": rows, "cones": [cone], "reg": reg, "scr": scr})

    packs: list[PackPattern] = []
    for b in bins:
        if len(b["cones"]) < 2:
            continue
        union = frozenset().union(*b["cones"])
        if contraction_creates_cycle(g, union):
            continue
        try:
            packs.append(PackPattern(
                g, union, "pack",
                member_groups=tuple(sorted(b["cones"], key=sorted))))
        except ValueError:
            continue
        if len(packs) >= cfg.max_patterns:
            break
    return packs


def generate_patterns(g: Graph, cfg: GenConfig | None = None,
                      hw=None) -> list[FusionPattern]:
    """§4.2 composition rule: substitution fusion is the base strategy,
    exploratory fusion is supplementary, and independent-op packing adds
    horizontal candidates the first two (dependence-connected by
    construction) can never propose."""
    cfg = cfg or GenConfig()
    out = multi_step_substitution(g, cfg)
    seen = {p.members for p in out}
    for p in exploratory_fusion(g, None, cfg):
        if p.members not in seen:
            seen.add(p.members)
            out.append(p)
    for p in packing_fusion(g, cfg, hw):
        if p.members not in seen:
            seen.add(p.members)
            out.append(p)
    return out
