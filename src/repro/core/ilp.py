"""Fusion-plan ILP (paper §4.1) with iterative cycle-cut constraints (Fig. 3).

The problem:   maximize  sum_j X_j * f(P_j)
               s.t.      X_u + X_v <= 1   whenever P_u and P_v overlap
                         X_j in {0, 1}
plus lazily-added constraints forbidding plans whose contracted graph is
cyclic.  This is weighted set packing.  Instance sizes after the paper's
heuristics are modest (tens to a few thousand patterns), so we solve exactly
with a best-first branch-and-bound whose bound is the LP-ish greedy residual;
``pulp`` (the package the paper itself uses) is used as an optional
cross-check in tests, never as a runtime dependency.

Cycle handling mirrors Fig. 3(d): solve -> contract chosen patterns ->
detect a cycle among contracted supernodes -> add a "not all of these
together" cut -> re-solve, until acyclic.
"""

from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field

from .ir import Graph
from .pattern import FusionPattern

__all__ = ["ILPSolver", "solve_fusion_plan", "greedy_fusion_plan", "PlanResult"]


@dataclass
class PlanResult:
    chosen: list[FusionPattern]
    objective: float
    iterations: int          # number of solve rounds (1 + cycle-cut rounds)
    cuts_added: int
    nodes_explored: int
    method: str = "ilp"      # "ilp" | "greedy" (anytime budget expired)
    budget_expired: bool = False


class ILPSolver:
    """Exact best-first branch & bound for weighted set packing with
    arbitrary 'at most k-1 of this set' cut constraints.

    ``deadline`` (a ``time.monotonic`` instant) makes the solve *anytime*:
    on expiry it returns the best feasible selection found so far and sets
    ``budget_expired`` — a huge backward graph can never hang the caller.
    """

    def __init__(self, weights: list[float], overlaps: list[set[int]],
                 node_budget: int = 200_000, deadline: float | None = None):
        self.w = weights
        self.overlaps = overlaps          # overlaps[i] = set of j conflicting with i
        self.cuts: list[frozenset[int]] = []
        self.node_budget = node_budget
        self.deadline = deadline
        self.budget_expired = False
        self.nodes_explored = 0

    def add_cut(self, idxs: frozenset[int]) -> None:
        """Forbid selecting ALL of `idxs` simultaneously."""
        self.cuts.append(idxs)

    # -------------------------------------------------------------- solve --
    def solve(self) -> tuple[list[int], float]:
        n = len(self.w)
        order = sorted(range(n), key=lambda i: -self.w[i])
        # suffix upper bound: sum of remaining positive weights (ignores
        # conflicts -> valid optimistic bound)
        suffix = [0.0] * (n + 1)
        for pos in range(n - 1, -1, -1):
            suffix[pos] = suffix[pos + 1] + max(self.w[order[pos]], 0.0)

        best_val = 0.0
        best_sel: list[int] = []
        self.nodes_explored = 0

        # DFS with bounding (explicit stack; states: (pos, chosen, blocked, val))
        stack = [(0, frozenset(), frozenset(), 0.0)]
        while stack:
            pos, chosen, blocked, val = stack.pop()
            self.nodes_explored += 1
            if self.nodes_explored > self.node_budget:
                break  # return best found so far (budget guard; tested small)
            if (self.deadline is not None and self.nodes_explored % 256 == 0
                    and time.monotonic() > self.deadline):
                self.budget_expired = True
                break  # anytime: best-so-far under the wall-clock budget
            if val > best_val:
                best_val, best_sel = val, sorted(chosen)
            if pos >= n or val + suffix[pos] <= best_val:
                continue
            i = order[pos]
            # branch 1: skip i
            stack.append((pos + 1, chosen, blocked, val))
            # branch 2: take i (if feasible)
            if i not in blocked and self.w[i] > 0:
                new_chosen = chosen | {i}
                if not self._violates_cut(new_chosen):
                    new_blocked = blocked | self.overlaps[i]
                    stack.append((pos + 1, new_chosen, new_blocked, val + self.w[i]))
        return best_sel, best_val

    def _violates_cut(self, chosen: frozenset[int]) -> bool:
        return any(cut.issubset(chosen) for cut in self.cuts)


# ---------------------------------------------------------------------------
# plan-level driver: ILP + cycle detection loop
# ---------------------------------------------------------------------------

def _find_cycle_patterns(g: Graph, chosen: list[FusionPattern]) -> frozenset[int] | None:
    """Detect a cycle in the graph contracted by `chosen`; return the indices
    of the patterns participating in one cycle, or None if acyclic.

    Contracted-graph nodes: one supernode per chosen pattern + one node per
    remaining op.  Edge u->v iff some member/op of u feeds some member/op
    of v."""
    owner: dict[str, int] = {}
    for idx, p in enumerate(chosen):
        for m in p.members:
            owner[m] = idx

    def rep(name: str) -> tuple[str, int] | str:
        return ("P", owner[name]) if name in owner else name

    adj: dict[object, set[object]] = {}
    for name, node in g.nodes.items():
        dst = rep(name)
        for o in node.operands:
            src = rep(o)
            if src != dst:
                adj.setdefault(src, set()).add(dst)
        adj.setdefault(dst, set())

    # iterative DFS cycle detection, tracking the stack to extract the cycle
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {v: WHITE for v in adj}
    parent: dict[object, object] = {}
    for root in list(adj):
        if color[root] != WHITE:
            continue
        stack = [(root, iter(sorted(adj[root], key=repr)))]
        color[root] = GRAY
        while stack:
            v, it = stack[-1]
            advanced = False
            for w in it:
                if color[w] == WHITE:
                    color[w] = GRAY
                    parent[w] = v
                    stack.append((w, iter(sorted(adj[w], key=repr))))
                    advanced = True
                    break
                if color[w] == GRAY:
                    # found cycle w -> ... -> v -> w ; collect pattern ids
                    ids: set[int] = set()
                    cur = v
                    while True:
                        if isinstance(cur, tuple) and cur[0] == "P":
                            ids.add(cur[1])
                        if cur == w:
                            break
                        cur = parent.get(cur)
                        if cur is None:
                            break
                    if isinstance(w, tuple) and w[0] == "P":
                        ids.add(w[1])
                    if ids:
                        return frozenset(ids)
            if not advanced:
                color[v] = BLACK
                stack.pop()
        # continue to next root
    return None


def greedy_fusion_plan(
    g: Graph,
    pats: list[FusionPattern],
    w: list[float],
    overlaps: list[set[int]],
) -> tuple[list[FusionPattern], float]:
    """The paper's §4 greedy heuristic: take patterns in descending score
    order, skipping overlaps, then repair cycles by dropping the cheapest
    pattern of each detected cycle.  Used as the anytime fallback when the
    ILP's wall-clock budget expires — always valid, usually near-optimal."""
    chosen_idx: list[int] = []
    blocked: set[int] = set()
    for i in sorted(range(len(w)), key=lambda i: -w[i]):
        if w[i] <= 0 or i in blocked:
            continue
        chosen_idx.append(i)
        blocked |= overlaps[i]
    while True:
        cyc = _find_cycle_patterns(g, [pats[i] for i in chosen_idx])
        if cyc is None:
            break
        drop = min(cyc, key=lambda k: w[chosen_idx[k]])
        chosen_idx.pop(drop)
    return [pats[i] for i in chosen_idx], sum(w[i] for i in chosen_idx)


def solve_fusion_plan(
    g: Graph,
    patterns: list[FusionPattern],
    scores: list[float],
    max_cycle_rounds: int = 50,
    budget_seconds: float | None = None,
    scratch_requests: list[int] | None = None,
    scratch_budget: int | None = None,
) -> PlanResult:
    """The paper's full loop: ILP -> cycle check -> add cut -> re-solve.

    ``budget_seconds`` makes the whole loop *anytime*: when the wall-clock
    budget expires (inside a branch-and-bound solve or between cycle-cut
    rounds), the greedy §4 heuristic produces the plan instead, recorded in
    the returned :class:`PlanResult` (``method="greedy"``,
    ``budget_expired=True``) so callers and cache records can tell an
    optimal plan from a budgeted one.

    ``scratch_requests``/``scratch_budget`` add the on-chip feasibility
    constraint: any pattern whose requested scratch exceeds the budget is
    excluded from the solve outright (infeasible, not merely unattractive).
    """
    assert len(patterns) == len(scores)
    deadline = (None if budget_seconds is None
                else time.monotonic() + budget_seconds)
    if scratch_requests is not None and scratch_budget is not None:
        assert len(scratch_requests) == len(patterns)
        scores = [
            -1.0 if scratch_requests[i] > scratch_budget else s
            for i, s in enumerate(scores)
        ]
    keep = [i for i, s in enumerate(scores) if s > 0]
    pats = [patterns[i] for i in keep]
    w = [scores[i] for i in keep]

    overlaps: list[set[int]] = [set() for _ in pats]
    for i, j in itertools.combinations(range(len(pats)), 2):
        if pats[i].overlaps(pats[j]):
            overlaps[i].add(j)
            overlaps[j].add(i)

    # Horizontal packs span distant regions of the graph, so a pack and a
    # vertical pattern that are each acyclic alone routinely close a cycle
    # *pairwise* once both are contracted — and a two-pattern cycle holds no
    # matter what else is selected, so it is a hard mutual exclusion, not a
    # lazy cut.  Folding these into the overlap constraints up front keeps
    # the cycle-cut loop for the rare >= 3-pattern cycles only; without
    # this, pack-heavy graphs (stacked RNN steps) burn one solve round per
    # pair and blow through ``max_cycle_rounds``.
    pack_idx = {i for i, p in enumerate(pats)
                if getattr(p, "member_groups", None)}
    for i in sorted(pack_idx):
        for j in range(len(pats)):
            if j == i or j in overlaps[i] or (j in pack_idx and j < i):
                continue
            if deadline is not None and time.monotonic() > deadline:
                break
            if _find_cycle_patterns(g, [pats[i], pats[j]]) is not None:
                overlaps[i].add(j)
                overlaps[j].add(i)

    def greedy(rounds: int, cuts: int, nodes: int) -> PlanResult:
        chosen, val = greedy_fusion_plan(g, pats, w, overlaps)
        return PlanResult(chosen, val, rounds, cuts, nodes,
                          method="greedy", budget_expired=True)

    solver = ILPSolver(w, overlaps, deadline=deadline)
    cuts = 0
    for rounds in range(1, max_cycle_rounds + 1):
        if deadline is not None and time.monotonic() > deadline:
            return greedy(rounds, cuts, solver.nodes_explored)
        sel, val = solver.solve()
        if solver.budget_expired:
            return greedy(rounds, cuts, solver.nodes_explored)
        chosen = [pats[i] for i in sel]
        cyc = _find_cycle_patterns(g, chosen)
        if cyc is None:
            return PlanResult(chosen, val, rounds, cuts, solver.nodes_explored)
        # map pattern positions in `chosen` back to solver indices
        cut_idx = frozenset(sel[k] for k in range(len(sel)) if k in cyc)
        if len(cut_idx) == 1:
            # a single pattern whose contraction self-cycles can never be
            # chosen (shouldn't happen: generators pre-filter, but be safe)
            only = next(iter(cut_idx))
            solver.w[only] = -1.0
        else:
            solver.add_cut(cut_idx)
        cuts += 1
    raise RuntimeError("cycle-cut loop did not converge")
